#!/usr/bin/env bash
# Repo verification gate.
#
#   scripts/verify.sh          fast gate: not-slow tests + API/serving smoke
#                              + docs smoke (runs the README quickstart)
#   scripts/verify.sh --full   tier-1 (the full pytest suite) + the smokes
#   scripts/verify.sh --bench-smoke
#                              fast gate + the smallest-size runs of
#                              benchmarks/kmvp_multirhs.py (multi-RHS
#                              amortization + stream chunk-cache transfer
#                              reduction), benchmarks/infer_scaling.py
#                              (inference memory contracts; appends a
#                              BENCH_infer.json trajectory point per PR),
#                              benchmarks/serve_slo.py (continuous
#                              batching vs request-at-a-time with
#                              occupancy/latency asserts; appends
#                              BENCH_serve.json),
#                              benchmarks/ckpt_overhead.py (in-training
#                              checkpoint step overhead; appends
#                              BENCH_ckpt.json), and
#                              benchmarks/multihost_scaling.py (step time
#                              + counted cross-host bytes/eval at 1/2/4
#                              controller processes; appends
#                              BENCH_multihost.json)
#   scripts/verify.sh --fault-smoke
#                              fast gate + the chaos path: (a) a stream
#                              fit under injected transient chunk-read
#                              faults must match the clean fit BITWISE
#                              (the retry layer absorbs the fault), and
#                              (b) a supervised kernel_train run whose
#                              worker SIGKILLs itself mid-commit must
#                              auto-restart from the latest checkpoint
#                              and save a beta bitwise identical to an
#                              uninterrupted supervised run
#   scripts/verify.sh --multihost-smoke
#                              fast gate + a real 2-process
#                              jax.distributed round-trip through the
#                              CLIs: scripts/launch_multihost.sh trains
#                              over an exported shard directory, saves on
#                              the primary, then a 2-process spanning
#                              engine serves the checkpoint and verifies
#                              every response against a local reference
#
# Every mode also runs the resume smoke: a real stream `kernel_train` run
# is SIGKILLed after its first committed step file, `--resume`d to
# completion, and the saved model is served — the preemption path the
# checkpoint subsystem exists for, exercised through the actual CLIs.
#
# The fast gate is what you run in the inner loop (a couple of minutes);
# the slow marker holds the fake-device subprocess suites
# (test_distributed, test_dryrun_path, test_multihost, the decode
# sections of test_models_smoke).
#
# The docs smoke extracts the first ```python block from README.md and
# executes it, so the quickstart the repo advertises cannot silently rot.
#
# Each pytest run ends with a per-test-file pass/fail summary table
# (scripts/summarize_junit.py); any slow-unmarked test exceeding the 60s
# budget fails the gate so the fast path stays fast.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export JAX_PLATFORMS=${JAX_PLATFORMS:-cpu}

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT
status=0

run_suite() {   # run_suite <label> <marker-expr> <per-test-budget-seconds>
    local label="$1" marker="$2" budget="$3"
    local xml="$tmp/$label.xml"
    echo "== $label: pytest -m \"$marker\" =="
    python -m pytest -x -q -m "$marker" --junitxml="$xml" || status=1
    if [[ -f "$xml" ]]; then
        python scripts/summarize_junit.py "$xml" --max-seconds "$budget" \
            || status=1
    else
        echo "no junit report produced for $label" >&2
        status=1
    fi
}

bench_smoke=0
if [[ "${1:-}" == "--bench-smoke" ]]; then
    bench_smoke=1
fi
multihost_smoke=0
if [[ "${1:-}" == "--multihost-smoke" ]]; then
    multihost_smoke=1
fi
fault_smoke=0
if [[ "${1:-}" == "--fault-smoke" ]]; then
    fault_smoke=1
fi

if [[ "${1:-}" == "--full" ]]; then
    run_suite "fast suite" "not slow" 60
    run_suite "slow suite" "slow" 0
else
    run_suite "fast gate" "not slow" 60
fi

if [[ "$status" -ne 0 ]]; then
    echo "== verify FAILED (skipping smoke) =="
    exit "$status"
fi

echo "== API smoke: train -> save -> load -> serve =="
serve_out="$tmp/serve_selftest.out"
python -m repro.launch.kernel_serve --selftest 2>&1 | tee "$serve_out" \
    || status=1
# the selftest must exercise serving a stream-plan machine (the plan
# override path); a silently narrowed selftest fails the gate
grep -q "stream-plan machine served" "$serve_out" || {
    echo "serve selftest no longer covers a stream-plan machine" >&2
    status=1
}
# ... and the concurrent continuous-batching engine (client threads firing
# interleaved mixed-size mixed-K requests, every response verified)
grep -q "concurrent engine OK" "$serve_out" || {
    echo "serve selftest no longer covers the concurrent serve engine" >&2
    status=1
}

echo "== ckpt smoke: train -> SIGKILL -> --resume -> save -> serve =="
ck="$tmp/ckpt_smoke"
mkdir -p "$ck"
python - "$ck/shards" <<'PY' || status=1
import sys
import numpy as np
from repro.data.chunks import save_chunks
rng = np.random.default_rng(7)
X = rng.standard_normal((1024, 12)).astype(np.float32)
w = rng.standard_normal(12)
y = np.where(X @ w > 0, 1, -1).astype(np.int64)
save_chunks(sys.argv[1], X, y, rows_per_shard=256)
PY
train_cmd=(python -m repro.launch.kernel_train --plan stream
           --data-dir "$ck/shards" --m 32 --max-iter 40 --lam 1e-3
           --sigma 2.0 --chunk-rows 256 --ckpt-interval 2
           --ckpt-dir "$ck/steps" --save "$ck/model.npz")
"${train_cmd[@]}" > "$ck/train.out" 2>&1 &
train_pid=$!
# kill -9 the moment the first step file commits (the atomic-rename
# protocol means whatever is on disk at that instant must be loadable)
for _ in $(seq 1 3000); do
    compgen -G "$ck/steps/step-*.npz" > /dev/null && break
    kill -0 "$train_pid" 2>/dev/null || break
    sleep 0.1
done
if compgen -G "$ck/steps/step-*.npz" > /dev/null; then
    kill -9 "$train_pid" 2>/dev/null
    wait "$train_pid" 2>/dev/null
else
    wait "$train_pid" 2>/dev/null
    echo "ckpt smoke: no step file ever committed" >&2
    cat "$ck/train.out" >&2
    status=1
fi
if [[ "$status" -eq 0 ]]; then
    "${train_cmd[@]}" --resume "$ck/steps" 2>&1 | tee "$ck/resume.out" \
        || status=1
    grep -q "resuming from step" "$ck/resume.out" || {
        echo "ckpt smoke: --resume did not restore a committed step" >&2
        status=1
    }
    [[ -f "$ck/model.npz" ]] || {
        echo "ckpt smoke: resumed run saved no model" >&2
        status=1
    }
fi
if [[ "$status" -eq 0 ]]; then
    # the resumed model must be servable
    python -m repro.launch.kernel_serve --ckpt "$ck/model.npz" \
        --requests 16 --clients 2 > "$ck/serve.out" 2>&1 || {
        echo "ckpt smoke: serving the resumed model failed" >&2
        cat "$ck/serve.out" >&2
        status=1
    }
fi

if [[ "$bench_smoke" -eq 1 ]]; then
    echo "== bench smoke: multi-RHS kmvp amortization + stream chunk cache =="
    python -m benchmarks.kmvp_multirhs --smoke --emit-json || status=1
    echo "== bench smoke: inference scaling + memory contracts =="
    python -m benchmarks.infer_scaling --smoke || status=1
    echo "== bench smoke: dtype accuracy-vs-speed columns in trajectories =="
    python - <<'PY' || status=1
import json
# the dtype-policy sweeps must land their accuracy-vs-speed columns in the
# emitted trajectories — a silently dropped sweep fails the gate
kmvp = json.load(open("BENCH_kmvp.json"))[-1]["results"]
sweep = {r["policy"]: r for r in kmvp["dtype_sweep"]}
assert set(sweep) == {"fp32", "bf16", "fp16"}, sweep
for r in sweep.values():
    assert {"fwd_s", "t_s", "step_vs_fp32", "max_rel_err"} <= set(r), r
infer = json.load(open("BENCH_infer.json"))[-1]["results"]
plans = {r["plan"] for r in infer}
assert {"local[fp32]", "local[bf16]", "local[fp16]", "ckpt[int8]"} <= plans
pol = [r for r in infer if r["plan"].startswith("local[")]
for r in pol:
    assert {"score_s", "rows_per_s", "max_rel_err"} <= set(r), r
ck = next(r for r in infer if r["plan"] == "ckpt[int8]")
assert ck["checkpoint_bytes_int8"] < ck["checkpoint_bytes_fp32"], ck
print("dtype accuracy-vs-speed columns present in "
      "BENCH_kmvp.json and BENCH_infer.json")
PY
    echo "== bench smoke: serve SLO (continuous batching vs baseline) =="
    python -m benchmarks.serve_slo --smoke || status=1
    echo "== bench smoke: checkpoint step-time overhead =="
    python -m benchmarks.ckpt_overhead --smoke || status=1
    echo "== bench smoke: multi-controller scaling (1/2/4 processes) =="
    python -m benchmarks.multihost_scaling --smoke || status=1
fi

if [[ "$multihost_smoke" -eq 1 ]]; then
    echo "== multihost smoke: 2-process train -> save -> spanning serve =="
    mh="$tmp/mh_smoke"
    mkdir -p "$mh"
    scripts/launch_multihost.sh -n 2 -d 2 -l "$mh/train_logs" -- \
        --dataset covtype --scale 0.005 --plan stream --m 32 --max-iter 30 \
        --data-dir "$mh/shards" --export-chunks --chunk-rows 512 \
        --save "$mh/model.npz" > "$mh/train.out" 2>&1 || {
        echo "multihost smoke: 2-process training failed" >&2
        cat "$mh/train.out" >&2
        status=1
    }
    if [[ "$status" -eq 0 ]]; then
        grep -q "spanning server" "$mh/train.out" || {
            echo "multihost smoke: training never ran the spanning eval" >&2
            status=1
        }
        [[ -f "$mh/model.npz" ]] || {
            echo "multihost smoke: primary saved no model" >&2
            status=1
        }
    fi
    if [[ "$status" -eq 0 ]]; then
        scripts/launch_multihost.sh -n 2 -d 2 -m repro.launch.kernel_serve \
            -l "$mh/serve_logs" -- --ckpt "$mh/model.npz" --requests 16 \
            > "$mh/serve.out" 2>&1 || {
            echo "multihost smoke: 2-process serving failed" >&2
            cat "$mh/serve.out" >&2
            status=1
        }
    fi
    if [[ "$status" -eq 0 ]]; then
        grep -q "spanning engine OK" "$mh/serve.out" || {
            echo "multihost smoke: spanning engine verified no responses" >&2
            cat "$mh/serve.out" >&2
            status=1
        }
    fi
fi

if [[ "$fault_smoke" -eq 1 ]]; then
    echo "== fault smoke A: transient chunk-read faults, bitwise parity =="
    python - <<'PY' || status=1
import numpy as np, jax, jax.numpy as jnp, tempfile
from repro.api import KernelMachine, MachineConfig, StreamConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_classification
from repro.data.chunks import MmapChunkSource, save_chunks
from repro.faults import FaultPlan

cfg = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=1e-2,
                    plan="stream", tron=TronConfig(max_iter=20),
                    stream=StreamConfig(chunk_rows=64))
X, y = make_classification(jax.random.PRNGKey(0), 512, 8)
d = tempfile.mkdtemp(prefix="fault-smoke-")
save_chunks(d, np.asarray(X), np.asarray(y), rows_per_shard=100)
basis = np.asarray(random_basis(jax.random.PRNGKey(1), jnp.asarray(X), 16))
clean = KernelMachine(cfg).fit(MmapChunkSource(d, chunk_rows=64), None, basis)
# times=2 is the most one read survives under the 3-attempt retry cap
plan = FaultPlan().inject("chunk.read", times=2)
with plan:
    faulted = KernelMachine(cfg).fit(MmapChunkSource(d, chunk_rows=64),
                                     None, basis)
fired = plan.stats()["fired"].get("chunk.read", 0)
assert fired >= 1, "fault plan never fired"
assert np.array_equal(np.asarray(clean.state_["beta"]),
                      np.asarray(faulted.state_["beta"])), \
    "transient chunk-read faults changed result bits"
print(f"fault smoke A OK: {fired} injected read fault(s), beta bitwise equal")
PY

    echo "== fault smoke B: SIGKILL under --supervise, auto-recovery =="
    fs="$tmp/fault_smoke"
    mkdir -p "$fs"
    python - "$fs/shards" <<'PY' || status=1
import sys
import numpy as np
from repro.data.chunks import save_chunks
rng = np.random.default_rng(7)
X = rng.standard_normal((1024, 12)).astype(np.float32)
w = rng.standard_normal(12)
y = np.where(X @ w > 0, 1, -1).astype(np.int64)
save_chunks(sys.argv[1], X, y, rows_per_shard=256)
PY
    sup_cmd=(python -m repro.launch.kernel_train --supervise
             --max-restarts 2 --plan stream --data-dir "$fs/shards"
             --m 32 --max-iter 40 --lam 1e-3 --sigma 2.0 --chunk-rows 256
             --ckpt-interval 2)
    "${sup_cmd[@]}" --ckpt-dir "$fs/ref-steps" --save "$fs/ref.npz" \
        > "$fs/ref.out" 2>&1 || { cat "$fs/ref.out" >&2; status=1; }
    # the worker SIGKILLs itself inside its 2nd checkpoint commit; the
    # flag file makes that happen exactly once across restarts
    REPRO_FAULTS='{"rules":[{"site":"ckpt.commit","action":"kill","after":1,"times":1,"flag":"'"$fs"'/killed-once"}]}' \
        "${sup_cmd[@]}" --ckpt-dir "$fs/got-steps" --save "$fs/got.npz" \
        > "$fs/got.out" 2>&1 || { cat "$fs/got.out" >&2; status=1; }
    if [[ "$status" -eq 0 ]]; then
        [[ -f "$fs/killed-once" ]] || {
            echo "fault smoke: the kill rule never fired" >&2
            status=1
        }
        grep -q "restarting from step" "$fs/got.out" || {
            echo "fault smoke: supervisor never restarted from a step" >&2
            tail -30 "$fs/got.out" >&2
            status=1
        }
        python - "$fs/ref.npz" "$fs/got.npz" <<'PY' || status=1
import sys
import numpy as np
ref, got = (np.load(p, allow_pickle=True) for p in sys.argv[1:3])
assert np.array_equal(ref["beta"], got["beta"]), \
    "supervised recovery diverged from the uninterrupted run"
print("fault smoke B OK: recovered beta bitwise equal after SIGKILL")
PY
    fi
fi

echo "== docs smoke: README quickstart block =="
awk '/^```python$/{flag=1; next} /^```$/{if (flag) exit} flag' README.md \
    > "$tmp/readme_quickstart.py"
if [[ ! -s "$tmp/readme_quickstart.py" ]]; then
    echo "README.md has no \`\`\`python quickstart block" >&2
    status=1
else
    python "$tmp/readme_quickstart.py" || status=1
fi

if [[ "$status" -ne 0 ]]; then
    echo "== verify FAILED =="
    exit "$status"
fi
echo "== verify OK =="
