#!/usr/bin/env bash
# Repo verification gate.
#
#   scripts/verify.sh          fast gate: not-slow tests + API/serving smoke
#   scripts/verify.sh --full   tier-1 (the full pytest suite) + the smoke
#
# The fast gate is what you run in the inner loop (a couple of minutes);
# the slow marker holds the 8-fake-device subprocess suites
# (test_distributed, test_dryrun_path, test_decode_consistency).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export JAX_PLATFORMS=${JAX_PLATFORMS:-cpu}

if [[ "${1:-}" == "--full" ]]; then
    echo "== tier-1: full pytest suite =="
    python -m pytest -x -q
else
    echo "== fast gate: pytest -m 'not slow' =="
    python -m pytest -x -q -m "not slow"
fi

echo "== API smoke: train -> save -> load -> serve =="
python -m repro.launch.kernel_serve --selftest

echo "== verify OK =="
