#!/usr/bin/env bash
# Local multi-controller launcher: run N copies of a repro.launch CLI as
# N simulated hosts (one process per "host", K fake CPU devices each via
# --xla_force_host_platform_device_count), wired together through a
# jax.distributed coordinator on localhost.
#
#   scripts/launch_multihost.sh [-n NPROC] [-d DEV_PER_PROC] [-p PORT] \
#       [-m MODULE] [-l LOGDIR] -- <args passed to every process>
#
#   # 2-host stream training over a shared shard directory:
#   scripts/launch_multihost.sh -n 2 -- \
#       --dataset covtype --scale 0.005 --m 64 --plan stream \
#       --data-dir /tmp/mh_shards --export-chunks --save /tmp/mh.npz
#
#   # then serve that checkpoint from a 2-process spanning engine:
#   scripts/launch_multihost.sh -n 2 -m repro.launch.kernel_serve -- \
#       --ckpt /tmp/mh.npz --requests 16 --max-batch 64
#
# The watchdog kills every remaining worker the moment one dies, prints
# the dead worker's exit code and log tail, and exits nonzero — a hung
# collective can never outlive its peers silently. Process 0's log is
# echoed on success (followers are silent by design).
set -u

NPROC=2
DEVS=1
PORT=$(( (RANDOM % 2000) + 12000 ))
MODULE=repro.launch.kernel_train
LOGDIR=""
while getopts "n:d:p:m:l:h" opt; do
  case "$opt" in
    n) NPROC="$OPTARG" ;;
    d) DEVS="$OPTARG" ;;
    p) PORT="$OPTARG" ;;
    m) MODULE="$OPTARG" ;;
    l) LOGDIR="$OPTARG" ;;
    h) sed -n '2,20p' "$0"; exit 0 ;;
    *) exit 2 ;;
  esac
done
shift $((OPTIND - 1))
[ "${1:-}" = "--" ] && shift

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
LOGDIR="${LOGDIR:-$(mktemp -d /tmp/multihost-logs.XXXXXX)}"
mkdir -p "$LOGDIR"
echo "[launch] $MODULE x $NPROC processes ($DEVS fake devices each), " \
     "coordinator 127.0.0.1:$PORT, logs in $LOGDIR"

PIDS=()
for ((p = 0; p < NPROC; p++)); do
  XLA_FLAGS="--xla_force_host_platform_device_count=$DEVS ${XLA_FLAGS:-}" \
  JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
  PYTHONPATH="$ROOT/src${PYTHONPATH:+:$PYTHONPATH}" \
  python -m "$MODULE" \
      --coordinator "127.0.0.1:$PORT" --num-processes "$NPROC" \
      --process-id "$p" "$@" > "$LOGDIR/proc$p.log" 2>&1 &
  PIDS[$p]=$!
done

# Watchdog: poll the fleet; first nonzero exit kills the rest.
FAIL=""
ALIVE=$NPROC
while [ "$ALIVE" -gt 0 ] && [ -z "$FAIL" ]; do
  ALIVE=0
  for ((p = 0; p < NPROC; p++)); do
    pid="${PIDS[$p]}"
    [ -z "$pid" ] && continue
    if kill -0 "$pid" 2>/dev/null; then
      ALIVE=$((ALIVE + 1))
    else
      wait "$pid"; rc=$?
      PIDS[$p]=""
      if [ "$rc" -ne 0 ]; then FAIL="$p:$rc"; fi
    fi
  done
  [ "$ALIVE" -gt 0 ] && [ -z "$FAIL" ] && sleep 0.2
done

if [ -n "$FAIL" ]; then
  DEAD="${FAIL%%:*}"; RC="${FAIL##*:}"
  for ((p = 0; p < NPROC; p++)); do
    [ -n "${PIDS[$p]}" ] && kill -9 "${PIDS[$p]}" 2>/dev/null
  done
  wait 2>/dev/null
  echo "[launch] FAIL: process $DEAD exited rc=$RC — killed the remaining" \
       "workers. Its log tail ($LOGDIR/proc$DEAD.log):" >&2
  tail -n 25 "$LOGDIR/proc$DEAD.log" >&2
  exit 1
fi
wait 2>/dev/null

echo "[launch] OK — process 0 output:"
cat "$LOGDIR/proc0.log"
