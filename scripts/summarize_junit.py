"""Per-test-file pass/fail summary + duration gate for scripts/verify.sh.

Reads a pytest --junitxml report, prints one table row per test file, and
exits nonzero when (a) any test failed/errored, or (b) --max-seconds > 0
and any single test exceeded it. The duration gate is how the fast gate
stays fast: a test that belongs in the slow suite but forgot its
``@pytest.mark.slow`` fails verification instead of silently dragging the
inner loop past the budget.

For each failed test a detail block of the failure text is printed after
the table. The multihost fleet tests embed per-process worker log tails
in their FleetError messages (tests/multihost/rig.py), so a dead or hung
subprocess worker's last words reach the verify.sh transcript instead of
dying with the tmpdir.
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET
from collections import defaultdict


def file_key(case) -> str:
    # xunit2 has no file attr; classname looks like tests.test_kernels[.Cls]
    f = case.get("file")
    if f:
        return f
    parts = (case.get("classname") or "?").split(".")
    for p in parts:
        if p.startswith("test_"):
            return p + ".py"
    return ".".join(parts) or "?"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("junit_xml")
    ap.add_argument("--max-seconds", type=float, default=60.0,
                    help="fail any single test over this; 0 disables "
                         "(the slow suite)")
    ap.add_argument("--detail-lines", type=int, default=40,
                    help="max failure-text lines printed per failed test "
                         "(keeps subprocess log tails, drops traceback "
                         "noise above them); 0 disables detail blocks")
    args = ap.parse_args()

    tree = ET.parse(args.junit_xml)
    per_file = defaultdict(lambda: {"pass": 0, "fail": 0, "skip": 0,
                                    "time": 0.0, "worst": ("", 0.0)})
    over_budget = []
    details = []
    for case in tree.iter("testcase"):
        row = per_file[file_key(case)]
        t = float(case.get("time") or 0.0)
        row["time"] += t
        name = case.get("name", "?")
        if t > row["worst"][1]:
            row["worst"] = (name, t)
        bad = case.find("failure")
        if bad is None:
            bad = case.find("error")
        if bad is not None:
            row["fail"] += 1
            text = (bad.text or bad.get("message") or "").rstrip()
            details.append((file_key(case), name, text))
        elif case.find("skipped") is not None:
            row["skip"] += 1
        else:
            row["pass"] += 1
        if args.max_seconds > 0 and t > args.max_seconds:
            over_budget.append((file_key(case), name, t))

    width = max([len(f) for f in per_file] + [10])
    print(f"{'file':<{width}}  {'pass':>5} {'fail':>5} {'skip':>5} "
          f"{'time':>8}  slowest")
    failed = 0
    for f in sorted(per_file):
        r = per_file[f]
        failed += r["fail"]
        status = "FAIL" if r["fail"] else "ok"
        print(f"{f:<{width}}  {r['pass']:>5} {r['fail']:>5} {r['skip']:>5} "
              f"{r['time']:>7.1f}s  {r['worst'][0]} ({r['worst'][1]:.1f}s) "
              f"[{status}]")

    rc = 0
    if failed:
        if args.detail_lines > 0:
            for f, name, text in details:
                print(f"---- failure detail: {f}::{name} ----",
                      file=sys.stderr)
                lines = text.splitlines()
                if len(lines) > args.detail_lines:
                    print(f"[... {len(lines) - args.detail_lines} lines "
                          f"elided ...]", file=sys.stderr)
                    lines = lines[-args.detail_lines:]
                for ln in lines:
                    print(ln, file=sys.stderr)
        print(f"SUMMARY: {failed} test(s) failed", file=sys.stderr)
        rc = 1
    for f, name, t in over_budget:
        print(f"SUMMARY: {f}::{name} took {t:.1f}s > "
              f"{args.max_seconds:.0f}s budget — mark it @pytest.mark.slow "
              f"or make it faster", file=sys.stderr)
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
