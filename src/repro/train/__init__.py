from repro.train.steps import make_train_step, make_serve_step
