"""train_step / serve_step factories — the units the dry-run lowers.

train_step: loss -> grad -> AdamW update (grads f32, params cfg dtype,
moments cfg.state_dtype). serve_step: one-token decode against the cache.
Both are pure functions of (state..., batch) so jit in/out shardings fully
determine the distribution.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.registry import ModelAPI
from repro.optim.adamw import AdamWConfig, adamw_update


def make_train_step(model: ModelAPI, opt_cfg: AdamWConfig,
                    microbatches: int = 1, acc_dtype=None):
    """AdamW train step with optional gradient accumulation.

    ``microbatches > 1`` splits the global batch on the leading dim and
    scans over the slices accumulating grads — activation memory drops by
    the microbatch factor (how the 256x4096-token train shapes fit the
    16 GB/chip budget; see EXPERIMENTS.md §Dry-run). The scan goes through
    models.common.pscan so dry-run cost probes stay exact.
    """
    from repro.models.common import pscan

    def loss_fn(p, b):
        loss, metrics = model.loss(p, b)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            adt = acc_dtype or jnp.float32
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (grads, loss), _ = pscan(acc_body, (g0, jnp.zeros(())), micro,
                                     length=microbatches)
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype), grads, params)
            loss = loss / microbatches
            metrics = {"ce": loss, "aux": jnp.zeros(())}
        new_params, new_opt = adamw_update(grads, opt_state, params, opt_cfg)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {**metrics, "loss": loss, "gnorm": gnorm}

    return train_step


def make_prefill_step(model: ModelAPI):
    """Forward-only over the full sequence; emits last-position logits
    (what a serving system computes before switching to decode)."""
    from repro.models import encdec as encdec_mod
    from repro.models import transformer as lm_mod

    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.is_encdec:
            enc_out = encdec_mod.encode(params, cfg, batch["frames"])
            logits = encdec_mod.decoder_forward(params, cfg, batch["tokens"],
                                                enc_out)
        else:
            logits, _, _ = lm_mod.forward_lm(params, cfg, batch, remat=False)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(model: ModelAPI):
    def serve_step(params, tokens, cache):
        logits, new_cache = model.decode_step(params, tokens, cache)
        next_tokens = jnp.argmax(logits[:, -1:, :], axis=-1)
        return next_tokens, logits, new_cache

    return serve_step
