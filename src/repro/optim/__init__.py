from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.sgd import sgd_init, sgd_update
