"""Plain SGD with momentum (used by small examples and tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params):
    return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}


def sgd_update(grads, state, params, *, lr: float = 1e-2, momentum: float = 0.9):
    new_mom = jax.tree.map(
        lambda m, g: momentum * m + g.astype(jnp.float32), state["mom"], grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, new_mom)
    return new_params, {"mom": new_mom}
