"""AdamW from scratch (no optax in this container).

``state_dtype`` controls the m/v moment dtype: float32 default; bfloat16 for
the 236B/314B dry-run configs so optimizer state fits the 16 GB/chip HBM
budget (documented trade-off in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    dt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), \
            m_new.astype(dt), v_new.astype(dt)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
