"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
on every other layer. [arXiv:2403.19887]

Adaptation note (DESIGN.md): Jamba's mamba layers are Mamba-1; we use the
repo's Mamba-2/SSD block (state=16 kept from the Jamba card)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    attn_period=8, attn_index=4,     # 1 attention layer per 8 (1:7)
    n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_chunk=256, conv_width=4,
    citation="arXiv:2403.19887",
)
