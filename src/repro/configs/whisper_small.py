"""whisper-small [audio] — enc-dec; conv/mel frontend STUBBED (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51865,
    is_encdec=True, encoder_layers=12, encoder_seq=1500,
    mlp_variant="gelu",
    citation="arXiv:2212.04356",
)
