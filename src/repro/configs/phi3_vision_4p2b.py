"""phi-3-vision-4.2b [vlm] — phi3-mini LM backbone + CLIP vision stub.
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064,
    n_patches=1024,                  # stubbed ViT/projector output tokens
    rope_theta=10_000.0, mlp_variant="swiglu",
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
)
