"""mamba2-1.3b [ssm] — SSD (state-space duality). [arXiv:2405.21060]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256, conv_width=4,
    citation="arXiv:2405.21060",
)
