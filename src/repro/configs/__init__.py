"""Assigned architecture configs (10, spanning 6 families) + paper datasets.

Every config cites its source model card / paper. ``get_arch(name)`` returns
the exact published configuration; ``--arch <id>`` in the launchers selects
one. ``reduced()`` on any config gives the CPU smoke-test variant.
"""
from repro.configs.phi3_vision_4p2b import CONFIG as phi3_vision_4p2b
from repro.configs.mamba2_1p3b import CONFIG as mamba2_1p3b
from repro.configs.llama32_1b import CONFIG as llama32_1b
from repro.configs.qwen3_4b import CONFIG as qwen3_4b
from repro.configs.jamba_v01_52b import CONFIG as jamba_v01_52b
from repro.configs.deepseek_v2_236b import CONFIG as deepseek_v2_236b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.whisper_small import CONFIG as whisper_small
from repro.configs.tinyllama_1p1b import CONFIG as tinyllama_1p1b
from repro.configs.grok1_314b import CONFIG as grok1_314b

ARCHS = {c.name: c for c in [
    phi3_vision_4p2b, mamba2_1p3b, llama32_1b, qwen3_4b, jamba_v01_52b,
    deepseek_v2_236b, granite_34b, whisper_small, tinyllama_1p1b, grok1_314b,
]}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
