"""granite-34b [dense] — Granite Code 34B: GPT-BigCode-style, MQA (kv=1),
88 layers, gelu MLP (d_ff = 4*d_model => ~34B params; a swiglu MLP at this
d_ff would be ~47B, contradicting the model name). [arXiv:2405.04324]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152, mlp_variant="gelu",
    citation="arXiv:2405.04324",
)
