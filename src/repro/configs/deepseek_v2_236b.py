"""deepseek-v2-236b [moe] — MLA kv_lora=512, 160 routed experts top-6 +
2 shared. [arXiv:2405.04434]

Adaptation note: the HF card keeps layer 0 dense; we keep all 60 layers MoE
(the assigned config lists uniform MoE)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=160, top_k=6, moe_d_ff=1536, n_shared_experts=2, moe_every=1,
    citation="arXiv:2405.04434",
)
