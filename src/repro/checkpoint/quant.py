"""Int8 symmetric per-column quantization for serving checkpoints.

A fitted kernel machine is (basis, beta): the basis is by far the bytes
(m × d floats), and the decide arms only ever *read* it through a gram
computation that a bf16 policy already rounds harder than int8 per-column
quantization does. Shipping the checkpoint at int8 + one fp32 scale per
column cuts the `.npz` ~4× with a dequantize-on-load that reconstructs
arrays within 1/254 of each column's dynamic range:

    scale_j = max_i |A[i, j]| / 127          (fp32, per column)
    Q[i, j] = round(A[i, j] / scale_j)       (int8, symmetric, no zero point)
    A~      = Q * scale                      (dequantized fp32)

Symmetric (no zero-point) because gram distances and margins are built
from *differences* and inner products — a bias term would leak into every
kernel evaluation, while symmetric rounding error stays bounded per column.
Columns are features for the basis (axis -1) and one-vs-rest classes for
beta, so each feature/class keeps its own dynamic range; an all-zero
column takes scale 1 to avoid 0/0 (its values quantize exactly anyway).

The quantized arrays ride the normal ``save_checkpoint`` `.npz` under
``<key>::q8`` / ``<key>::scale`` entries plus a metadata manifest, so the
atomic-commit/fault-injection machinery applies unchanged and pre-policy
loaders fail loudly (missing key) instead of silently reading int8 bits.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

#: Suffixes for quantized entries inside the checkpoint tree. "::" cannot
#: appear in state keys (flat dicts of python identifiers), so collisions
#: with real array names are impossible.
QSUF, SSUF = "::q8", "::scale"

#: State keys save(quantize=...) compresses. Everything else (classes,
#: rff phases, ...) is metadata-sized and stays exact.
QUANT_KEYS = ("basis", "beta")


def quantize_int8(arr) -> Tuple[np.ndarray, np.ndarray]:
    """(int8 codes, fp32 per-column scales) for a 1-D or 2-D float array.

    Columns are the last axis; a 1-D beta is treated as one column."""
    a = np.asarray(arr, np.float32)
    amax = np.max(np.abs(a), axis=tuple(range(max(a.ndim - 1, 1))))
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(a / scale), -127, 127).astype(np.int8)
    return q, np.atleast_1d(scale)


def dequantize_int8(q, scale) -> np.ndarray:
    """Reconstruct fp32 from :func:`quantize_int8` output."""
    return (np.asarray(q, np.float32) * np.asarray(scale, np.float32))


def quantize_state(state: Dict, scheme: str = "int8") -> Tuple[Dict, Dict]:
    """Quantize the heavy keys of a fitted state dict.

    Returns (tree, manifest): ``tree`` is what to hand ``save_checkpoint``
    (quantized keys replaced by their ``::q8``/``::scale`` pair, everything
    else passed through) and ``manifest`` maps each quantized key to its
    scheme — stored in the checkpoint metadata so load knows what to undo.
    """
    if scheme != "int8":
        raise ValueError(f"unknown quantization scheme {scheme!r}; "
                         f"supported: 'int8'")
    tree, manifest = {}, {}
    for k, v in state.items():
        a = np.asarray(v)
        if k in QUANT_KEYS and np.issubdtype(a.dtype, np.floating):
            q, s = quantize_int8(a)
            tree[k + QSUF] = q
            tree[k + SSUF] = s
            manifest[k] = scheme
        else:
            tree[k] = a
    return tree, manifest


def dequantize_state(arrays: Dict, manifest: Dict) -> Dict:
    """Invert :func:`quantize_state` on a loaded checkpoint's array dict."""
    out = {}
    for k, v in arrays.items():
        if k.endswith(QSUF):
            base = k[: -len(QSUF)]
            if manifest.get(base) != "int8":
                raise ValueError(
                    f"checkpoint carries quantized entry {k!r} but the "
                    f"metadata manifest does not declare {base!r}; refusing "
                    f"to guess the scheme")
            out[base] = dequantize_int8(v, arrays[base + SSUF])
        elif k.endswith(SSUF):
            continue
        else:
            out[k] = v
    missing = [k for k in manifest if k not in out]
    if missing:
        raise ValueError(f"metadata declares quantized keys {missing} "
                         f"absent from the checkpoint arrays")
    return out
