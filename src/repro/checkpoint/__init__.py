from repro.checkpoint.ckpt import save_checkpoint, load_checkpoint, load_arrays
from repro.checkpoint.async_writer import AsyncCheckpointWriter
from repro.checkpoint.training import (CheckpointConfig, ResumeState,
                                       TrainingCheckpointer, check_resume_config,
                                       list_steps, load_latest, load_step,
                                       prune_steps, step_path, steps_dir_for,
                                       write_step)
