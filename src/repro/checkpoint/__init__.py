from repro.checkpoint.ckpt import save_checkpoint, load_checkpoint, load_arrays
