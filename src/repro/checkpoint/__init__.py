from repro.checkpoint.ckpt import (IOWarningSink, load_arrays,
                                   load_checkpoint, save_checkpoint)
from repro.checkpoint.async_writer import AsyncCheckpointWriter
from repro.checkpoint.training import (COMMIT_RETRY, CheckpointConfig,
                                       ResumeState, TrainingCheckpointer,
                                       check_resume_config, list_steps,
                                       load_latest, load_step, prune_steps,
                                       step_path, steps_dir_for, write_step)
