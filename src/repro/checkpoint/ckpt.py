"""Minimal numpy-based pytree checkpointing (no orbax in this container).

Flattens the pytree with jax.tree_util key paths, stores leaves in a single
.npz plus a treedef manifest. Commits follow the classic crash-safe
protocol: write to a same-directory temp file, fsync the file, atomically
``os.replace`` it over the destination, then fsync the directory so the
rename itself is durable — a kill at any instant leaves either the
previous complete checkpoint or the next one, never a torn file. A real
deployment would swap in orbax behind the same two calls.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import faults

#: Signature of the optional I/O-warning sink threaded through the commit
#: path: ``sink(kind, path, exc)``. The default emits a RuntimeWarning so
#: swallowed cleanup failures are at least visible; TrainingCheckpointer
#: installs a counting sink so they land in FitResult.extras["ckpt"].
IOWarningSink = Callable[[str, str, BaseException], None]


def _warn_io(kind: str, path: str, exc: BaseException,
             sink: Optional[IOWarningSink]) -> None:
    warnings.warn(f"checkpoint I/O problem ({kind}) on {path}: {exc!r}",
                  RuntimeWarning, stacklevel=3)
    if sink is not None:
        sink(kind, path, exc)


def _key_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _fsync_dir(dirname: str) -> None:
    fd = os.open(dirname or ".", os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(path: str, tree: Any, metadata: dict | None = None,
                    *, fsync: bool = True,
                    on_io_warning: Optional[IOWarningSink] = None) -> int:
    """Atomically write ``tree`` (+ JSON-able ``metadata``) to ``path``.

    Returns the committed file size in bytes. ``fsync=False`` skips the
    durability syncs (still atomic against concurrent readers via the
    rename, but a machine crash may lose the write) — useful in tests.
    Secondary I/O failures that don't fail the commit itself (e.g. a tmp
    file that can't be unlinked after a failed write) are reported through
    ``on_io_warning`` instead of being silently swallowed.
    """
    if faults.fire("ckpt.commit", detail=path) == "torn":
        # Simulate a non-atomic writer dying mid-commit: garbage lands at
        # the destination (which load_latest must skip over) and the
        # caller sees a failed write.
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "wb") as fh:
            fh.write(b"PK\x03\x04 torn by fault plan")
        raise OSError(f"injected torn commit: {path}")
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_key_str(p): np.asarray(v) for p, v in leaves_with_paths}
    manifest = {"keys": list(arrays.keys()), "metadata": metadata or {}}
    dirname = os.path.dirname(path) or "."
    os.makedirs(dirname, exist_ok=True)
    # suffix keeps np.savez from appending ".npz" to a second file (which
    # used to leak the empty mkstemp file next to every checkpoint); the
    # prefix lets step scanners ignore in-flight temp files by name
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=".tmp-ckpt-",
                               suffix=".npz")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez(fh, __manifest__=json.dumps(manifest), **arrays)
            fh.flush()
            if fsync:
                os.fsync(fh.fileno())
        nbytes = os.path.getsize(tmp)
        os.replace(tmp, path)              # atomic commit
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError as cleanup_exc:
            # The commit failure propagates below; the leaked tmp file is a
            # secondary problem — surfaced, not swallowed, so disk slowly
            # filling with .tmp-ckpt-* orphans is observable.
            _warn_io("tmp-cleanup", tmp, cleanup_exc, on_io_warning)
        raise
    if fsync:
        _fsync_dir(dirname)
    return nbytes


def load_arrays(path: str) -> tuple[dict, dict]:
    """Load a checkpoint without a ``like`` tree: (flat key->array, metadata).

    Keys are the '/'-joined tree paths written by save_checkpoint; a flat
    dict state round-trips to its own keys. Used by KernelMachine.load,
    where the state structure is only known from the checkpoint itself.
    """
    with np.load(path, allow_pickle=False) as data:
        manifest = json.loads(data["__manifest__"].item())
        arrays = {k: np.asarray(data[k]) for k in manifest["keys"]}
    return arrays, manifest.get("metadata", {})


def load_checkpoint(path: str, like: Any) -> Any:
    with np.load(path, allow_pickle=False) as data:
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        leaves = [np.asarray(data[_key_str(p)]) for p, _ in leaves_with_paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)
