"""Minimal numpy-based pytree checkpointing (no orbax in this container).

Flattens the pytree with jax.tree_util key paths, stores leaves in a single
.npz plus a treedef manifest. Atomic via tmp-file rename. Good enough for
the example drivers; a real deployment would swap in orbax behind the same
two calls.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _key_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def save_checkpoint(path: str, tree: Any, metadata: dict | None = None):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_key_str(p): np.asarray(v) for p, v in leaves_with_paths}
    manifest = {"keys": list(arrays.keys()), "metadata": metadata or {}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    os.close(fd)
    np.savez(tmp, __manifest__=json.dumps(manifest), **arrays)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_arrays(path: str) -> tuple[dict, dict]:
    """Load a checkpoint without a ``like`` tree: (flat key->array, metadata).

    Keys are the '/'-joined tree paths written by save_checkpoint; a flat
    dict state round-trips to its own keys. Used by KernelMachine.load,
    where the state structure is only known from the checkpoint itself.
    """
    with np.load(path, allow_pickle=False) as data:
        manifest = json.loads(data["__manifest__"].item())
        arrays = {k: np.asarray(data[k]) for k in manifest["keys"]}
    return arrays, manifest.get("metadata", {})


def load_checkpoint(path: str, like: Any) -> Any:
    with np.load(path, allow_pickle=False) as data:
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        leaves = [np.asarray(data[_key_str(p)]) for p, _ in leaves_with_paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)
