"""In-training checkpoints: versioned step files, atomic commit, resume.

Layout — a ``ckpt-steps`` directory next to the final machine ``.npz``
(:func:`steps_dir_for`), holding one file per snapshot interval::

    model.npz                      # the final machine (KernelMachine.save)
    model.npz.ckpt-steps/
        step-00000003.npz          # TronSnapshot + basis [+ classes]
        step-00000006.npz
        ...

Each step file is written by :func:`write_step` through the
write-temp -> fsync -> rename commit protocol of
:func:`repro.checkpoint.ckpt.save_checkpoint`, so a SIGKILL at any
instant leaves the directory holding only complete checkpoints (stray
``.tmp-ckpt-*`` files are ignored by name). This is the paper's
fault-tolerant Map-Reduce premise made local: worker loss is the normal
case, and what makes recovery cheap is that the entire iterate state of
the distributed TRON solve is the O(m·K) replicated vector block every
node already holds — beta, trust radii, convergence masks — never the
O(n) partitioned data, which is re-read from its (immutable) shards.

Elastic restore falls out of the same fact: nothing in a step file is
sharded, so loading it under a different local device count just
re-slices the replicated state (the stream plan re-rounds its chunk size
to the new data-axis extent; in-memory plans re-shard C/W from X + the
stored basis).

:class:`TrainingCheckpointer` is the runtime object the fit path threads
down to the TRON drivers: it turns each
:class:`~repro.core.tron.TronSnapshot` callback into a step-file commit —
through an :class:`~repro.checkpoint.async_writer.AsyncCheckpointWriter`
by default, so commits overlap training compute — and carries the
identity arrays (basis, classes) and metadata every step file embeds.
"""
from __future__ import annotations

import dataclasses
import os
import re
import threading
import time
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.checkpoint.async_writer import AsyncCheckpointWriter
from repro.checkpoint.ckpt import (IOWarningSink, _warn_io, load_arrays,
                                   save_checkpoint)
from repro.core.tron import TronSnapshot
from repro.util.retry import RetryPolicy, call_with_retry

#: Default transient-I/O policy for step-file commits: a flaky disk gets
#: three chances per snapshot before the failure lands in ``errors``.
COMMIT_RETRY = RetryPolicy(max_attempts=3, backoff_s=0.05, max_backoff_s=1.0)

TRAIN_CKPT_FORMAT = "train-ckpt-1"
_STEP_RE = re.compile(r"^step-(\d{8})\.npz$")
_SNAP_KEYS = ("beta", "delta", "gnorm0", "active", "it", "n_fg", "n_hd")


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Per-fit checkpointing knobs for ``KernelMachine.fit(checkpoint=...)``.

    ``dir`` holds the versioned step files (use :func:`steps_dir_for` to
    derive it from a final ``.npz`` path). ``interval`` is outer TRON
    iterations between snapshots. ``keep`` bounds retained step files
    (oldest pruned after each commit; 0 keeps all). ``background`` routes
    commits through the async writer (drop-oldest, overlapping compute);
    False commits synchronously on the training thread. ``resume`` makes
    ``fit`` restore from the latest valid step in ``dir`` before training
    (raising ``FileNotFoundError`` if there is none). ``fsync`` controls
    the durability syncs of each commit (atomicity is kept either way).

    ``write`` gates the commits themselves: multi-controller runs set it
    on process 0 only — every process holds the identical replicated
    snapshot, so P writers would race on the same step files for no
    information gain — while ``resume`` stays usable on every process
    (all hosts restore the same state from the shared directory).
    """
    dir: str
    interval: int = 10
    keep: int = 3
    background: bool = True
    resume: bool = False
    fsync: bool = True
    write: bool = True

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"checkpoint interval must be >= 1, "
                             f"got {self.interval}")


class ResumeState:
    """A loaded step checkpoint: snapshot + identity arrays + metadata."""

    def __init__(self, step: int, snapshot: TronSnapshot, arrays: dict,
                 meta: dict, path: str):
        self.step = step
        self.snapshot = snapshot
        self.arrays = arrays       # non-snapshot arrays: basis [, classes]
        self.meta = meta
        self.path = path


def steps_dir_for(save_path: str) -> str:
    """The ``ckpt-steps`` directory next to a final ``.npz`` path."""
    return str(save_path) + ".ckpt-steps"


def step_path(dir: str, step: int) -> str:
    return os.path.join(dir, f"step-{int(step):08d}.npz")


def list_steps(dir: str) -> List[Tuple[int, str]]:
    """Committed (step, path) pairs, ascending. Temp files are ignored by
    name — only fully renamed ``step-*.npz`` files count as committed."""
    try:
        names = os.listdir(dir)
    except FileNotFoundError:
        return []
    out = []
    for name in names:
        mm = _STEP_RE.match(name)
        if mm:
            out.append((int(mm.group(1)), os.path.join(dir, name)))
    return sorted(out)


def write_step(dir: str, step: int, tree: dict, metadata: dict, *,
               fsync: bool = True, keep: int = 0,
               on_io_warning: Optional[IOWarningSink] = None) -> int:
    """Commit one step file atomically; prune to the newest ``keep``.

    Returns bytes written. ``metadata`` gains ``format``/``step``/
    ``wall_time`` stamps."""
    os.makedirs(dir, exist_ok=True)
    md = dict(metadata)
    md.setdefault("format", TRAIN_CKPT_FORMAT)
    md["step"] = int(step)
    md["wall_time"] = time.time()
    nbytes = save_checkpoint(step_path(dir, step), tree, metadata=md,
                             fsync=fsync, on_io_warning=on_io_warning)
    if keep > 0:
        prune_steps(dir, keep, on_io_warning=on_io_warning)
    return nbytes


def prune_steps(dir: str, keep: int, *,
                on_io_warning: Optional[IOWarningSink] = None) -> int:
    """Unlink all but the newest ``keep`` committed step files.

    A step that can't be unlinked is not fatal (the commit already
    succeeded; retention is best-effort) but it is reported through
    ``on_io_warning`` — a retention policy that silently stops pruning
    fills the disk invisibly."""
    steps = list_steps(dir)
    removed = 0
    for _, path in steps[:max(0, len(steps) - keep)]:
        try:
            os.unlink(path)
            removed += 1
        except OSError as exc:
            _warn_io("prune-unlink", path, exc, on_io_warning)
    return removed


def load_step(path: str) -> ResumeState:
    """Load one step file into a :class:`ResumeState`."""
    arrays, meta = load_arrays(path)
    if meta.get("format") != TRAIN_CKPT_FORMAT:
        raise ValueError(f"{path}: not an in-training checkpoint "
                         f"(format={meta.get('format')!r})")
    snap = TronSnapshot.from_arrays(arrays)
    extra = {k: v for k, v in arrays.items() if k not in _SNAP_KEYS}
    return ResumeState(step=int(meta.get("step", snap.it)), snapshot=snap,
                       arrays=extra, meta=meta, path=path)


def load_latest(dir: str) -> ResumeState:
    """The newest loadable step in ``dir``.

    The commit protocol guarantees committed files are complete, so the
    newest one loads; walking backwards over older steps is pure
    belt-and-braces against external corruption. Raises
    ``FileNotFoundError`` when no usable step exists."""
    steps = list_steps(dir)
    last_err: Optional[BaseException] = None
    for step, path in reversed(steps):
        try:
            return load_step(path)
        except Exception as e:  # torn/foreign files fail in many shapes:
            last_err = e        # BadZipFile, OSError, ValueError, KeyError...
    raise FileNotFoundError(
        f"no resumable checkpoint under {dir!r}"
        + (f" (newest failed to load: {last_err})" if last_err else ""))


def check_resume_config(config, meta: dict) -> None:
    """Refuse to resume under a different objective/solver.

    Device count, mesh shape and chunk size may change freely (elastic
    restore); the fields pinned here change the optimization problem or
    its trajectory, so silently continuing would produce a model that is
    neither the old run's nor a fresh run's."""
    stored = meta.get("config", {})
    pins = ("solver", "plan", "loss", "lam", "kernel", "m")
    current = config.to_dict()
    diffs = [f"{k}: checkpoint={stored.get(k)!r} != current={current.get(k)!r}"
             for k in pins if k in stored and stored.get(k) != current.get(k)]
    if diffs:
        raise ValueError(
            "checkpoint was written by an incompatible config; refusing to "
            "resume (" + "; ".join(diffs) + ")")


class TrainingCheckpointer:
    """Runtime bridge from TRON snapshot callbacks to step-file commits.

    Built per fit by the solver layer with the run's identity ``arrays``
    (basis [, classes]) and ``meta`` (config dict, solver, plan); the plan
    layer may :meth:`attach_feeder` the stream chunk feeder so every step
    file also records the feeder cursor/accounting state — and so a
    resumed fit restores the feeder's counters for continuity.
    """

    def __init__(self, cfg: CheckpointConfig, *, meta: dict,
                 arrays: Optional[dict] = None,
                 resume_meta: Optional[dict] = None):
        self.cfg = cfg
        self.meta = dict(meta)
        self.arrays = {k: np.asarray(v) for k, v in (arrays or {}).items()}
        self.resume_meta = resume_meta
        self.feeder: Any = None
        self._sync_written = 0
        self._sync_bytes = 0
        self._sync_seconds = 0.0
        self._sync_retries = 0
        self._last_step: Optional[int] = None
        self._io_lock = threading.Lock()
        self._io_warnings = 0
        self._writer: Optional[AsyncCheckpointWriter] = None
        if cfg.background and cfg.write:
            self._writer = AsyncCheckpointWriter(self._commit,
                                                 retry=COMMIT_RETRY)

    @property
    def interval(self) -> int:
        return self.cfg.interval

    # ------------------------------------------------------------- plumbing
    def attach_feeder(self, feeder) -> None:
        """Record the stream feeder for per-step cursor export; on resume,
        restore its cursor/accounting state from the checkpoint."""
        self.feeder = feeder
        if self.resume_meta is not None and feeder is not None:
            state = self.resume_meta.get("feeder")
            if state:
                feeder.restore_state(state)

    def _commit(self, step: int, tree: dict, metadata: dict) -> int:
        return write_step(self.cfg.dir, step, tree, metadata,
                          fsync=self.cfg.fsync, keep=self.cfg.keep,
                          on_io_warning=self._note_io_warning)

    def _note_io_warning(self, kind: str, path: str,
                         exc: BaseException) -> None:
        # Sink for swallowed-but-reported I/O problems (tmp cleanup, prune
        # unlink) — counted so they surface in FitResult.extras["ckpt"].
        with self._io_lock:
            self._io_warnings += 1

    def _note_sync_retry(self, attempt: int, exc: BaseException,
                         delay_s: float) -> None:
        self._sync_retries += 1

    def on_snapshot(self, snap: TronSnapshot) -> None:
        """The TRON drivers' callback: package and commit one snapshot."""
        if not self.cfg.write:        # non-primary multi-controller process
            self._last_step = snap.it
            return
        tree = {**snap.to_arrays(), **self.arrays}
        md = dict(self.meta)
        if self.feeder is not None:
            md["feeder"] = self.feeder.state()
        if self._writer is not None:
            self._writer.submit(snap.it, tree, md)
        else:
            t0 = time.perf_counter()
            nbytes = call_with_retry(COMMIT_RETRY, self._commit,
                                     snap.it, tree, md,
                                     label=f"ckpt-sync-step-{snap.it}",
                                     on_retry=self._note_sync_retry)
            self._sync_seconds += time.perf_counter() - t0
            self._sync_written += 1
            self._sync_bytes += nbytes
        self._last_step = snap.it

    def close(self) -> None:
        if self._writer is not None:
            self._writer.close(flush=True)

    def stats(self) -> dict:
        """Checkpoint I/O accounting for ``FitResult.extras['ckpt']``."""
        base = {"dir": self.cfg.dir, "interval": self.cfg.interval,
                "background": self.cfg.background,
                "resumed_step": None if self.resume_meta is None
                else int(self.resume_meta.get("step", -1))}
        if self._writer is not None:
            base.update(self._writer.stats())
        else:
            base.update(snapshots_submitted=self._sync_written,
                        snapshots_written=self._sync_written,
                        snapshots_dropped=0,
                        bytes_written=self._sync_bytes,
                        write_seconds=self._sync_seconds,
                        last_step=self._last_step, errors=0,
                        write_retries=self._sync_retries)
        with self._io_lock:
            base["io_warnings"] = self._io_warnings
        return base
