"""Background checkpoint writer: snapshots off the training thread.

The training loop's only job at a checkpoint interval is to hand a
host-materialized snapshot to :meth:`AsyncCheckpointWriter.submit` — a
dict copy plus one notify, microseconds — while a daemon thread runs the
actual (atomic, fsync'd) file commit concurrently with the next training
iterations. The design is double-buffered with a drop-oldest policy: at
most one snapshot is being written and one is pending. If training
produces snapshots faster than the disk commits them, submitting a new
one *replaces* the pending one (the stale intermediate state nobody would
resume from is dropped, counted in ``snapshots_dropped``) instead of
blocking the training thread or growing an unbounded queue. The newest
submitted snapshot is therefore always either committed or about to be.

Accounting mirrors the stream feeder's ``h2d_bytes`` idiom: the writer
totals ``bytes_written`` and ``write_seconds`` (wall time inside the
commit calls) so callers can surface checkpoint I/O cost next to the
transfer counters in ``FitResult`` — and benchmarks can prove the writes
overlapped compute instead of extending the step time.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.util.retry import RetryPolicy, call_with_retry


class AsyncCheckpointWriter:
    """Daemon-thread checkpoint writer (double-buffered, drop-oldest).

    ``write_fn(step, tree, metadata) -> bytes_written`` performs one
    commit — typically :func:`repro.checkpoint.training.write_step` — and
    must be self-contained (atomic rename, fsync); the writer adds no
    durability of its own. Snapshot trees must already be host numpy
    arrays owned by the caller (device arrays would drag a d2h transfer
    onto this thread, which is fine, but mutation by the trainer would
    race — :class:`~repro.core.tron.TronSnapshot` arrays are fresh copies).

    Transient I/O failures are retried per ``retry`` (an
    :class:`~repro.util.retry.RetryPolicy`; pass
    ``RetryPolicy(max_attempts=1)`` to disable) with each extra attempt
    counted in ``write_retries``. Errors that survive the retry cap are
    recorded (``errors``, ``last_error``) and the writer keeps accepting
    snapshots: a flaky disk must not kill an hours-long training run.
    ``close()`` drains the pending slot (unless ``flush=False``) and joins
    the thread.
    """

    def __init__(self, write_fn: Callable[[int, dict, dict], int], *,
                 name: str = "ckpt-writer",
                 retry: Optional[RetryPolicy] = None):
        self._write_fn = write_fn
        self._retry = retry if retry is not None else RetryPolicy(
            max_attempts=3, backoff_s=0.05, max_backoff_s=1.0)
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._pending: Optional[tuple] = None   # newest (step, tree, meta)
        self._writing = False
        self._closed = False
        self.snapshots_submitted = 0
        self.snapshots_written = 0
        self.snapshots_dropped = 0
        self.bytes_written = 0
        self.write_seconds = 0.0
        self.last_step: Optional[int] = None
        self.errors = 0
        self.write_retries = 0
        self.last_error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ producer
    def submit(self, step: int, tree: dict, metadata: dict) -> None:
        """Hand one snapshot to the writer; never blocks on I/O.

        If a snapshot is already waiting (the writer is busy with an older
        one), the waiting snapshot is dropped — newest wins."""
        with self._lock:
            if self._closed:
                raise RuntimeError("AsyncCheckpointWriter is closed")
            self.snapshots_submitted += 1
            if self._pending is not None:
                self.snapshots_dropped += 1
            self._pending = (int(step), tree, metadata)
            self._work.notify()

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until the pending slot is empty and no write is running."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._pending is not None or self._writing:
                left = None if deadline is None \
                    else max(0.0, deadline - time.monotonic())
                if deadline is not None and left == 0.0:
                    return False
                self._idle.wait(left)
        return True

    def close(self, *, flush: bool = True,
              timeout: Optional[float] = None) -> None:
        if flush:
            self.flush(timeout)
        with self._lock:
            if not flush:
                if self._pending is not None:
                    self.snapshots_dropped += 1
                self._pending = None
            self._closed = True
            self._work.notify()
        self._thread.join(timeout)

    def stats(self) -> dict:
        with self._lock:
            return {
                "snapshots_submitted": self.snapshots_submitted,
                "snapshots_written": self.snapshots_written,
                "snapshots_dropped": self.snapshots_dropped,
                "bytes_written": self.bytes_written,
                "write_seconds": self.write_seconds,
                "last_step": self.last_step,
                "errors": self.errors,
                "write_retries": self.write_retries,
            }

    def _count_retry(self, attempt: int, exc: BaseException,
                     delay_s: float) -> None:
        with self._lock:
            self.write_retries += 1

    # ------------------------------------------------------------ consumer
    def _run(self) -> None:
        while True:
            with self._lock:
                while self._pending is None and not self._closed:
                    self._work.wait()
                if self._pending is None:       # closed and drained
                    self._idle.notify_all()
                    return
                step, tree, metadata = self._pending
                self._pending = None
                self._writing = True
            nbytes, err = 0, None
            t0 = time.perf_counter()
            try:
                nbytes = int(call_with_retry(
                    self._retry, self._write_fn, step, tree, metadata,
                    label=f"ckpt-step-{step}",
                    on_retry=self._count_retry) or 0)
            except BaseException as e:          # keep the run alive
                err = e
            dt = time.perf_counter() - t0
            with self._lock:
                self._writing = False
                self.write_seconds += dt
                if err is None:
                    self.snapshots_written += 1
                    self.bytes_written += nbytes
                    self.last_step = step
                else:
                    self.errors += 1
                    self.last_error = err
                self._idle.notify_all()
