"""The one result type every solver returns.

Subsumes the old ``TronResult`` (tron/linearized/rff paths) and
``StageResult`` (stage-wise growth: one FitResult per ``partial_fit`` call,
collected on ``KernelMachine.history_``). Counters that a solver does not
track (e.g. ppacksvm has no gradient norm) are NaN/0 rather than absent, so
downstream tables can treat results uniformly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

from repro.core.tron import TronResult


@dataclasses.dataclass(frozen=True)
class FitResult:
    solver: str
    plan: str
    m: int                    # parameter count (basis size / features / support)
    f: float                  # final objective (NaN when the solver has none)
    gnorm: float
    n_iter: int               # outer iterations / SGD communication rounds
    n_fg: int
    n_hd: int
    converged: bool
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_tron(cls, res: TronResult, *, solver: str, plan: str, m: int,
                  extras: Optional[Dict[str, Any]] = None) -> "FitResult":
        """Column-batched (one-vs-rest) TronResults carry (K,) per-column
        f/gnorm/converged; the scalar summary here is the separable total
        objective (sum), the worst gradient norm, and all-columns
        convergence. The raw per-column result stays in ``extras['tron']``.
        """
        import numpy as np
        ex = {"tron": res}
        if extras:
            ex.update(extras)
        f = np.asarray(res.f)
        gnorm = np.asarray(res.gnorm)
        conv = np.asarray(res.converged)
        return cls(solver=solver, plan=plan, m=m,
                   f=float(f.sum()), gnorm=float(gnorm.max()),
                   n_iter=int(res.n_iter), n_fg=int(res.n_fg),
                   n_hd=int(res.n_hd), converged=bool(conv.all()),
                   extras=ex)

    @property
    def tron(self) -> Optional[TronResult]:
        return self.extras.get("tron")

    def __repr__(self):  # keep array-laden extras out of logs
        f = "nan" if math.isnan(self.f) else f"{self.f:.6g}"
        return (f"FitResult(solver={self.solver!r}, plan={self.plan!r}, "
                f"m={self.m}, f={f}, n_iter={self.n_iter}, "
                f"converged={self.converged})")
