"""Plan-aware inference engine: the paper's prediction map under every plan.

Training and prediction are the same distributed primitive. The margin
o(x) = k(x, basis)·β is one row of C·β — exactly the row-partitioned
contraction every f/g/Hd evaluation performs — so each execution plan's
``decide`` arm (registered alongside its ``fit`` arm in
:mod:`repro.api.plans`) reuses the plan's training machinery:

* ``local``      — the dense reference: materialize the (n_test, m) test
                   gram on one device, one matmul. Fastest for batches that
                   fit; also the numerical reference every other decide arm
                   is tested against.
* ``shard_map`` / ``auto`` / ``otf`` / ``otf_shard``
                 — rows of the query batch sharded over the mesh's data
                   axes, margins evaluated through the fused/chunked kmvp
                   dispatchers (:func:`repro.kernels.ops.otf_kmvp_fwd`):
                   no (n/p, m) test-gram block ever exists on any device —
                   the same memory contract the training closures keep,
                   asserted by ``repro.core.introspect`` in tests. Margins
                   are row-partitioned like C·β, so prediction needs NO
                   AllReduce — β is broadcast (the paper's step 2) and each
                   device keeps the margins of its own rows. Multiclass
                   (m, K) β blocks ride the multi-RHS kernels: one gram
                   recomputation serves all K columns per batch.
* ``stream``     — out-of-core scoring: the query set lives in a
                   :class:`repro.data.chunks.ChunkSource` (in-memory
                   arrays, or a directory of memory-mapped .npy shards
                   larger than RAM) and margins are produced chunk by
                   chunk through the same ``_ChunkFeeder`` pipeline the
                   training plan uses (background-thread prefetch,
                   host-pad caching). No intermediate reaches
                   chunk_rows × m elements.

Solvers contribute only a :class:`DecisionSpec` — which feature map,
basis points, and weights realize o(x). Nyström solvers (tron,
linearized, ppacksvm) use the identity map with their stored basis; rff
maps x through φ(·) and contracts against an identity basis under a
linear kernel — the same exact reduction its training path uses, so every
plan applies unchanged.
"""
from __future__ import annotations

import math
from pathlib import Path
from typing import Any, Callable, Iterator, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import default_mesh, shard_map
from repro.core.nystrom import KernelSpec, gram
from repro.data.chunks import (ArrayChunkSource, ChunkSource,
                               as_chunk_source)


class DecisionSpec(NamedTuple):
    """How a fitted state realizes the prediction map o(x).

    ``map_x`` is a jit-traceable feature map applied to query rows before
    the kernel contraction (identity for Nyström states, φ(·) for rff);
    ``basis``/``beta`` are the points and weights of o(x) = k(map_x(x),
    basis)·β; ``kernel``/``backend`` parameterize the gram/kmvp calls.
    β may be (m,) or an (m, K) one-vs-rest block — every decide arm is
    rank-generic over the trailing class axis.

    ``identity_basis`` marks the rff-style reduction where the linear
    kernel against an identity basis makes o(x) = map_x(x)·β exactly:
    decide arms then contract the features directly — O(n_q·m·K) instead
    of the O(n_q·m²) identity-gram detour — and never read ``basis``
    (it may be None).

    ``policy`` names the dtype policy (``repro.kernels.policy.POLICIES``)
    every decide arm computes under — solvers populate it from
    ``config.dtype_policy``, so a machine fit (or loaded) with a cheap
    policy serves through it too.
    """
    map_x: Callable
    basis: Any
    beta: Any
    kernel: KernelSpec
    backend: str
    identity_basis: bool = False
    policy: str = "fp32"


def _is_chunked(X) -> bool:
    """Query sets that must route through the stream decide arm."""
    return isinstance(X, (ChunkSource, str, Path))


def as_inference_source(X, config) -> ChunkSource:
    """Coerce a query set into a ChunkSource for chunked scoring.

    Delegates to :func:`repro.data.chunks.as_chunk_source` (same rechunk /
    shard-directory semantics as training) except that plain arrays wrap
    label-less: inference never reads y, so requiring it would be noise.
    """
    if isinstance(X, (ChunkSource, str, Path)):
        return as_chunk_source(X, None, chunk_rows=config.stream.chunk_rows,
                               mmap=config.stream.mmap)
    return ArrayChunkSource(np.asarray(X), None, config.stream.chunk_rows)


def _basis_operand(spec: DecisionSpec):
    """Array to ship as the basis argument of a margin body. Identity-basis
    specs never read it, so a scalar placeholder keeps the body signature
    uniform without materializing an (m, m) eye."""
    if spec.identity_basis:
        return jnp.zeros((), jnp.float32)
    return jnp.asarray(spec.basis)


# ------------------------------------------------------------------- local
def decide_local(config, mesh, spec: DecisionSpec, X, *,
                 backend: Optional[str] = None):
    """Dense single-device reference: materialize the test gram, contract
    (identity-basis specs contract their features directly)."""
    del mesh
    Xe = spec.map_x(jnp.asarray(X))
    if spec.identity_basis:
        return Xe @ spec.beta
    C = gram(Xe, spec.basis, spec.kernel,
             backend if backend is not None else spec.backend,
             policy=spec.policy if spec.policy != "fp32" else None)
    return C @ spec.beta


# ------------------------------------------------------- fused (on-mesh)
def _resolve_mesh(config, mesh):
    if mesh is not None:
        return mesh
    return default_mesh(config.data_axes, None)


def _data_extent(config, mesh) -> int:
    return math.prod(mesh.shape[a] for a in config.data_axes)


def make_margin_body(config, mesh, spec: DecisionSpec,
                     backend: Optional[str] = None) -> Callable:
    """shard_map body evaluating row-sharded margins through the fused
    kmvp dispatchers — the decide-side sibling of
    ``DistributedNystrom.make_fused_closures``. Rows-only partition;
    margins stay with their rows (no collective). Exposed unjitted so
    tests can trace it and prove the no-(n/p, m) memory contract."""
    from repro.kernels.ops import otf_kmvp_fwd
    da = tuple(config.data_axes)
    kw = dict(kind=spec.kernel.kind, sigma=spec.kernel.sigma,
              backend=backend if backend is not None else spec.backend,
              block_rows=config.otf_block_rows, policy=spec.policy)
    x_spec = P(da, None)
    o_spec = x_spec if jnp.ndim(spec.beta) == 2 else P(da)
    map_x = spec.map_x

    if spec.identity_basis:
        def o_local(Xl, basis, beta):
            del basis                      # o = φ(x)·β exactly, no gram
            return map_x(Xl) @ beta
    else:
        def o_local(Xl, basis, beta):
            return otf_kmvp_fwd(map_x(Xl), basis, beta, **kw)

    return shard_map(o_local, mesh=mesh, check_vma=False,
                     in_specs=(x_spec, P(), P()), out_specs=o_spec)


def decide_fused(config, mesh, spec: DecisionSpec, X, *,
                 backend: Optional[str] = None):
    """Mesh-sharded margins, C never materialized: query rows over the
    data axes, basis/β replicated, per-shard fused kmvp. Any n — ragged
    batches are zero-row padded (padded margins are sliced off, so the
    garbage rows never escape)."""
    mesh = _resolve_mesh(config, mesh)
    dp = _data_extent(config, mesh)
    Xe = jnp.asarray(X)
    n = Xe.shape[0]
    npad = -(-n // dp) * dp
    if npad != n:
        Xe = jnp.pad(Xe, ((0, npad - n), (0, 0)))
    body = make_margin_body(config, mesh, spec, backend)
    with mesh:
        o = body(Xe, _basis_operand(spec), jnp.asarray(spec.beta))
    return o[:n]


# ------------------------------------------------------ stream (out of core)
class StreamDecider(NamedTuple):
    """Chunked margin evaluation over a :class:`ChunkSource`.

    ``o_chunk`` is the jitted per-chunk shard_map body — tests trace it
    to prove no intermediate reaches chunk_rows × m elements. ``margins``
    is a zero-arg callable returning the per-chunk margin iterator
    (np arrays trimmed to true rows). ``feeder`` exposes ``h2d_bytes``
    for transfer accounting."""
    o_chunk: Callable
    chunk_rows: int
    n_chunks: int
    feeder: Any
    source: ChunkSource
    margins: Callable


def make_stream_decider(config, mesh, spec: DecisionSpec,
                        source: ChunkSource, *,
                        backend: Optional[str] = None,
                        cache_chunks: int = 0,
                        prefetch: Optional[int] = None) -> StreamDecider:
    """Build the chunk-by-chunk margin pipeline over ``source``.

    Chunks ride the same :class:`repro.core.distributed._ChunkFeeder`
    the training plan uses — X-only transfers (``need_y=False``),
    background-thread prefetch ``prefetch`` deep (default: the machine's
    ``StreamConfig.prefetch``). The device cache defaults to 0: scoring
    is one pass, so resident chunks would only burn HBM."""
    from repro.core.distributed import _ChunkFeeder
    mesh = _resolve_mesh(config, mesh)
    dp = _data_extent(config, mesh)
    cr = -(-source.chunk_rows // dp) * dp
    if cr != source.chunk_rows:
        source = source.with_chunk_rows(cr)
    body = jax.jit(make_margin_body(config, mesh, spec, backend))
    da = tuple(config.data_axes)
    from repro.kernels.policy import get_policy
    pol = get_policy(spec.policy)
    # Chunks transfer at the policy's compute dtype: under bf16 the feeder
    # halves H2D bytes (and the on-device chunk) before the kernels even run.
    x_dtype = (None if pol.compute == "float32"
               else pol.np_compute_dtype())
    feeder = _ChunkFeeder(
        source, cr, np.dtype(source.dtype), x_dtype=x_dtype,
        x_sh=NamedSharding(mesh, P(da, None)),
        y_sh=NamedSharding(mesh, P(da)),
        r_sh=NamedSharding(mesh, P(da)),
        cache_chunks=cache_chunks,
        prefetch=config.stream.prefetch if prefetch is None else prefetch)
    basis_dev = _basis_operand(spec)
    beta_dev = jnp.asarray(spec.beta)
    n, n_chunks = source.n, source.n_chunks

    def margins() -> Iterator[np.ndarray]:
        with mesh:
            for i, Xd in enumerate(feeder.chunks(need_y=False)):
                rows = min(n - i * cr, cr)
                yield np.asarray(body(Xd, basis_dev, beta_dev))[:rows]

    return StreamDecider(o_chunk=body, chunk_rows=cr, n_chunks=n_chunks,
                         feeder=feeder, source=source, margins=margins)


def decide_stream(config, mesh, spec: DecisionSpec, X, *,
                  backend: Optional[str] = None):
    """Out-of-core margins: accumulate the (n[, K]) output chunk by chunk
    on the host. The only full-size array is the margin vector itself
    (O(n·K) floats — a factor d/K smaller than the X the plan refuses to
    hold); every device intermediate stays under chunk_rows × m. Returns
    a host np.ndarray. For score/predict over sets where even the margin
    vector binds, use the ``KernelMachine.decision_chunks`` /
    ``predict_chunks`` iterators instead."""
    source = as_inference_source(X, config)
    sd = make_stream_decider(config, mesh, spec, source, backend=backend)
    out = None
    at = 0
    for oc in sd.margins():
        if out is None:
            out = np.empty((source.n,) + oc.shape[1:], oc.dtype)
        out[at:at + oc.shape[0]] = oc
        at += oc.shape[0]
    return out


# ------------------------------------------------------- bucketed serving
# Never dispatch a single-row bucket: XLA lowers a (1, d) contraction to a
# different dot/gemm strategy than multi-row shapes, and the one-ULP drift
# that causes would break the continuous-batching determinism contract
# (a row served alone must be bitwise the row served inside a coalesced
# block). Flooring at 2 keeps every bucket in the same gemm family for the
# cost of one padded row on 1-row requests.
MIN_BUCKET = 2


def bucket_rows(n: int, max_batch: int) -> int:
    """Power-of-two batch bucket for ``n`` query rows (floor
    ``MIN_BUCKET``), capped at ``max_batch``. One jit executable per bucket
    instead of one per request size — the standard shape-bucketing trick
    for latency-stable serving."""
    b = MIN_BUCKET
    while b < n:
        b <<= 1
    return min(b, max_batch)


def scatter_rows(margins, sizes) -> list:
    """Split a coalesced margin block back into per-request row slices.

    ``margins`` is the (sum(sizes)[, K]) output of one decide dispatch over
    rows concatenated from many requests; the returned list has one
    (sizes[i][, K]) view per request, in submission order. The inverse of
    the ``np.concatenate`` the batcher performs — together they are the
    continuous-batching contract: one dispatch, many callers, no row ever
    crossing a request boundary."""
    out, at = [], 0
    for s in sizes:
        out.append(margins[at:at + s])
        at += s
    return out


class BucketedDecider:
    """Bucketed jit-executable cache over one plan's decide callable.

    The batch-composable serving primitive: ``__call__`` pads a request (or
    a coalesced multi-request block) up to its power-of-two bucket, runs
    the cached executable for that bucket, and trims the padding rows off —
    so the jit cache holds at most log2(max_batch)+1 executables no matter
    how many distinct batch sizes traffic produces. Oversize inputs split
    into max_batch-row dispatches. Per-row margins are batch-composition
    independent (rows reduce over m only), so a row served inside any
    bucket equals the same row served alone — the property continuous
    batching relies on and tests assert bitwise.
    """

    def __init__(self, decide: Callable, max_batch: int = 256):
        self.max_batch = int(max_batch)
        self._decide = decide
        self._compiled = {}

    def _compiled_for(self, b: int):
        if b not in self._compiled:
            self._compiled[b] = jax.jit(self._decide)
        return self._compiled[b]

    def __call__(self, X) -> np.ndarray:
        """Margins for ``X`` as a host array, synchronously. Padding and
        trimming happen host-side in numpy — only the bucket-shaped
        executable itself touches XLA, so no request size ever triggers an
        eager pad/slice compile (those one-off ~100 ms stalls would
        dominate tail latency)."""
        X = np.asarray(X)
        n = X.shape[0]
        if n > self.max_batch:          # split oversize (coalesced) blocks
            parts = [self(X[i:i + self.max_batch])
                     for i in range(0, n, self.max_batch)]
            return np.concatenate(parts)
        b = bucket_rows(n, self.max_batch)
        if b != n:
            Xp = np.zeros((b,) + X.shape[1:], X.dtype)
            Xp[:n] = X
        else:
            Xp = X
        return np.asarray(self._compiled_for(b)(Xp))[:n]

    def padded_rows(self, n: int) -> int:
        """Device rows one ``__call__(n rows)`` dispatches, padding and
        oversize splits included — the denominator of batch occupancy."""
        full, rem = divmod(n, self.max_batch)
        total = full * self.max_batch
        if rem:
            total += bucket_rows(rem, self.max_batch)
        return total

    def warmup(self, d: int, dtype=np.float32) -> int:
        """Precompile every bucket (1, 2, 4, ..., max_batch) for feature
        dimension ``d`` so no live request ever pays a compile. Returns the
        executable count. Reachable buckets are the powers of two from
        ``MIN_BUCKET`` below ``max_batch`` plus ``max_batch`` itself (the
        cap bucket, which need not be a power of two)."""
        b = MIN_BUCKET
        while b < self.max_batch:
            self(np.zeros((b, d), dtype))
            b <<= 1
        self(np.zeros((self.max_batch, d), dtype))
        return self.n_executables

    @property
    def n_executables(self) -> int:
        return len(self._compiled)


def iter_label_chunks(source: ChunkSource, chunk_rows: int) -> Iterator:
    """Re-chunk ``source``'s label stream to exactly ``chunk_rows`` rows
    per block (last block ragged), aligned with a same-sized
    :class:`StreamDecider`. Uses :meth:`ChunkSource.iter_y`, so .npy
    shard dirs read only their y files — no X bytes touched."""
    buf: Optional[np.ndarray] = None
    for seg in source.iter_y():
        seg = np.asarray(seg)
        buf = seg if buf is None or not buf.size else np.concatenate(
            [buf, seg])
        while buf.shape[0] >= chunk_rows:
            yield buf[:chunk_rows]
            buf = buf[chunk_rows:]
    if buf is not None and buf.shape[0]:
        yield buf
