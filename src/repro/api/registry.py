"""Solver and execution-plan registries.

A *solver* owns the optimization strategy (objective + update rule); an
*execution plan* owns where the math runs (one device, explicit shard_map
collectives, XLA-auto SPMD, materialization-free on-the-fly gram, or
out-of-core chunk streaming). Any
solver composes with any plan it declares mathematically valid — the
composition is checked here, once, with an error message that lists the
legal choices instead of failing deep inside a trace.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Optional

SolverFn = Callable  # (config, X, y, basis, beta0, *, mesh, plan, key, CW) -> (state, FitResult)
DecisionFn = Callable  # (config, state, X) -> outputs
PlanFn = Callable    # (config, mesh, X, y, basis, beta0, CW=None) -> TronResult


@dataclasses.dataclass(frozen=True)
class SolverEntry:
    name: str
    fit: SolverFn
    decision: DecisionFn
    plans: FrozenSet[str]      # execution plans this solver is valid under
    grows: bool = False        # supports partial_fit basis growth
    needs_basis: bool = False  # fit consumes a point basis (else ignores it)


_SOLVERS: Dict[str, SolverEntry] = {}
_PLANS: Dict[str, PlanFn] = {}


def register_solver(name: str, *, plans, grows: bool = False,
                    needs_basis: bool = False,
                    decision: Optional[DecisionFn] = None):
    def deco(fn: SolverFn):
        if name in _SOLVERS:
            raise ValueError(f"solver {name!r} already registered")
        _SOLVERS[name] = SolverEntry(name=name, fit=fn, decision=decision,
                                     plans=frozenset(plans), grows=grows,
                                     needs_basis=needs_basis)
        return fn
    return deco


def register_plan(name: str):
    def deco(fn: PlanFn):
        if name in _PLANS:
            raise ValueError(f"plan {name!r} already registered")
        _PLANS[name] = fn
        return fn
    return deco


def available_solvers():
    return sorted(_SOLVERS)


def available_plans():
    return sorted(_PLANS)


def get_solver(name: str) -> SolverEntry:
    if name not in _SOLVERS:
        raise KeyError(
            f"unknown solver {name!r}; registered: {available_solvers()}")
    return _SOLVERS[name]


def get_plan(name: str) -> PlanFn:
    if name not in _PLANS:
        raise KeyError(
            f"unknown execution plan {name!r}; registered: {available_plans()}")
    return _PLANS[name]


def validate(solver: str, plan: str) -> SolverEntry:
    """Check the (solver, plan) composition; raise a helpful error if bad."""
    entry = get_solver(solver)
    get_plan(plan)
    if plan not in entry.plans:
        raise ValueError(
            f"solver {solver!r} does not support execution plan {plan!r}; "
            f"valid plans for it: {sorted(entry.plans)}")
    return entry


def valid_combinations():
    """[(solver, plan)] for every registered, mathematically valid pairing."""
    return [(s, p) for s in available_solvers()
            for p in sorted(_SOLVERS[s].plans) if p in _PLANS]
