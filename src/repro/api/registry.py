"""Solver and execution-plan registries.

A *solver* owns the optimization strategy (objective + update rule); an
*execution plan* owns where the math runs (one device, explicit shard_map
collectives, XLA-auto SPMD, materialization-free on-the-fly gram, or
out-of-core chunk streaming). Any
solver composes with any plan it declares mathematically valid — the
composition is checked here, once, with an error message that lists the
legal choices instead of failing deep inside a trace.

The same split holds for inference. A solver contributes only a
*decision spec* — which points/features and weights realize the paper's
prediction map o(x) = k(x, basis)·β (``SolverEntry.decision_spec``) — and
every plan carries a ``decide`` arm that executes that map under its own
memory/distribution contract (``PlanEntry.decide``, implemented in
:mod:`repro.api.infer`). Training validity (``SolverEntry.plans``) does
NOT constrain inference: o(x) is one kmvp regardless of how β was
obtained, so any fitted machine may serve under any registered plan via
``KernelMachine.decision_function(..., plan=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet

SolverFn = Callable  # (config, X, y, basis, beta0, *, mesh, plan, key, CW) -> (state, FitResult)
DecisionSpecFn = Callable  # (config, state) -> repro.api.infer.DecisionSpec
PlanFn = Callable    # (config, mesh, X, y, basis, beta0, CW=None) -> TronResult
DecideFn = Callable  # (config, mesh, spec, X, *, backend=None) -> margins


@dataclasses.dataclass(frozen=True)
class SolverEntry:
    name: str
    fit: SolverFn
    decision_spec: DecisionSpecFn  # state -> (features, basis, beta) of o(x)
    plans: FrozenSet[str]      # execution plans this solver is valid under
    grows: bool = False        # supports partial_fit basis growth
    needs_basis: bool = False  # fit consumes a point basis (else ignores it)


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    name: str
    fit: PlanFn                # run a TRON solve under this plan
    decide: DecideFn           # evaluate o(x) margins under this plan


_SOLVERS: Dict[str, SolverEntry] = {}
_PLANS: Dict[str, PlanEntry] = {}


def register_solver(name: str, *, plans, grows: bool = False,
                    needs_basis: bool = False,
                    decision_spec: DecisionSpecFn = None):
    def deco(fn: SolverFn):
        if name in _SOLVERS:
            raise ValueError(f"solver {name!r} already registered")
        if decision_spec is None:
            raise ValueError(f"solver {name!r} needs a decision_spec: every "
                             f"fitted machine must be able to predict")
        _SOLVERS[name] = SolverEntry(name=name, fit=fn,
                                     decision_spec=decision_spec,
                                     plans=frozenset(plans), grows=grows,
                                     needs_basis=needs_basis)
        return fn
    return deco


def register_plan(name: str, *, decide: DecideFn = None):
    def deco(fn: PlanFn):
        if name in _PLANS:
            raise ValueError(f"plan {name!r} already registered")
        if decide is None:
            raise ValueError(f"plan {name!r} needs a decide arm: inference "
                             f"routes through the plan registry")
        _PLANS[name] = PlanEntry(name=name, fit=fn, decide=decide)
        return fn
    return deco


def available_solvers():
    return sorted(_SOLVERS)


def available_plans():
    return sorted(_PLANS)


def get_solver(name: str) -> SolverEntry:
    if name not in _SOLVERS:
        raise KeyError(
            f"unknown solver {name!r}; registered: {available_solvers()}")
    return _SOLVERS[name]


def get_plan(name: str) -> PlanEntry:
    if name not in _PLANS:
        raise KeyError(
            f"unknown execution plan {name!r}; registered: {available_plans()}")
    return _PLANS[name]


def validate(solver: str, plan: str) -> SolverEntry:
    """Check the (solver, plan) composition; raise a helpful error if bad."""
    entry = get_solver(solver)
    get_plan(plan)
    if plan not in entry.plans:
        raise ValueError(
            f"solver {solver!r} does not support execution plan {plan!r}; "
            f"valid plans for it: {sorted(entry.plans)}")
    # under a live multi-controller topology only the rows-only streaming
    # plans can span processes — fail at machine construction, not mid-fit
    from repro.sharding import multihost
    multihost.check_plan(plan)
    return entry


def valid_combinations():
    """[(solver, plan)] for every registered, mathematically valid pairing."""
    return [(s, p) for s in available_solvers()
            for p in sorted(_SOLVERS[s].plans) if p in _PLANS]
