"""Frozen, JSON-round-trippable configuration for :class:`KernelMachine`.

One config drives every solver x execution-plan combination: the paper's
point is that formulation (4) is *one* objective, so the knobs that pick a
training strategy (solver name, plan name, mesh axes) are data, not code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.core.losses import Loss, get_loss
from repro.core.nystrom import KernelSpec
from repro.core.tron import TronConfig
from repro.kernels.policy import DtypePolicy, get_policy


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs for the out-of-core ``stream`` execution plan.

    ``chunk_rows`` is the block size the solver streams per step (rounded
    up to a multiple of the mesh's data extent; ``None`` picks
    ``min(n, 16384)``) — it bounds every materialized intermediate at
    ``chunk_rows x m`` elements. ``mmap`` controls whether ``.npy`` shard
    directories are opened memory-mapped (reads touch only the rows a
    chunk needs) or loaded eagerly per shard.

    Chunk I/O pipelining (see ``core.distributed._ChunkFeeder``):
    ``cache_chunks`` bounds the device-resident chunk cache — chunks kept
    on the mesh across f/g/Hd evaluations so CG's dozens of Hd calls per
    TRON step stop re-transferring the dataset. ``None`` auto-sizes to a
    256 MiB HBM budget (counting the (chunk_rows, K) one-vs-rest target
    block when multiclass); ``0`` disables caching. ``prefetch`` is the
    depth of the background-thread host->device pipeline for uncached
    chunks (2 = double buffering; <=1 reads synchronously). Note the
    transient footprint: with prefetch = p, up to p in-flight chunks sit
    on device in addition to the one being consumed — set
    ``prefetch=0`` as well as ``cache_chunks=0`` to get the strict
    one-transient-chunk residency of the pre-pipeline implementation.
    """

    chunk_rows: Optional[int] = None
    mmap: bool = True
    cache_chunks: Optional[int] = None
    prefetch: int = 2


@dataclasses.dataclass(frozen=True)
class MachineConfig:
    """Everything needed to train and serve one kernel machine.

    ``solver`` / ``plan`` name entries in :mod:`repro.api.registry`; the
    remaining fields parameterize the objective (kernel, loss, lam), the
    optimizer (tron), and the solver/plan specifics.
    """

    kernel: KernelSpec = KernelSpec()
    loss: str = "squared_hinge"        # by name -> repro.core.losses.get_loss
    lam: float = 1.0
    solver: str = "tron"               # tron | linearized | rff | ppacksvm
    plan: str = "local"                # local | shard_map | auto | otf
                                       #   | otf_shard | stream
    tron: TronConfig = TronConfig()
    backend: str = "jnp"               # gram/kmvp backend: jnp | pallas
    seed: int = 0                      # rff draw / ppacksvm shuffle / basis pick
    dtype_policy: str = "fp32"         # kernel compute policy by name
                                       # (repro.kernels.policy.POLICIES):
                                       # fp32 | bf16 | fp16. Governs the
                                       # gram/kmvp compute dtype everywhere;
                                       # accumulation and TRON state stay f32.

    # basis selection when fit() is called without an explicit basis
    m: int = 256
    basis_strategy: str = "random"     # random | kmeans | auto

    # solver-specific knobs
    rff_features: int = 256            # feature count for solver="rff"
    ppack_epochs: int = 1
    ppack_size: int = 64
    linearized_rank: Optional[int] = None

    # execution-plan knobs (distributed plans)
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = None   # column partition; otf_shard: must be
                                       # None (rows-only fused plan)
    otf_block_rows: Optional[int] = None  # otf_shard jnp-fallback row-chunk;
                                          # None -> per-shard-n heuristic
                                          # (kernels.ops.otf_block_rows)
    stream: StreamConfig = StreamConfig()  # plan="stream" chunking knobs

    def __post_init__(self):
        get_loss(self.loss)  # fail fast on unknown loss names
        get_policy(self.dtype_policy)  # fail fast on unknown policy names

    def get_loss(self) -> Loss:
        return get_loss(self.loss)

    def get_policy(self) -> DtypePolicy:
        return get_policy(self.dtype_policy)

    def replace(self, **kw) -> "MachineConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- round-trip
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["data_axes"] = list(self.data_axes)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MachineConfig":
        d = dict(d)
        d["kernel"] = KernelSpec(**d["kernel"])
        d["tron"] = TronConfig(**d["tron"])
        d["data_axes"] = tuple(d["data_axes"])
        # checkpoints written before the stream plan carry no "stream" key
        d["stream"] = StreamConfig(**d.get("stream", {}))
        return cls(**d)
