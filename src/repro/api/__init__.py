"""repro.api — the unified KernelMachine estimator surface.

One config-driven estimator over formulation (4) with two registries:
solvers (tron | linearized | rff | ppacksvm) and execution plans
(local | shard_map | auto | otf | otf_shard | stream). See
repro.api.machine for the tour.
"""
from repro.api.config import MachineConfig, StreamConfig
from repro.api.infer import DecisionSpec
from repro.api.result import FitResult
from repro.api.machine import KernelMachine
from repro.api.registry import (available_plans, available_solvers,
                                get_plan, get_solver, register_plan,
                                register_solver, valid_combinations, validate)

__all__ = [
    "KernelMachine", "MachineConfig", "StreamConfig", "FitResult",
    "DecisionSpec",
    "available_plans", "available_solvers", "get_plan", "get_solver",
    "register_plan", "register_solver", "valid_combinations", "validate",
]
