"""Execution plans: where (and how) a TRON solve runs.

Every plan has the same contract — take the global problem
``(X, y, basis, beta0)`` plus a :class:`MachineConfig`, return a
``TronResult`` — so solvers compose with plans without knowing which one
they got. Each registration also carries a ``decide`` arm
(:mod:`repro.api.infer`) executing the prediction map o(x) = k(x, basis)·β
under the same memory/distribution contract as the plan's training
closures — ``local`` materializes the dense test gram, the mesh plans
route through the fused kmvp dispatchers, ``stream`` scores chunk by
chunk from a :class:`~repro.data.chunks.ChunkSource`:

* ``local``     — one device, materialized (C, W), Formulation4 closures.
                  Accepts a precomputed ``CW`` cache (stage-wise growth
                  reuses every already-computed column of C).
* ``shard_map`` — the paper's Algorithm 1: explicit psum AllReduces, one
                  per paper step, via DistributedNystrom(mode="shard_map").
* ``auto``      — same math under jit with sharded operands; XLA SPMD picks
                  the collective schedule.
* ``otf``       — compute-on-the-fly: C is never *stored*, but each f/g/Hd
                  evaluation still rebuilds a transient (n/p, m) gram block
                  per shard before contracting it.
* ``otf_shard`` — mesh-sharded fully-fused on-the-fly: rows of X over the
                  data axes, full basis replicated; C beta / C^T D r / W
                  contractions run through the fused kmvp path (Pallas VMEM
                  tiles via ``config.backend="pallas"``, row-chunked jnp
                  recomputation otherwise), so no (n/p, m) array ever
                  exists on any device and each evaluation AllReduces one
                  m-vector.
* ``stream``    — out-of-core: X lives in a chunked source (in-memory
                  arrays or a directory of memory-mapped .npy shards) and
                  every f/g/Hd evaluation is *accumulated* chunk by chunk
                  through the fused kmvp path. TRON runs eagerly on the
                  host (``tron_host``); n may exceed host RAM.

Memory/flops/communication per f/g/Hd call (p devices, rows sharded):

                  plan        C bytes/device   extra flops    comms/eval
                  ----------  ---------------  -------------  -----------
                  shard_map   4 n m / p        0              O(m)
                  otf         4 n m / p (peak) O(n m d / p)   O(m)
                  otf_shard   tile (VMEM)      O(n m d / p)   O(m)
                  stream      tile (VMEM)      O(n m d / p)   O(m) / chunk

Distributed in-memory plans run on ``mesh`` (or a default all-devices data
mesh) and require n and m divisible by the data-axis extent — checked here
with a readable error instead of a shard_map trace failure. ``otf_shard``
and ``stream`` shard rows only (``model_axis`` must be None) and are
validated by shape instrumentation in tests: no intermediate reaches
n/p x m (respectively chunk_rows x m) elements. ``stream`` accepts any n —
ragged chunks are mask-padded exactly.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.infer import decide_fused, decide_local, decide_stream
from repro.api.registry import register_plan
from repro.core.compat import default_mesh
from repro.core.distributed import DistConfig, DistributedNystrom
from repro.core.formulation import Formulation4
from repro.core.nystrom import build_C, build_W
from repro.core.tron import TronResult, tron
from repro.data.chunks import as_chunk_source


@register_plan("local", decide=decide_local)
def plan_local(config, mesh, X, y, basis, beta0,
               CW: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
               classes=None, checkpoint=None, state0=None) -> TronResult:
    del mesh, classes   # multiclass y arrives pre-expanded to (n, K) ±1
    pol = None if config.dtype_policy == "fp32" else config.dtype_policy
    if CW is None:
        C = build_C(X, basis, config.kernel, config.backend, policy=pol)
        W = build_W(basis, config.kernel, config.backend, policy=pol)
    else:
        C, W = CW
    form = Formulation4(lam=config.lam, loss=config.get_loss())
    cfg = config.tron

    if checkpoint is not None or state0 is not None:
        # tron jits its own while_loop segments and snapshots between them;
        # an outer jit here would hide the state from the host
        return tron(lambda b: form.fgrad(C, W, y, b),
                    lambda D, d: form.hessd(C, W, D, d), beta0, cfg,
                    state0=state0,
                    snapshot_every=checkpoint.interval if checkpoint else 0,
                    on_snapshot=checkpoint.on_snapshot if checkpoint
                    else None)

    @jax.jit
    def _run(C, W, y, beta0):
        return tron(lambda b: form.fgrad(C, W, y, b),
                    lambda D, d: form.hessd(C, W, D, d), beta0, cfg)

    return _run(C, W, y, beta0)


def _axis_extent(mesh, axes) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def _resolve_mesh(config, mesh):
    if mesh is not None:
        return mesh
    return default_mesh(config.data_axes, config.model_axis)


def _check_divisible(config, mesh, n: int, m: int, plan: str):
    dp = _axis_extent(mesh, config.data_axes)
    mp = mesh.shape[config.model_axis] if config.model_axis else 1
    if n % dp:
        raise ValueError(
            f"plan {plan!r}: n={n} rows must divide evenly over the data axes "
            f"{config.data_axes} (extent {dp}); truncate or pad the dataset")
    if m % (dp * mp) or (config.model_axis and m % mp):
        raise ValueError(
            f"plan {plan!r}: basis size m={m} must divide evenly over "
            f"data x model axes (extents {dp} x {mp}) for the 2-D (C, W) "
            f"partition; round m to a multiple of {dp * mp}")


def _distributed(config, mesh, X, y, basis, beta0, *, mode: str,
                 materialize: bool, plan: str, fused: bool = False,
                 checkpoint=None, state0=None) -> TronResult:
    mesh = _resolve_mesh(config, mesh)
    _check_divisible(config, mesh, X.shape[0], basis.shape[0], plan)
    dc = DistConfig(data_axes=config.data_axes, model_axis=config.model_axis,
                    mode=mode, materialize=materialize,
                    backend=config.backend, fused=fused,
                    block_rows=config.otf_block_rows,
                    policy=config.dtype_policy)
    solver = DistributedNystrom(mesh, config.lam, config.loss, config.kernel,
                                dc)
    return solver.solve(X, y, basis, beta0=beta0, cfg=config.tron,
                        checkpoint=checkpoint, state0=state0)


@register_plan("shard_map", decide=decide_fused)
def plan_shard_map(config, mesh, X, y, basis, beta0, CW=None,
                   classes=None, checkpoint=None, state0=None) -> TronResult:
    del CW, classes  # distributed plans build their own sharded (C, W);
    #                  multiclass y arrives pre-expanded to (n, K) ±1
    return _distributed(config, mesh, X, y, basis, beta0,
                        mode="shard_map", materialize=True, plan="shard_map",
                        checkpoint=checkpoint, state0=state0)


@register_plan("auto", decide=decide_fused)
def plan_auto(config, mesh, X, y, basis, beta0, CW=None,
              classes=None, checkpoint=None, state0=None) -> TronResult:
    del CW, classes
    return _distributed(config, mesh, X, y, basis, beta0,
                        mode="auto", materialize=True, plan="auto",
                        checkpoint=checkpoint, state0=state0)


@register_plan("otf", decide=decide_fused)
def plan_otf(config, mesh, X, y, basis, beta0, CW=None,
             classes=None, checkpoint=None, state0=None) -> TronResult:
    del CW, classes  # the whole point: C is never materialized
    return _distributed(config, mesh, X, y, basis, beta0,
                        mode="shard_map", materialize=False, plan="otf",
                        checkpoint=checkpoint, state0=state0)


@register_plan("stream", decide=decide_stream)
def plan_stream(config, mesh, X, y, basis, beta0, CW=None,
                classes=None, checkpoint=None, state0=None) -> TronResult:
    """Out-of-core accumulation: X may be an in-memory array (wrapped into
    an ArrayChunkSource), a ChunkSource, or a shard-directory path.

    Unlike the in-memory plans, a multiclass solve keeps the source's
    compact integer labels and receives ``classes``: each chunk is
    expanded into (chunk_rows, K) ±1 targets on the host right before
    transfer, so the one-vs-rest blow-up never exists at full n."""
    del CW  # recomputation leaves nothing to cache (same argument as
    #         otf_shard: growth re-streams, warm start carries the progress)
    if config.model_axis is not None:
        raise ValueError(
            "plan 'stream' shards rows only: chunks go through the fused "
            "kmvp kernels, which contract over all basis columns; set "
            "model_axis=None")
    mesh = _resolve_mesh(config, mesh)
    source = as_chunk_source(X, y, chunk_rows=config.stream.chunk_rows,
                             mmap=config.stream.mmap)
    dc = DistConfig(data_axes=config.data_axes, model_axis=None,
                    mode="shard_map", materialize=False,
                    backend=config.backend, fused=True,
                    block_rows=config.otf_block_rows,
                    policy=config.dtype_policy)
    solver = DistributedNystrom(mesh, config.lam, config.loss, config.kernel,
                                dc)
    return solver.solve_stream(source, basis, beta0=beta0, cfg=config.tron,
                               classes=classes,
                               cache_chunks=config.stream.cache_chunks,
                               prefetch=config.stream.prefetch,
                               checkpoint=checkpoint, state0=state0)


@register_plan("otf_shard", decide=decide_fused)
def plan_otf_shard(config, mesh, X, y, basis, beta0, CW=None,
                   classes=None, checkpoint=None, state0=None) -> TronResult:
    del CW, classes  # no (n/p, m) block exists to cache, let alone (C, W)
    if config.model_axis is not None:
        raise ValueError(
            "plan 'otf_shard' shards rows only: the fused kmvp kernels "
            "contract over all basis columns in VMEM, so a model_axis "
            "column partition does not apply; set model_axis=None (or use "
            "plan 'otf' for the 2-D on-the-fly partition)")
    return _distributed(config, mesh, X, y, basis, beta0,
                        mode="shard_map", materialize=False,
                        plan="otf_shard", fused=True,
                        checkpoint=checkpoint, state0=state0)
