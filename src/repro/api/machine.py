"""`KernelMachine`: the one estimator every entrypoint targets.

    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0),
                           lam=0.5, solver="tron", plan="shard_map")
    km = KernelMachine(config).fit(X, y, basis)
    yhat = km.predict(Xt)
    km.save("machine.npz")
    km2 = KernelMachine.load("machine.npz")

Swapping single-node for distributed training, stage-wise growth, RFF, or
the baselines is a config edit, not a code path change — the paper's
"one objective, many execution strategies" claim made into an API.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import MachineConfig
from repro.api.infer import (_is_chunked, as_inference_source,
                             iter_label_chunks, make_stream_decider)
from repro.api.registry import get_plan, get_solver, validate
from repro.api.result import FitResult
from repro.checkpoint import (check_resume_config, load_arrays, load_latest,
                              save_checkpoint)
from repro.core.basis import select_basis
from repro.core.nystrom import build_C, build_W, gram

# solver/plan registration happens on import
import repro.api.plans    # noqa: F401
import repro.api.solvers  # noqa: F401

_CKPT_FORMAT = 1


def _x_fingerprint(X) -> tuple:
    """Cheap dataset identity for the local-plan (C, W) growth cache.

    Shape alone is NOT identity — two same-shape datasets must not share
    cached kernel columns — so the key adds dtype and a strided-sample
    checksum (≤ ~8k elements hashed regardless of n·d: O(1)-ish against
    the O(n·m·d) gram build the cache avoids). Sampling is a deliberate
    tradeoff: a swap to independently-generated data is caught with
    near-certainty, but a surgical in-place edit confined to unsampled
    rows is not — callers who mutate X between grow calls should treat it
    as a new dataset (jax arrays, being immutable, cannot hit this)."""
    n, d = map(int, X.shape)
    sample = np.ascontiguousarray(
        np.asarray(X[:: max(1, n // 64), :: max(1, d // 8)]))
    return (n, d, str(sample.dtype),
            hashlib.sha1(sample.tobytes()).hexdigest())


class KernelMachine:
    """Estimator over formulation (4) with pluggable solver and plan.

    Attributes set by fitting:
      ``state_``    — flat dict of arrays (the deployable model)
      ``history_``  — one :class:`FitResult` per fit/partial_fit call
      ``result_``   — the latest :class:`FitResult`
    """

    def __init__(self, config: MachineConfig = MachineConfig(), *, mesh=None):
        validate(config.solver, config.plan)   # fail at construction, not fit
        self.config = config
        self.mesh = mesh
        self.state_: Optional[dict] = None
        self.history_: List[FitResult] = []
        self._cw = None          # (C, W) cache for local stage-wise growth
        self._cw_key = None      # data fingerprint the cache was built on

    # ------------------------------------------------------------------- fit
    @property
    def result_(self) -> Optional[FitResult]:
        return self.history_[-1] if self.history_ else None

    def fit(self, X, y, basis=None, *, beta0=None, key=None, checkpoint=None):
        """Train from scratch. ``basis`` defaults to ``config.basis_strategy``
        selection of ``config.m`` points (ignored by rff/ppacksvm solvers).

        Integer multiclass y (solver ``tron``) trains one-vs-rest: all K
        beta columns in ONE column-batched TRON pass, sharing every gram
        recomputation under the fused/stream plans. ``decision_function``
        then returns (n, K) margins and :meth:`predict` argmaxes back to
        the original labels.

        ``checkpoint`` (a :class:`repro.checkpoint.CheckpointConfig`,
        solver ``tron`` only) commits preemption-safe in-training step
        files every ``interval`` outer iterations; with
        ``checkpoint.resume=True`` the fit first restores the newest step
        in ``checkpoint.dir`` — including its stored basis (and one-vs-rest
        class order), so the restarted run optimizes the identical
        objective — and continues from that iterate.
        """
        entry = validate(self.config.solver, self.config.plan)
        resume = None
        if checkpoint is not None:
            if self.config.solver != "tron":
                raise ValueError(
                    f"in-training checkpoints snapshot TRON iterate state; "
                    f"solver {self.config.solver!r} does not support "
                    f"checkpoint= (use solver='tron')")
            if checkpoint.resume:
                resume = load_latest(checkpoint.dir)
                check_resume_config(self.config, resume.meta)
                if "basis" in resume.arrays:
                    # the stored basis IS the objective's identity: never
                    # re-select (a fresh random draw would change k(x, basis))
                    basis = jnp.asarray(resume.arrays["basis"])
        if key is None:
            key = jax.random.PRNGKey(self.config.seed)
        if basis is None and entry.needs_basis:
            from repro.data.chunks import ChunkSource, random_basis_from_source
            if isinstance(X, ChunkSource):   # out-of-core: O(m) rows read
                if self.config.basis_strategy not in ("random", "auto"):
                    raise ValueError(
                        f"basis_strategy {self.config.basis_strategy!r} "
                        f"needs X in memory; chunked sources support "
                        f"'random' (or pass an explicit basis)")
                basis = jnp.asarray(random_basis_from_source(
                    key, X, self.config.m))
            else:
                basis = select_basis(key, X, self.config.m,
                                     strategy=self.config.basis_strategy,
                                     mesh=self.mesh,
                                     data_axes=self.config.data_axes)
        hooks = {} if checkpoint is None else {"checkpoint": checkpoint,
                                               "resume": resume}
        state, res = entry.fit(self.config, X, y, basis, beta0,
                               mesh=self.mesh, plan=self.config.plan, key=key,
                               **hooks)
        self.state_ = state
        self.history_ = [res]
        self._cw = self._cw_key = None
        return self

    def partial_fit(self, X, y, new_basis, *, key=None):
        """Stage-wise basis growth (paper §3): add ``new_basis`` points,
        warm-start beta (old coordinates kept, new ones zero) and re-solve.

        Under the ``local`` plan only the NEW columns of C (and new blocks
        of W) are computed — the incrementality the paper highlights as
        formulation (4)'s advantage over (3)'s incremental SVD. Distributed
        plans rebuild their sharded (C, W) but keep the warm start. The
        cache is keyed on a data fingerprint (shape + dtype + sampled
        checksum), so passing *different* data of the same shape rebuilds
        the kernel columns instead of silently reusing stale ones.
        """
        entry = validate(self.config.solver, self.config.plan)
        if not entry.grows:
            raise ValueError(
                f"solver {self.config.solver!r} does not support stage-wise "
                f"basis growth (partial_fit); use solver='tron'")
        new_basis = jnp.asarray(new_basis)
        kern, backend = self.config.kernel, self.config.backend
        local = self.config.plan == "local"
        xkey = _x_fingerprint(X) if local else None   # computed once per call

        if self.state_ is None:
            basis = new_basis
            beta0 = None      # solver picks (m,) or (m, K) zeros to match y
            if local:
                self._cw = (build_C(X, basis, kern, backend),
                            build_W(basis, kern, backend))
                self._cw_key = xkey
        else:
            old_basis, old_beta = self.state_["basis"], self.state_["beta"]
            basis = jnp.concatenate([old_basis, new_basis], axis=0)
            # warm start keeps every old coordinate — including the K
            # one-vs-rest columns of a multiclass beta (rank-generic zeros)
            beta0 = jnp.concatenate(
                [old_beta, jnp.zeros((new_basis.shape[0],)
                                     + old_beta.shape[1:], old_beta.dtype)])
            if local:
                # sampled-checksum comparison, never id(): an id fast path
                # would falsely hit on in-place-mutated numpy arrays and on
                # CPython id reuse
                if self._cw is not None and self._cw_key == xkey:
                    C, W = self._cw          # only new columns/blocks below
                else:                        # fit() first, or swapped data
                    C = build_C(X, old_basis, kern, backend)
                    W = build_W(old_basis, kern, backend)
                C_new = gram(X, new_basis, kern, backend)
                W_cross = gram(old_basis, new_basis, kern, backend)
                W_new = gram(new_basis, new_basis, kern, backend)
                C = jnp.concatenate([C, C_new], axis=1)
                W = jnp.block([[W, W_cross], [W_cross.T, W_new]])
                self._cw = (C, W)
                self._cw_key = xkey

        state, res = entry.fit(self.config, X, y, basis, beta0,
                               mesh=self.mesh, plan=self.config.plan,
                               key=key, CW=self._cw if local else None)
        self.state_ = state
        self.history_.append(res)
        return self

    # --------------------------------------------------------------- predict
    def _require_fitted(self):
        if self.state_ is None:
            raise RuntimeError("KernelMachine is not fitted; call fit() or "
                               "load() first")

    def _decision_plan(self, X, plan: Optional[str]) -> str:
        """Resolve which plan's decide arm serves this query set."""
        if plan is None:
            return "stream" if _is_chunked(X) else self.config.plan
        get_plan(plan)                       # fail fast on unknown names
        if _is_chunked(X) and plan != "stream":
            raise ValueError(
                f"plan {plan!r} scores in-memory batches; a ChunkSource / "
                f"shard-directory query set routes through plan='stream' "
                f"(or use decision_chunks/predict_chunks)")
        return plan

    def _spec(self):
        return get_solver(self.config.solver).decision_spec(self.config,
                                                            self.state_)

    def decision_function(self, X, *, plan: Optional[str] = None,
                          backend: Optional[str] = None):
        """Raw margin o(x) through the execution-plan registry. Shape (n,)
        for a binary machine, (n, K) per-class margins for one-vs-rest.

        ``plan`` overrides the training plan for this evaluation — any
        registered plan is valid for inference regardless of how the
        machine was trained (a ``stream``-trained machine serves small
        batches via ``'local'``; a ``local``-trained machine scores a
        larger-than-RAM shard directory via ``'stream'``). ``X`` may be a
        :class:`~repro.data.chunks.ChunkSource` or shard-directory path
        (routed through ``'stream'``, margins returned as one host
        array); arrays go to the resolved plan's decide arm.
        """
        self._require_fitted()
        plan = self._decision_plan(X, plan)
        return get_plan(plan).decide(self.config, self.mesh, self._spec(),
                                     X, backend=backend)

    def decision_chunks(self, X) -> Iterator:
        """Streaming margins: yield one (rows[, K]) host array per chunk of
        ``X`` (array, ChunkSource, or shard-directory path), evaluated
        through the stream decide pipeline — bounded memory even when the
        full margin vector would not fit."""
        self._require_fitted()
        sd = make_stream_decider(self.config, self.mesh, self._spec(),
                                 as_inference_source(X, self.config))
        return sd.margins()

    def _labels(self, o):
        if "classes" in self.state_:
            return self.state_["classes"][jnp.argmax(jnp.asarray(o), axis=-1)]
        return jnp.sign(jnp.asarray(o))

    def predict(self, X, *, plan: Optional[str] = None):
        """±1 signs for a binary machine; original integer labels (argmax
        over the one-vs-rest margins) for a multiclass machine."""
        return self._labels(self.decision_function(X, plan=plan))

    def predict_chunks(self, X) -> Iterator:
        """Streaming :meth:`predict`: one host label array per chunk."""
        for o in self.decision_chunks(X):
            yield np.asarray(self._labels(o))

    def score(self, X, y=None, *, plan: Optional[str] = None) -> float:
        """Mean accuracy. A chunked ``X`` (ChunkSource / shard directory)
        scores chunk-by-chunk in bounded memory; ``y=None`` then reads the
        labels from the source itself (y-only shard reads)."""
        self._require_fitted()
        if _is_chunked(X):
            self._decision_plan(X, plan)   # reject non-stream overrides
            source = as_inference_source(X, self.config)
            sd = make_stream_decider(self.config, self.mesh, self._spec(),
                                     source)
            labels = iter_label_chunks(sd.source, sd.chunk_rows) \
                if y is None else None
            correct = total = 0
            at = 0
            for o in sd.margins():
                pred = np.asarray(self._labels(o))
                rows = pred.shape[0]
                yc = next(labels) if labels is not None \
                    else np.asarray(y)[at:at + rows]
                correct += int(np.sum(pred == yc))
                total += rows
                at += rows
            return correct / total
        if y is None:
            raise TypeError("score() needs y for in-memory X (only chunked "
                            "sources carry their own labels)")
        # exact-count division (not f32 jnp.mean) so the in-memory and
        # chunked paths return bit-identical accuracies for identical
        # predictions at any n
        pred = np.asarray(self.predict(X, plan=plan))
        return int(np.sum(pred == np.asarray(y))) / pred.shape[0]

    def decider(self, *, plan: Optional[str] = None,
                backend: Optional[str] = None) -> Callable:
        """A stable ``X -> margins`` callable bound to one plan's decide
        arm — what a serving loop jit-compiles per batch bucket
        (:mod:`repro.launch.kernel_serve`). The ``local`` and fused-plan
        deciders are jit-traceable; the ``stream`` decider is host-driven
        (serve a stream-trained machine via ``plan='local'`` or
        ``'otf_shard'`` instead)."""
        self._require_fitted()
        entry = get_plan(plan or self.config.plan)
        config, mesh, spec = self.config, self.mesh, self._spec()

        def decide(X):
            return entry.decide(config, mesh, spec, X, backend=backend)

        return decide

    # ------------------------------------------------------------- save/load
    def save(self, path: str, *, quantize: Optional[str] = None):
        """Persist state + config via repro.checkpoint (single .npz).

        ``quantize="int8"`` stores the heavy state arrays (basis, beta) as
        symmetric per-column int8 codes with fp32 scales — ~4× smaller
        checkpoints for serving fleets (see ``repro.checkpoint.quant``).
        :meth:`load` dequantizes transparently; margins shift by at most
        the per-column rounding step, bounded by the round-trip test."""
        self._require_fitted()
        meta = {"format": _CKPT_FORMAT, "config": self.config.to_dict(),
                "history": [
                    {"solver": r.solver, "plan": r.plan, "m": r.m, "f": r.f,
                     "n_iter": r.n_iter, "converged": r.converged}
                    for r in self.history_]}
        tree = dict(self.state_)
        if quantize is not None:
            from repro.checkpoint.quant import quantize_state
            tree, manifest = quantize_state(tree, quantize)
            meta["quantized"] = manifest
        save_checkpoint(path, tree, metadata=meta)
        return path

    @classmethod
    def load(cls, path: str, *, mesh=None,
             policy: Optional[str] = None) -> "KernelMachine":
        """Restore a machine from :meth:`save` output.

        Pre-policy fp32 checkpoints (no ``dtype_policy`` config key, no
        quantization manifest) load byte-identically under the default
        policy. ``policy`` overrides the checkpointed ``dtype_policy`` for
        this instance — the standard serving move is training fp32 then
        loading with ``policy="bf16"`` (often on a ``quantize="int8"``
        checkpoint) to serve through the cheap decide arm."""
        arrays, meta = load_arrays(path)
        if meta.get("format") != _CKPT_FORMAT:
            raise ValueError(f"{path}: not a KernelMachine checkpoint "
                             f"(format={meta.get('format')!r})")
        if meta.get("quantized"):
            from repro.checkpoint.quant import dequantize_state
            arrays = dequantize_state(arrays, meta["quantized"])
        config = MachineConfig.from_dict(meta["config"])
        if policy is not None:
            config = config.replace(dtype_policy=policy)
        km = cls(config, mesh=mesh)
        km.state_ = {k: jnp.asarray(v) for k, v in arrays.items()}
        return km
