"""`KernelMachine`: the one estimator every entrypoint targets.

    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0),
                           lam=0.5, solver="tron", plan="shard_map")
    km = KernelMachine(config).fit(X, y, basis)
    yhat = km.predict(Xt)
    km.save("machine.npz")
    km2 = KernelMachine.load("machine.npz")

Swapping single-node for distributed training, stage-wise growth, RFF, or
the baselines is a config edit, not a code path change — the paper's
"one objective, many execution strategies" claim made into an API.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.api.config import MachineConfig
from repro.api.registry import validate
from repro.api.result import FitResult
from repro.checkpoint import load_arrays, save_checkpoint
from repro.core.basis import select_basis
from repro.core.nystrom import build_C, build_W, gram

# solver/plan registration happens on import
import repro.api.plans    # noqa: F401
import repro.api.solvers  # noqa: F401

_CKPT_FORMAT = 1


class KernelMachine:
    """Estimator over formulation (4) with pluggable solver and plan.

    Attributes set by fitting:
      ``state_``    — flat dict of arrays (the deployable model)
      ``history_``  — one :class:`FitResult` per fit/partial_fit call
      ``result_``   — the latest :class:`FitResult`
    """

    def __init__(self, config: MachineConfig = MachineConfig(), *, mesh=None):
        validate(config.solver, config.plan)   # fail at construction, not fit
        self.config = config
        self.mesh = mesh
        self.state_: Optional[dict] = None
        self.history_: List[FitResult] = []
        self._cw = None          # (C, W) cache for local stage-wise growth
        self._cw_shape = None    # X shape the cache was built against

    # ------------------------------------------------------------------- fit
    @property
    def result_(self) -> Optional[FitResult]:
        return self.history_[-1] if self.history_ else None

    def fit(self, X, y, basis=None, *, beta0=None, key=None):
        """Train from scratch. ``basis`` defaults to ``config.basis_strategy``
        selection of ``config.m`` points (ignored by rff/ppacksvm solvers).

        Integer multiclass y (solver ``tron``) trains one-vs-rest: all K
        beta columns in ONE column-batched TRON pass, sharing every gram
        recomputation under the fused/stream plans. ``decision_function``
        then returns (n, K) margins and :meth:`predict` argmaxes back to
        the original labels.
        """
        entry = validate(self.config.solver, self.config.plan)
        if key is None:
            key = jax.random.PRNGKey(self.config.seed)
        if basis is None and entry.needs_basis:
            from repro.data.chunks import ChunkSource, random_basis_from_source
            if isinstance(X, ChunkSource):   # out-of-core: O(m) rows read
                if self.config.basis_strategy not in ("random", "auto"):
                    raise ValueError(
                        f"basis_strategy {self.config.basis_strategy!r} "
                        f"needs X in memory; chunked sources support "
                        f"'random' (or pass an explicit basis)")
                basis = jnp.asarray(random_basis_from_source(
                    key, X, self.config.m))
            else:
                basis = select_basis(key, X, self.config.m,
                                     strategy=self.config.basis_strategy,
                                     mesh=self.mesh,
                                     data_axes=self.config.data_axes)
        state, res = entry.fit(self.config, X, y, basis, beta0,
                               mesh=self.mesh, plan=self.config.plan, key=key)
        self.state_ = state
        self.history_ = [res]
        self._cw = self._cw_shape = None
        return self

    def partial_fit(self, X, y, new_basis, *, key=None):
        """Stage-wise basis growth (paper §3): add ``new_basis`` points,
        warm-start beta (old coordinates kept, new ones zero) and re-solve.

        Under the ``local`` plan only the NEW columns of C (and new blocks
        of W) are computed — the incrementality the paper highlights as
        formulation (4)'s advantage over (3)'s incremental SVD. Distributed
        plans rebuild their sharded (C, W) but keep the warm start. ``X, y``
        must be the same dataset across calls.
        """
        entry = validate(self.config.solver, self.config.plan)
        if not entry.grows:
            raise ValueError(
                f"solver {self.config.solver!r} does not support stage-wise "
                f"basis growth (partial_fit); use solver='tron'")
        new_basis = jnp.asarray(new_basis)
        kern, backend = self.config.kernel, self.config.backend
        local = self.config.plan == "local"

        if self.state_ is None:
            basis = new_basis
            beta0 = None      # solver picks (m,) or (m, K) zeros to match y
            if local:
                self._cw = (build_C(X, basis, kern, backend),
                            build_W(basis, kern, backend))
                self._cw_shape = X.shape
        else:
            old_basis, old_beta = self.state_["basis"], self.state_["beta"]
            basis = jnp.concatenate([old_basis, new_basis], axis=0)
            # warm start keeps every old coordinate — including the K
            # one-vs-rest columns of a multiclass beta (rank-generic zeros)
            beta0 = jnp.concatenate(
                [old_beta, jnp.zeros((new_basis.shape[0],)
                                     + old_beta.shape[1:], old_beta.dtype)])
            if local:
                if self._cw is not None and self._cw_shape == X.shape:
                    C, W = self._cw          # only new columns/blocks below
                else:                        # e.g. fit() first, then grow
                    C = build_C(X, old_basis, kern, backend)
                    W = build_W(old_basis, kern, backend)
                C_new = gram(X, new_basis, kern, backend)
                W_cross = gram(old_basis, new_basis, kern, backend)
                W_new = gram(new_basis, new_basis, kern, backend)
                C = jnp.concatenate([C, C_new], axis=1)
                W = jnp.block([[W, W_cross], [W_cross.T, W_new]])
                self._cw = (C, W)
                self._cw_shape = X.shape

        state, res = entry.fit(self.config, X, y, basis, beta0,
                               mesh=self.mesh, plan=self.config.plan,
                               key=key, CW=self._cw if local else None)
        self.state_ = state
        self.history_.append(res)
        return self

    # --------------------------------------------------------------- predict
    def _require_fitted(self):
        if self.state_ is None:
            raise RuntimeError("KernelMachine is not fitted; call fit() or "
                               "load() first")

    def decision_function(self, X, *, backend: Optional[str] = None):
        """Raw margin o(x); jit-traceable given fixed state. Shape (n,) for
        a binary machine, (n, K) per-class margins for one-vs-rest."""
        self._require_fitted()
        entry = validate(self.config.solver, self.config.plan)
        return entry.decision(self.config, self.state_, X, backend=backend)

    def predict(self, X):
        """±1 signs for a binary machine; original integer labels (argmax
        over the one-vs-rest margins) for a multiclass machine."""
        o = self.decision_function(X)
        if self.state_ is not None and "classes" in self.state_:
            return self.state_["classes"][jnp.argmax(o, axis=-1)]
        return jnp.sign(o)

    def score(self, X, y) -> float:
        return float(jnp.mean(self.predict(X) == jnp.asarray(y)))

    # ------------------------------------------------------------- save/load
    def save(self, path: str):
        """Persist state + config via repro.checkpoint (single .npz)."""
        self._require_fitted()
        meta = {"format": _CKPT_FORMAT, "config": self.config.to_dict(),
                "history": [
                    {"solver": r.solver, "plan": r.plan, "m": r.m, "f": r.f,
                     "n_iter": r.n_iter, "converged": r.converged}
                    for r in self.history_]}
        save_checkpoint(path, dict(self.state_), metadata=meta)
        return path

    @classmethod
    def load(cls, path: str, *, mesh=None) -> "KernelMachine":
        arrays, meta = load_arrays(path)
        if meta.get("format") != _CKPT_FORMAT:
            raise ValueError(f"{path}: not a KernelMachine checkpoint "
                             f"(format={meta.get('format')!r})")
        km = cls(MachineConfig.from_dict(meta["config"]), mesh=mesh)
        km.state_ = {k: jnp.asarray(v) for k, v in arrays.items()}
        return km
