"""Solver adapters: each optimization strategy behind one fit contract.

    fit(config, X, y, basis, beta0, *, mesh, plan, key, CW=None)
        -> (state, FitResult)

``state`` is a flat dict of arrays — exactly what predict needs and exactly
what goes through ``repro.checkpoint`` on save/load:

    tron / linearized : {"basis": (m, d), "beta": (m,)}
    rff               : {"omega": (d, m), "phase": (m,), "beta": (m,)}
    ppacksvm          : {"basis": (n, d), "beta": (n,)}   (support = X train)

Plan validity is the mathematically honest set. ``tron`` runs under every
plan (the paper's claim), including the fused ``otf_shard``. ``rff`` also
runs under every plan via the exact reduction phi(X) -> linear-kernel
machine with identity basis (C = phi(X), W = I is formulation (4)
verbatim; under ``otf_shard`` the fused linear kmvp contracts phi(X)
blocks against the identity basis without materializing them). Both run
under the out-of-core ``stream`` plan too — ``tron`` fully (X itself may
be a ChunkSource), ``rff`` with phi(X) in memory but the solve chunked.
``linearized`` is pinned to ``local``:
its O(m^3) eigendecomposition is the inherently-serial step the paper
argues against. ``ppacksvm`` is pinned to ``local``: sequential SGD with
O(n/r) communication rounds has no honest mapping onto the fused-loop plans.

Training validity does NOT constrain inference: every solver contributes a
``decision_spec`` (what o(x) is) and the plan registry's decide arms
(repro.api.infer) execute it, so even a local-pinned solver's machine can
serve its margins fused on a mesh or chunked out-of-core.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.infer import DecisionSpec
from repro.api.registry import get_plan, register_solver
from repro.api.result import FitResult
from repro.core import linearized as lin
from repro.core import ppacksvm as pps
from repro.core import rff as rffm
from repro.core.nystrom import KernelSpec


def _key(config, key):
    return jax.random.PRNGKey(config.seed) if key is None else key


def _zeros_like_beta(X, m, beta0):
    return jnp.zeros((m,), X.dtype) if beta0 is None else beta0


# ----------------------------------------------------------- one-vs-rest
def ovr_classes(X, y):
    """Distinct labels when (X, y) poses an integer one-vs-rest problem.

    The API rule: an integer label vector means multiclass one-vs-rest
    (each class gets a beta column, trained in ONE multi-RHS TRON pass);
    float targets mean the classic binary/regression problem. Integer ±1
    labels keep their historical binary meaning. Chunked sources are
    label-scanned via :meth:`ChunkSource.iter_y` — O(n) label reads, no X
    bytes touched for .npy shard dirs.
    """
    from repro.data.chunks import ChunkSource
    if isinstance(X, ChunkSource):
        y0 = next(iter(X.iter_y()))
        if not np.issubdtype(np.asarray(y0).dtype, np.integer):
            return None
        labels = np.asarray(X.unique_labels())
    else:
        if y is None:
            return None
        yn = np.asarray(y)
        if yn.ndim != 1 or not np.issubdtype(yn.dtype, np.integer):
            return None
        labels = np.unique(yn)
    if set(labels.tolist()) <= {-1, 1}:
        return None                       # integer ±1 is the binary problem
    if labels.size < 2:
        raise ValueError(
            f"integer labels pose a one-vs-rest problem but only one class "
            f"is present: {labels}; pass float ±1 targets for a binary fit")
    return labels


def _reject_ovr(X, y, solver: str):
    if ovr_classes(X, y) is not None:
        raise ValueError(
            f"solver {solver!r} is binary-only; integer multiclass labels "
            f"train one-vs-rest through solver='tron', whose multi-RHS "
            f"kmvp path fits all classes in one pass (pass float ±1 "
            f"targets if you really meant a binary/regression problem)")


# ------------------------------------------------------------ decision specs
# Solvers no longer execute predictions; they only declare what o(x) *is*
# (feature map, basis points, weights) and the plan registry's decide arms
# (repro.api.infer) execute it — dense locally, fused on a mesh, or chunked
# out-of-core — exactly like the fit side of the registry.

def _spec_nystrom(config, state) -> DecisionSpec:
    """o(x) = k(x, basis)·β over the stored point basis (tron, linearized,
    ppacksvm — for the last, the 'basis' is the full training set)."""
    return DecisionSpec(map_x=lambda x: x, basis=state["basis"],
                        beta=state["beta"], kernel=config.kernel,
                        backend=config.backend,
                        policy=config.dtype_policy)


def _spec_rff(config, state) -> DecisionSpec:
    """o(x) = φ(x)·β via the exact linear-kernel reduction the rff training
    path uses (C = φ(X), identity basis): every plan's decide arm applies
    unchanged. ``identity_basis`` lets the arms contract the features
    directly instead of detouring through an (m, m) identity gram."""
    basis = rffm.RFFBasis(omega=state["omega"], phase=state["phase"],
                          sigma=config.kernel.sigma)
    return DecisionSpec(map_x=lambda x: rffm.rff_features(x, basis),
                        basis=None, beta=state["beta"],
                        kernel=KernelSpec("linear"), backend="jnp",
                        identity_basis=True, policy=config.dtype_policy)


# -------------------------------------------------------------------- solvers
@register_solver("tron",
                 plans={"local", "shard_map", "auto", "otf", "otf_shard",
                        "stream"},
                 grows=True, needs_basis=True, decision_spec=_spec_nystrom)
def fit_tron(config, X, y, basis, beta0=None, *, mesh=None, plan=None,
             key=None, CW=None, checkpoint=None, resume=None):
    """Formulation (4) + trust-region Newton — the paper's solver.

    Integer multiclass y (see :func:`ovr_classes`) trains all K one-vs-rest
    columns in ONE column-batched TRON pass: beta is (m, K) and — under the
    fused/stream plans — every f/g/Hd evaluation recomputes the gram tiles
    once for all K classes instead of once per class. The fitted state
    carries ``classes`` so predict can argmax back to labels.

    ``checkpoint`` (a :class:`repro.checkpoint.CheckpointConfig`) commits
    in-training step files every ``interval`` outer iterations; ``resume``
    (a :class:`repro.checkpoint.ResumeState`, loaded by ``KernelMachine
    .fit``) restores the TRON iterate state so training continues exactly
    where the checkpointed run stopped.
    """
    del key
    plan = plan or config.plan
    classes = ovr_classes(X, y)
    state0 = None
    if resume is not None:
        state0 = resume.snapshot
        beta0 = jnp.asarray(np.asarray(state0.beta))
        if classes is not None and "classes" in resume.arrays:
            stored = np.asarray(resume.arrays["classes"])
            if stored.shape != np.shape(classes) or \
                    np.any(stored != np.asarray(classes)):
                raise ValueError(
                    f"checkpoint was written for one-vs-rest classes "
                    f"{stored.tolist()} but the data poses "
                    f"{np.asarray(classes).tolist()}; refusing to resume "
                    f"onto mismatched beta columns")
            classes = stored
    ck = None
    if checkpoint is not None:
        from repro.checkpoint import TrainingCheckpointer
        arrays = {"basis": np.asarray(basis)}
        if classes is not None:
            arrays["classes"] = np.asarray(classes)
        ck = TrainingCheckpointer(
            checkpoint,
            meta={"config": config.to_dict(), "solver": "tron",
                  "plan": plan},
            arrays=arrays,
            resume_meta=resume.meta if resume is not None else None)
    hooks = {}
    if ck is not None or state0 is not None:
        hooks = {"checkpoint": ck, "state0": state0}
    try:
        if classes is None:
            beta0 = _zeros_like_beta(X, basis.shape[0], beta0)
            res = get_plan(plan).fit(config, mesh, X, y, basis, beta0,
                                     CW=CW, **hooks)
            state = {"basis": basis, "beta": res.beta}
        else:
            from repro.data.chunks import ovr_targets
            m, K = int(basis.shape[0]), int(classes.size)
            if beta0 is None:
                beta0 = jnp.zeros((m, K), X.dtype)
            elif jnp.shape(beta0) != (m, K):
                raise ValueError(
                    f"one-vs-rest fit over {K} classes needs beta0 of shape "
                    f"({m}, {K}); got {jnp.shape(beta0)}")
            if plan == "stream":
                y_fit = y  # source keeps integer labels; chunks expand on
                #            the host right before transfer
            else:
                y_fit = jnp.asarray(ovr_targets(y, classes, dtype=X.dtype))
            res = get_plan(plan).fit(config, mesh, X, y_fit, basis, beta0,
                                     CW=CW, classes=classes, **hooks)
            state = {"basis": basis, "beta": res.beta,
                     "classes": jnp.asarray(classes)}
    finally:
        if ck is not None:
            ck.close()
    extras = {"ckpt": ck.stats()} if ck is not None else None
    return state, FitResult.from_tron(res, solver="tron", plan=plan,
                                      m=int(basis.shape[0]), extras=extras)


@register_solver("linearized", plans={"local"}, needs_basis=True,
                 decision_spec=_spec_nystrom)
def fit_linearized(config, X, y, basis, beta0=None, *, mesh=None, plan=None,
                   key=None, CW=None):
    """Formulation (3) baseline: eigendecompose W, solve the linear machine."""
    del mesh, key, CW
    _reject_ovr(X, y, "linearized")
    if beta0 is not None:
        raise ValueError("solver 'linearized' optimizes in w-space, not "
                         "beta-space; warm-starting from beta0 is not "
                         "supported (use solver='tron')")
    plan = plan or config.plan
    res = lin.solve_linearized(X, y, basis, lam=config.lam, loss=config.loss,
                               kernel=config.kernel,
                               rank=config.linearized_rank, cfg=config.tron,
                               backend=config.backend)
    state = {"basis": basis, "beta": res.beta}
    extras = {"w": res.w, "time_eig_and_A": res.time_eig_and_A,
              "time_solve": res.time_solve, "linearized": res}
    return state, FitResult.from_tron(res.stats, solver="linearized",
                                      plan=plan, m=int(basis.shape[0]),
                                      extras=extras)


@register_solver("rff",
                 plans={"local", "shard_map", "auto", "otf", "otf_shard",
                        "stream"},
                 decision_spec=_spec_rff)
def fit_rff(config, X, y, basis=None, beta0=None, *, mesh=None, plan=None,
            key=None, CW=None):
    """Random Fourier features, then the SAME formulation-(4) machinery.

    phi(X) with a linear kernel and identity basis gives C = phi(X), W = I —
    so every execution plan (including shard_map and on-the-fly) applies
    unchanged. ``basis`` may be a pre-sampled :class:`RFFBasis`; by default
    ``config.rff_features`` frequencies are drawn from ``key``.
    """
    del CW
    plan = plan or config.plan
    from repro.data.chunks import ChunkSource
    if isinstance(X, ChunkSource):
        raise TypeError(
            "solver 'rff' maps X through phi(X) up front, which needs X in "
            "memory; pass arrays (plan 'stream' still chunks the phi(X) "
            "solve), or use solver 'tron' for fully out-of-core training")
    _reject_ovr(X, y, "rff")
    if basis is None:
        basis = rffm.sample_rff(_key(config, key), X.shape[1],
                                config.rff_features, config.kernel.sigma)
    elif not isinstance(basis, rffm.RFFBasis):
        raise TypeError("solver 'rff' expects an RFFBasis (or None to sample "
                        "one); got an array — use solver 'tron' for Nystrom "
                        "point bases")
    A = rffm.rff_features(X, basis)
    m = basis.m
    eye = jnp.eye(m, dtype=A.dtype)
    beta0 = _zeros_like_beta(A, m, beta0)
    lin_cfg = config.replace(kernel=KernelSpec("linear"), backend="jnp")
    CW = (A, eye) if plan == "local" else None
    res = get_plan(plan).fit(lin_cfg, mesh, A, y, eye, beta0, CW=CW)
    state = {"omega": basis.omega, "phase": basis.phase, "beta": res.beta}
    return state, FitResult.from_tron(res, solver="rff", plan=plan, m=m)


@register_solver("ppacksvm", plans={"local"},
                 decision_spec=_spec_nystrom)
def fit_ppacksvm(config, X, y, basis=None, beta0=None, *, mesh=None,
                 plan=None, key=None, CW=None):
    """P-packSVM baseline: packed Pegasos SGD in the full kernel space.

    Hinge loss is built into the update rule (``config.loss`` is ignored);
    the support set is the training data itself, so the saved state scales
    with n, not m — the serving-cost contrast the paper draws.
    """
    del mesh, CW, beta0, basis
    _reject_ovr(X, y, "ppacksvm")
    plan = plan or config.plan
    res = pps.ppacksvm(_key(config, key), X, y, lam=config.lam,
                       kernel=config.kernel, epochs=config.ppack_epochs,
                       pack_size=config.ppack_size, backend=config.backend)
    state = {"basis": X, "beta": res.alpha}
    fit = FitResult(solver="ppacksvm", plan=plan, m=int(X.shape[0]),
                    f=float("nan"), gnorm=float("nan"),
                    n_iter=res.n_rounds, n_fg=0, n_hd=0, converged=True,
                    extras={"n_rounds": res.n_rounds})
    return state, fit
