import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination with ShapeDtypeStruct inputs (zero allocation), record
memory_analysis / cost_analysis / collective-bytes for the roofline.

The two lines above MUST precede any jax import: jax locks the device count
at first init. Do not set this flag globally — tests/benches see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]

Results cached as benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.core import compat
from repro.launch.mesh import make_production_mesh
from repro.models.common import unrolled_scans, unzip
from repro.models.config import INPUT_SHAPES, ArchConfig, ShapeSpec
from repro.models.registry import cache_specs, input_specs, make_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.sharding.ctx import use_shard_hints
from repro.sharding.partitioning import (batch_specs, cache_pspecs,
                                         fsdp_axes, param_specs)
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link
TRAIN_MICROBATCHES = 8   # gradient-accumulation factor for train shapes

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
                "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str):
    """Sum RESULT bytes of every collective in the partitioned HLO (per-device
    program, consistent with cost_analysis being per-partition)."""
    per_kind = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d.strip():
                nbytes *= int(d)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
    return per_kind


def adapt_for_shape(cfg: ArchConfig, shape: ShapeSpec) -> ArchConfig:
    """long_500k: full-attention families switch to the sliding-window
    variant (sub-quadratic decode via ring cache); ssm/hybrid run native.
    DESIGN.md §Arch-applicability records this policy."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm", "audio"):
        return cfg.with_(attention_variant="sliding", window=8192)
    return cfg


def opt_config(n_params: int) -> AdamWConfig:
    """bf16 moments above 20B params so optimizer state fits 16GB/chip."""
    return AdamWConfig(state_dtype="bfloat16" if n_params > 20e9 else "float32")


def _tree_size(tree) -> int:
    import math
    return sum(math.prod(x.shape) for x in jax.tree.leaves(tree))


def lower_step(cfg: ArchConfig, shape: ShapeSpec, mesh, micro_override=None):
    """Build shardings and lower the appropriate step. Returns jax Lowered."""
    model = make_model(cfg, max_dec_seq=shape.seq_len)
    annotated = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sds, axes = unzip(annotated)
    n_params = _tree_size(params_sds)
    p_specs = param_specs(axes, mesh)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))

    batch_sds = input_specs(cfg, shape)
    fa = fsdp_axes(mesh)
    fsdp_size = 1
    for a in fa:
        fsdp_size *= mesh.shape[a]

    if shape.kind == "train":
        ocfg = opt_config(n_params)
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_sds)
        opt_shard = {"m": p_shard, "v": p_shard,
                     "step": NamedSharding(mesh, P())}
        b_specs = batch_specs(batch_sds, mesh)
        b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                               is_leaf=lambda x: isinstance(x, P))
        micro = TRAIN_MICROBATCHES if shape.global_batch % TRAIN_MICROBATCHES == 0 else 1
        if micro_override is not None:
            micro = micro_override
        acc_dt = jnp.bfloat16 if n_params > 20e9 else None
        step = make_train_step(model, ocfg, microbatches=micro,
                               acc_dtype=acc_dt)
        with mesh, use_shard_hints(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, opt_shard, b_shard),
                out_shardings=(p_shard, opt_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        b_specs = batch_specs(batch_sds, mesh)
        b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                               is_leaf=lambda x: isinstance(x, P))
        step = make_prefill_step(model)
        with mesh, use_shard_hints(mesh):
            lowered = jax.jit(
                step, in_shardings=(p_shard, b_shard), out_shardings=None,
            ).lower(params_sds, batch_sds)
    else:  # decode
        cache_sds = cache_specs(cfg, shape)
        shard_seq = shape.global_batch < fsdp_size
        c_specs = cache_pspecs(cache_sds, mesh, shard_seq_over_fsdp=shard_seq)
        c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                               is_leaf=lambda x: isinstance(x, P))
        tok_spec = P(fa) if shape.global_batch >= fsdp_size else P()
        tok_shard = NamedSharding(mesh, P(*tok_spec, None))
        step = make_serve_step(model)
        with mesh, use_shard_hints(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, tok_shard, c_shard),
                out_shardings=(None, None, c_shard),
                donate_argnums=(2,),
            ).lower(params_sds, batch_sds["tokens"], cache_sds)
    return lowered, n_params


def _probe_cost(cfg: ArchConfig, shape: ShapeSpec, mesh, k_periods: int,
                micro_override: int | None = None):
    """Compile a k-period model with ALL scans unrolled -> exact op counts.

    Train shapes are probed with ONE microbatch at global_batch/micro and
    scaled back up (per-microbatch cost is shape-identical; only the tiny
    optimizer update is overcounted by the factor) — keeps the fully
    unrolled probe HLO ~8x smaller."""
    from repro.models.transformer import period_len
    pl_ = 1 if cfg.is_encdec else period_len(cfg)
    probe = cfg.with_(n_layers=pl_ * k_periods,
                      encoder_layers=k_periods if cfg.is_encdec else 0,
                      # per-period cost is pps-invariant (remat recomputes
                      # each period exactly once either way)
                      periods_per_scan_step=1)
    scale = 1
    pshape = shape
    eff_micro = micro_override or TRAIN_MICROBATCHES
    if shape.kind == "train" and shape.global_batch % eff_micro == 0:
        scale = eff_micro
        pshape = dataclasses.replace(
            shape, global_batch=shape.global_batch // eff_micro)
    with unrolled_scans():
        lowered, _ = lower_step(probe, pshape, mesh, micro_override=1)
        compiled = lowered.compile()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)) * scale,
        "bytes": float(cost.get("bytes accessed", 0.0)) * scale,
        "colls": {k: v * scale for k, v in colls.items()},
        "n_coll": len(_COLL_RE.findall(hlo)) * scale,
    }


def extrapolated_cost(cfg: ArchConfig, shape: ShapeSpec, mesh,
                      micro_override: int | None = None) -> dict:
    """cost(full depth) = c1 + (P-1) * (c2 - c1), exact if per-period cost is
    depth-invariant (it is: identical period structure)."""
    from repro.models.transformer import n_periods
    P_full = cfg.encoder_layers if cfg.is_encdec else n_periods(cfg)
    c1 = _probe_cost(cfg, shape, mesh, 1, micro_override=micro_override)
    c2 = _probe_cost(cfg, shape, mesh, 2, micro_override=micro_override)
    scale = P_full - 1
    kinds = set(c1["colls"]) | set(c2["colls"])
    colls = {k: max(c1["colls"].get(k, 0) +
                    scale * (c2["colls"].get(k, 0) - c1["colls"].get(k, 0)), 0)
             for k in kinds}
    return {
        "flops": max(c1["flops"] + scale * (c2["flops"] - c1["flops"]), 0.0),
        "bytes": max(c1["bytes"] + scale * (c2["bytes"] - c1["bytes"]), 0.0),
        "colls": colls,
        "n_coll": max(c1["n_coll"] + scale * (c2["n_coll"] - c1["n_coll"]), 0),
    }


def modeled_traffic(cfg: ArchConfig, shape: ShapeSpec, n_params: int,
                    n_chips: int) -> float:
    """Streaming LOWER BOUND on per-device HBM traffic for one step.

    The HLO 'bytes accessed' metric assumes every intermediate round-trips
    HBM (no fusion) — a loose upper bound. This models the minimum:
    parameters/optimizer state streamed once per use, one saved activation
    per period (remat), logits, KV/state cache read+write for decode.
    True traffic lies between the two; both are reported.
    """
    from repro.models.registry import cache_specs as _cs
    from repro.models.transformer import n_periods, period_len
    dt = 2 if cfg.dtype == "bfloat16" else 4
    pb = n_params * dt / n_chips
    B, S, d = shape.global_batch, shape.seq_len, cfg.d_model
    Pn = cfg.encoder_layers if cfg.is_encdec else n_periods(cfg)
    act = B * S * d * dt / n_chips
    if shape.kind == "train":
        ob = n_params * (2 if n_params > 20e9 else 4) * 2 / n_chips
        logits = B * S * cfg.vocab_padded * dt / n_chips
        # params: fwd read + bwd read + remat read + grad w/r + update write
        return pb * 6 + ob * 2 + act * Pn * 3 + logits * 3
    if shape.kind == "prefill":
        logits_last = B * cfg.vocab_padded * dt / n_chips
        return pb + act * Pn * 2 + logits_last
    # decode: params + cache r/w dominate
    import math
    cache = _cs(cfg, shape)
    cb = sum(math.prod(x.shape) * jnp.dtype(x.dtype).itemsize
             for x in jax.tree.leaves(cache)) / n_chips
    return pb + cb * 2 + B * d * dt * Pn * 2 / n_chips


def modeled_peak_gib(cfg: ArchConfig, shape: ShapeSpec, n_params: int,
                     mesh, micro: int | None = None) -> float:
    """Analytic per-device peak for TPU bf16 semantics.

    The XLA-CPU ``memory_analysis`` widens bf16 buffers to f32 (CPUs lack
    native bf16), overstating the remat-saved activation stacks ~2x; this
    model gives the TPU-accurate estimate (both are reported).
    Terms: params + optimizer moments + grad accumulator + per-micro grads
    + remat-saved carry stack (sharded over fsdp only) + logits + caches.
    """
    from repro.models.registry import cache_specs as _cs
    from repro.models.transformer import n_periods
    fa = fsdp_axes(mesh)
    fsdp_sz = 1
    for a in fa:
        fsdp_sz *= mesh.shape[a]
    chips = mesh.devices.size
    dt = 2
    B, S, d = shape.global_batch, shape.seq_len, cfg.d_model
    Pn = cfg.encoder_layers + cfg.n_layers if cfg.is_encdec else n_periods(cfg)
    pl_ = 1 if cfg.is_encdec else (cfg.attn_period if cfg.family == "hybrid" else 1)
    params = n_params * dt / chips
    total = params
    if shape.kind == "train":
        big = n_params > 20e9
        total += n_params * (2 if big else 4) * 2 / chips        # m, v
        total += n_params * (2 if big else 4) / chips            # grad acc
        total += params                                          # micro grads
        Bm = max(B // (micro or TRAIN_MICROBATCHES), 1)
        # saved carry stack: one h per pps periods; batch-sharded, plus the
        # model axis when cfg.shard_carry
        carry_div = fsdp_sz * (mesh.shape.get("model", 1)
                               if cfg.shard_carry else 1)
        pps = max(cfg.periods_per_scan_step, 1)
        total += Pn * pl_ * Bm * S * d * dt / carry_div / pps
        total += Bm * S * cfg.vocab_padded * dt / chips * 3      # logits f+b
        total += 2 * Bm * S * d * dt / fsdp_sz * 4               # live acts
    elif shape.kind == "prefill":
        total += 4 * B * S * d * dt / fsdp_sz                    # live acts
        total += B * cfg.vocab_padded * dt / chips
    else:
        import math
        cache = _cs(cfg, shape)
        total += sum(math.prod(x.shape) * jnp.dtype(x.dtype).itemsize
                     for x in jax.tree.leaves(cache)) / chips    # donated
    return round(total / 2 ** 30, 3)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, probe_costs: bool = True,
               cfg_override: dict | None = None,
               micro_override: int | None = None) -> dict:
    """cfg_override / micro_override: hillclimb knobs (EXPERIMENTS.md §Perf)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_for_shape(get_arch(arch), shape)
    if cfg_override:
        cfg = cfg.with_(**cfg_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    t0 = time.time()
    lowered, n_params = lower_step(cfg, shape, mesh,
                                   micro_override=micro_override)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()

    if probe_costs:
        cost = extrapolated_cost(cfg, shape, mesh,
                                 micro_override=micro_override)
        flops_dev, bytes_dev = cost["flops"], cost["bytes"]
        colls, n_coll = cost["colls"], cost["n_coll"]
    else:   # raw (while bodies counted once) — kept for debugging
        ca = compat.cost_analysis(compiled)
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        colls = collective_bytes(hlo)
        n_coll = len(_COLL_RE.findall(hlo))
    coll_dev = float(sum(colls.values()))
    mem_lb = modeled_traffic(cfg, shape, n_params, n_chips)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "n_params": n_params,
        "n_chips": int(n_chips),
        "attention_variant": cfg.attention_variant,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "alias_bytes_per_device": int(mem.alias_size_in_bytes),
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes +
                 mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
            "modeled_peak_gib_tpu": modeled_peak_gib(cfg, shape, n_params,
                                                     mesh, micro_override),
        },
        "cost": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "modeled_min_bytes_per_device": mem_lb,
            "collective_bytes_per_device": coll_dev,
            "collectives_by_kind": colls,
            "n_collective_ops": n_coll,
        },
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": mem_lb / HBM_BW,              # streaming lower bound
            "memory_s_upper": bytes_dev / HBM_BW,     # unfused HLO upper bound
            "collective_s": coll_dev / ICI_BW,
        },
    }
    r = result["roofline"]
    result["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: r[k])
    if verbose:
        print(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                out = RESULTS_DIR / f"{tag}.json"
                if out.exists() and not args.force:
                    print(f"[skip] {tag} (cached)")
                    continue
                print(f"[run ] {tag}", flush=True)
                try:
                    res = dryrun_one(arch, shape, multi_pod=mp, verbose=False)
                    out.write_text(json.dumps(res, indent=2))
                    r = res["roofline"]
                    print(f"       ok: compile={res['compile_s']}s "
                          f"peak={res['memory']['peak_estimate_gib']}GiB "
                          f"(tpu-model {res['memory']['modeled_peak_gib_tpu']}GiB) "
                          f"dominant={r['dominant']}", flush=True)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((tag, repr(e)))
    if failures:
        print("FAILURES:")
        for tag, e in failures:
            print(" ", tag, e)
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
