"""Distributed Nystrom kernel-machine training driver (the paper's system),
config-driven through the unified ``repro.api.KernelMachine``.

Single-host CPU example (1 device -> trivial mesh):
  PYTHONPATH=src python -m repro.launch.kernel_train --dataset covtype \
      --scale 0.01 --m 512 --basis auto --plan auto

Multi-device simulation:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.kernel_train --mesh 4,2 --plan shard_map

Out-of-core streaming from a shard directory (written by
``repro.data.chunks.save_chunks``; ``--export-chunks`` writes the chosen
synthetic dataset there first, so this one line is a full demo):
  PYTHONPATH=src python -m repro.launch.kernel_train --plan stream \
      --data-dir /tmp/covtype_shards --export-chunks --chunk-rows 8192

Multi-host (multi-controller): run the SAME command once per host with
``--coordinator host:port --num-processes P --process-id i`` — the mesh
then spans every process's devices, each host streams only its own
partition of the data, and process 0 owns checkpoints/saves/eval output.
``scripts/launch_multihost.sh`` wraps the local N-process simulation
(fake devices per process via ``--xla_force_host_platform_device_count``).

Any registered solver x plan combination is reachable from the CLI; the
``--solver``/``--plan`` choices below are read from the live registries in
``repro.api.registry``, so a newly registered entry shows up in ``--help``
without touching this file. ``--save`` writes a serving checkpoint for
``repro.launch.kernel_serve``.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import (KernelMachine, MachineConfig, StreamConfig,
                       get_solver)
from repro.core import KernelSpec, TronConfig, select_basis
from repro.core.compat import make_mesh
from repro.data import PAPER_DATASETS, make_dataset, make_multiclass
from repro.data.chunks import (MmapChunkSource, is_partition_dir,
                               open_partition, save_chunks)
from repro.kernels.policy import POLICIES
from repro.launch.cli import plan_choices, registry_epilog, solver_choices
from repro.sharding import multihost


def main():
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        epilog=registry_epilog())
    ap.add_argument("--dataset", default="covtype", choices=list(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--basis", default="auto",
                    dest="strategy", choices=["auto", "random", "kmeans"])
    ap.add_argument("--mesh", default=None,
                    help="comma mesh shape, e.g. 4,2 -> (data, model)")
    ap.add_argument("--solver", default="tron", choices=solver_choices(),
                    help="optimization strategy (live registry: %(choices)s)")
    ap.add_argument("--plan", default="shard_map", choices=plan_choices(),
                    help="execution plan (live registry: %(choices)s)")
    ap.add_argument("--max-iter", type=int, default=200)
    ap.add_argument("--lam", type=float, default=None)
    ap.add_argument("--sigma", type=float, default=None)
    ap.add_argument("--classes", type=int, default=2,
                    help="class count: 2 trains the paper's binary problem; "
                         ">2 generates K-class data (integer labels) and "
                         "trains all one-vs-rest columns in ONE multi-RHS "
                         "TRON pass (solver 'tron' only)")
    ap.add_argument("--data-dir", default=None,
                    help="stream training data from this .npy/.npz shard "
                         "directory (plan 'stream'; see "
                         "repro.data.chunks.save_chunks)")
    ap.add_argument("--export-chunks", action="store_true",
                    help="write the synthetic --dataset into --data-dir as "
                         "mmap-able .npy shards before training")
    ap.add_argument("--chunk-rows", type=int, default=None,
                    help="rows streamed per step under plan 'stream' "
                         "(bounds every intermediate at chunk_rows x m)")
    ap.add_argument("--policy", default="fp32",
                    choices=sorted(POLICIES),
                    help="dtype policy for the kernel compute path "
                         "(bf16/fp16 cut the tile matmul precision; "
                         "accumulation and TRON state stay fp32)")
    ap.add_argument("--quantize", default=None, choices=["int8"],
                    help="store the saved checkpoint's basis/beta as "
                         "symmetric per-column int8 (serving checkpoints "
                         "~4x smaller; load dequantizes transparently)")
    ap.add_argument("--save", default=None,
                    help="checkpoint path for repro.launch.kernel_serve")
    ap.add_argument("--ckpt-interval", type=int, default=0,
                    help="commit a preemption-safe in-training checkpoint "
                         "every N outer TRON iterations (0 = off; solver "
                         "'tron' only)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="step-file directory (default: <--save>.ckpt-steps)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="retain only the newest N step files (0 = all)")
    ap.add_argument("--ckpt-sync", action="store_true",
                    help="commit checkpoints synchronously on the training "
                         "thread instead of the background writer")
    ap.add_argument("--resume", nargs="?", const="", default=None,
                    metavar="DIR",
                    help="restore the newest in-training checkpoint from DIR "
                         "(default: the --ckpt-dir / <--save>.ckpt-steps "
                         "directory) and continue training from it — "
                         "elastically: the device count may differ from the "
                         "run that wrote it")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="process 0's coordination address for "
                         "multi-controller runs (same value on every host)")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="total controller processes (hosts) in this run")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this host's index in [0, --num-processes)")
    ap.add_argument("--supervise", action="store_true",
                    help="run the fit under the fault-tolerant supervisor: "
                         "spawn --num-processes worker processes, restart "
                         "the fleet from the latest committed checkpoint "
                         "when a worker dies (capped exponential backoff + "
                         "jitter; shrinks the fleet after repeated failures "
                         "— requires --ckpt-interval)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="restart budget under --supervise (0 = fail fast)")
    args = ap.parse_args()

    if args.supervise:
        raise SystemExit(_supervise(ap, args))

    if args.num_processes > 1 and not args.coordinator:
        ap.error("--num-processes > 1 needs --coordinator host:port")
    multihost.init(args.coordinator, args.num_processes, args.process_id)
    # every process runs the same program; process 0 owns the console
    say = print if multihost.is_primary() else (lambda *a, **k: None)

    if multihost.active():
        if args.mesh:
            ap.error("--mesh conflicts with multi-controller runs: the "
                     "mesh always spans every process's devices")
        if args.strategy == "kmeans":
            ap.error("--basis kmeans is not routed multi-controller; use "
                     "--basis random (identical on every host)")
        mesh = multihost.spanning_mesh()
    elif args.mesh:
        shape = tuple(int(v) for v in args.mesh.split(","))
        names = ("data", "model")[: len(shape)]
        mesh = make_mesh(shape, names)
    else:
        shape, names = (len(jax.devices()),), ("data",)
        mesh = make_mesh(shape, names)
    model_axis = "model" if "model" in mesh.shape else None
    needs_basis = get_solver(args.solver).needs_basis
    if args.data_dir and args.plan != "stream":
        ap.error("--data-dir streams from disk and requires --plan stream")
    if args.classes > 2 and args.solver != "tron":
        ap.error(f"--classes {args.classes} trains one-vs-rest via the "
                 f"multi-RHS kmvp path, which only solver 'tron' supports")

    ckpt = None
    if args.ckpt_interval > 0 or args.resume is not None:
        from repro.checkpoint import (CheckpointConfig, load_latest,
                                      steps_dir_for)
        if args.solver != "tron":
            ap.error("--ckpt-interval/--resume snapshot TRON iterate state "
                     "and require --solver tron")
        ckpt_dir = args.resume or args.ckpt_dir \
            or (steps_dir_for(args.save) if args.save else None)
        if not ckpt_dir:
            ap.error("checkpointing needs a directory: pass --ckpt-dir, "
                     "--save (steps go next to it), or --resume DIR")
        ckpt = CheckpointConfig(
            dir=ckpt_dir,
            interval=args.ckpt_interval if args.ckpt_interval > 0 else 10,
            keep=args.ckpt_keep, background=not args.ckpt_sync,
            resume=args.resume is not None,
            write=multihost.is_primary())
        if ckpt.resume:
            rs = load_latest(ckpt.dir)   # fail fast, and announce the step
            say(f"[ckpt ] resuming from step {rs.step} ({rs.path})")
        else:
            import os
            if multihost.is_primary():
                os.makedirs(ckpt.dir, exist_ok=True)
            say(f"[ckpt ] step files -> {ckpt.dir} "
                f"every {ckpt.interval} iters "
                f"({'sync' if args.ckpt_sync else 'async'}, "
                f"keep={ckpt.keep})")

    def load_data(key):
        """(X, y, Xt, yt, spec): the paper's binary simulation, or K-class
        integer-label data when --classes > 2 (same mixture geometry)."""
        spec = PAPER_DATASETS[args.dataset]
        if args.classes <= 2:
            return make_dataset(args.dataset, key, scale=args.scale,
                                d_cap=784)
        n = max(int(spec.n * args.scale), 256)
        nt = max(int(spec.n_test * args.scale), 128)
        Xa, ya = make_multiclass(
            key, n + nt, min(spec.d, 784), args.classes,
            clusters_per_class=max(spec.clusters_per_class
                                   // args.classes, 2),
            margin=spec.margin)
        return Xa[:n], ya[:n], Xa[n:], ya[n:], spec

    def build_config(lam, sigma, m):
        return MachineConfig(
            kernel=KernelSpec("gaussian", sigma=sigma), lam=lam,
            solver=args.solver, plan=args.plan,
            tron=TronConfig(max_iter=args.max_iter),
            m=m, rff_features=m, model_axis=model_axis,
            dtype_policy=args.policy,
            stream=StreamConfig(chunk_rows=args.chunk_rows))

    # fail on an invalid solver/plan pair before any data work
    KernelMachine(build_config(1.0, 1.0, args.m), mesh=mesh)

    t0 = time.time()
    spec = PAPER_DATASETS[args.dataset]
    X = y = Xt = yt = None
    if args.data_dir and args.export_chunks:
        dd = Path(args.data_dir)
        if dd.is_dir() and (any(dd.glob("X_*.npy"))
                            or any(dd.glob("shard_*.npz"))):
            say(f"[export] {args.data_dir} already holds shards — "
                f"training on THOSE, not a fresh --dataset {args.dataset} "
                f"--scale {args.scale} export (delete the directory to "
                f"re-export)")
        elif multihost.is_primary():
            Xe, ye, _, _, _ = load_data(jax.random.PRNGKey(0))
            save_chunks(args.data_dir, Xe, ye)
            say(f"[export] wrote {Xe.shape[0]} rows to {args.data_dir} "
                f"({time.time() - t0:.2f}s)")
        multihost.sync("export-chunks")   # shards visible before any reader
    if args.data_dir:
        if is_partition_dir(args.data_dir):
            # this host's slice of a save_partition_dirs layout
            X = open_partition(args.data_dir)
            if args.chunk_rows:
                X = X.with_chunk_rows(args.chunk_rows)
            pid, nproc = X.process_span
            say(f"[step1] partition {args.data_dir}: process {pid}/{nproc} "
                f"of n={X.n} d={X.d} chunks={X.n_chunks} "
                f"({time.time() - t0:.2f}s)")
        else:
            # shared directory: multi-controller runs partition each chunk
            # row-wise per host inside make_stream_closures
            X = MmapChunkSource(args.data_dir, chunk_rows=args.chunk_rows)
            say(f"[step1] streaming {args.data_dir}: n={X.n} d={X.d} "
                f"chunks={X.n_chunks} ({time.time() - t0:.2f}s)")
    else:
        X, y, Xt, yt, spec = load_data(jax.random.PRNGKey(0))
        say(f"[step1] loaded {args.dataset}: n={X.shape[0]} d={X.shape[1]} "
            f"classes={args.classes} ({time.time() - t0:.2f}s)")
    lam = args.lam if args.lam is not None else max(spec.lam * args.scale, 1e-4)
    sigma = args.sigma if args.sigma is not None else max(spec.sigma, 1.0)

    if args.data_dir:
        Xs, ys = X, None           # plan 'stream' shards chunk by chunk
        n_dp = mesh.shape["data"]
        m = (args.m // n_dp) * n_dp if multihost.active() else args.m
    else:
        # keep shard sizes divisible for the in-memory distributed plans
        n_dp = mesh.shape["data"]
        n = (X.shape[0] // (n_dp * 8)) * n_dp * 8
        per = max(n_dp * mesh.shape.get("model", 1), 1)
        m = (args.m // per) * per
        X, y = X[:n], y[:n]
        if multihost.active():
            # leave X/y as host arrays: fit shards them globally, keeping
            # only this process's row block on its devices
            Xs, ys = np.asarray(X), np.asarray(y)
        else:
            Xs = jax.device_put(X, NamedSharding(mesh, P(("data",), None)))
            ys = jax.device_put(y, NamedSharding(mesh, P(("data",))))

    basis = None
    if needs_basis and not args.data_dir and not multihost.active():
        t0 = time.time()
        basis = select_basis(jax.random.PRNGKey(1), Xs, m,
                             strategy=args.strategy, mesh=mesh,
                             data_axes=("data",))
        basis.block_until_ready()
        say(f"[step2] basis: m={m} strategy={args.strategy} "
            f"({time.time() - t0:.2f}s)")
    elif needs_basis and multihost.active() and not args.data_dir:
        say(f"[step2] basis: m={m} sampled in-fit (deterministic on every "
            f"host)")

    km = KernelMachine(build_config(lam, sigma, m), mesh=mesh)

    t0 = time.time()
    km.fit(Xs, ys, basis,          # streaming fit samples a random basis
           checkpoint=ckpt)
    jax.block_until_ready(km.state_["beta"])
    r = km.result_
    say(f"[step3+4] {r.solver}/{r.plan}: f={r.f:.4f} iters={r.n_iter} "
        f"fg={r.n_fg} hd={r.n_hd} converged={r.converged} "
        f"({time.time() - t0:.2f}s)")
    if ckpt is not None:
        cs = r.extras["ckpt"]
        say(f"[ckpt ] wrote {cs['snapshots_written']} step files "
            f"({cs['bytes_written']} bytes, {cs['write_seconds']:.3f}s "
            f"{'sync' if args.ckpt_sync else 'async'}, "
            f"dropped={cs['snapshots_dropped']}, last_step={cs['last_step']}"
            f", errors={cs['errors']}, retries={cs.get('write_retries', 0)}"
            f", io_warnings={cs.get('io_warnings', 0)})")

    if multihost.active():
        _eval_multihost(km, X, y, mesh, args, say)
    elif args.data_dir:
        Xh, yh = X.chunk(0)        # held-in sample; no synthetic test split
        say(f"[eval ] train_acc(chunk0)={km.score(Xh, yh):.4f}")
    else:
        say(f"[eval ] train_acc={km.score(X, y):.4f} "
            f"test_acc={km.score(Xt, yt):.4f}")
    if args.save:
        if multihost.is_primary():
            print(f"[save ] {km.save(args.save, quantize=args.quantize)}")
        multihost.sync("save")     # checkpoint durable before anyone exits
    multihost.sync("done")


def _supervise(ap, args) -> int:
    """The ``--supervise`` branch: relaunch this CLI under the supervisor.

    The parent never initializes a mesh — it is a pure process manager.
    Each worker is this same command line minus the supervision flags,
    plus per-process coordinator flags (multi-process fleets) and
    ``--resume`` once the checkpoint directory holds a committed step.
    """
    import sys

    from repro.sharding.supervisor import (Supervisor, SupervisorConfig,
                                           SupervisorError)

    if args.solver != "tron" or args.ckpt_interval <= 0:
        ap.error("--supervise restarts from committed checkpoints and "
                 "needs --solver tron with --ckpt-interval N")
    if args.process_id != 0 or args.coordinator:
        ap.error("--supervise owns the fleet topology; don't combine it "
                 "with --coordinator/--process-id")
    from repro.checkpoint import steps_dir_for
    ckpt_dir = args.ckpt_dir or (steps_dir_for(args.save) if args.save
                                 else None)
    if not ckpt_dir:
        ap.error("--supervise needs a checkpoint directory: pass "
                 "--ckpt-dir or --save")

    # Child argv = this argv minus the supervision/topology/resume flags
    # (the supervisor decides topology and resume per attempt).
    strip_valued = {"--max-restarts", "--coordinator", "--num-processes",
                    "--process-id"}
    argv, base, i = sys.argv[1:], [], 0
    while i < len(argv):
        tok = argv[i]
        if tok == "--supervise":
            i += 1
        elif tok in strip_valued:
            i += 2
        elif tok == "--resume":
            i += 1
            if i < len(argv) and not argv[i].startswith("--"):
                i += 1                 # nargs="?": swallow the DIR value
        else:
            base.append(tok)
            i += 1

    def build_cmd(pid, nproc, port, resume):
        cmd = [sys.executable, "-m", "repro.launch.kernel_train", *base]
        if nproc > 1:
            cmd += ["--coordinator", f"127.0.0.1:{port}",
                    "--num-processes", str(nproc),
                    "--process-id", str(pid)]
        if resume:
            cmd += ["--resume", ckpt_dir]
        return cmd

    sup = Supervisor(build_cmd, num_processes=args.num_processes,
                     ckpt_dir=ckpt_dir,
                     config=SupervisorConfig(max_restarts=args.max_restarts))
    try:
        result = sup.run()
    except SupervisorError as err:
        print(err)
        return 1
    # surface the winning attempt's process-0 output (the say() lines a
    # non-supervised run would have printed)
    log0 = result.final_attempt["logs"][0]
    try:
        with open(log0, "r", errors="replace") as fh:
            tail = fh.read().splitlines()[-30:]
        for line in tail:
            print(line)
    except OSError:
        pass
    print(f"[supervise] done: restarts={result.restarts} "
          f"processes={result.final_processes}"
          f"{' (shrunk)' if result.shrunk else ''} "
          f"total={result.total_s:.1f}s logs={sup.log_dir}")
    return 0


def _eval_multihost(km, X, y, mesh, args, say) -> None:
    """Score a held-in sample through the process-spanning serving arm.

    The decider plans row-shard their outputs over local devices and so do
    not span processes; the :class:`SpanningServer` does — and doubles as
    a smoke test of the serving arm right after training. Every process
    enters the lockstep rounds with the identical (broadcast) batch, so no
    follower loop is needed.
    """
    from repro.sharding.multihost import SpanningServer
    st = km.state_
    if args.data_dir:
        Xh, yh = X.chunk(0)        # this host's block of global chunk 0
    else:
        Xh, yh = X, y
    Xh = np.asarray(Xh)
    yh = np.asarray(yh)
    ne = int(multihost.broadcast_from_primary(
        np.asarray([min(Xh.shape[0], 256)], np.int64))[0])
    xb = np.zeros((ne, Xh.shape[1]), Xh.dtype)
    xb[:min(ne, Xh.shape[0])] = Xh[:ne]
    yb = np.zeros((ne,), np.int64)
    yb[:min(ne, yh.shape[0])] = yh[:ne]
    Xh = multihost.broadcast_from_primary(xb)       # process 0's rows win
    yh = multihost.broadcast_from_primary(yb)
    server = SpanningServer(np.asarray(st["basis"]), np.asarray(st["beta"]),
                            km.config.kernel, mesh,
                            backend=km.config.backend,
                            max_batch=min(ne, 64))
    o = np.asarray(server.margins(Xh))
    if o.ndim == 2 and o.shape[1] > 1:
        pred = np.asarray(st["classes"])[np.argmax(o, axis=1)]
    else:
        pred = np.where(o.ravel() > 0, 1, -1)
    say(f"[eval ] train_acc({ne} rows via spanning server)="
        f"{float((pred == yh).mean()):.4f} "
        f"xhost_bytes/eval={server.collective_payload_bytes()}")


if __name__ == "__main__":
    main()
