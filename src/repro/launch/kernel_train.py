"""Distributed Nystrom kernel-machine training driver (the paper's system).

Single-host CPU example (1 device -> trivial mesh):
  PYTHONPATH=src python -m repro.launch.kernel_train --dataset covtype \
      --scale 0.01 --m 512 --strategy auto

Multi-device simulation:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.kernel_train --mesh 4,2 ...
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (DistConfig, DistributedNystrom, KernelSpec,
                        TronConfig, predict, select_basis)
from repro.data import PAPER_DATASETS, make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="covtype", choices=list(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "random", "kmeans"])
    ap.add_argument("--mesh", default=None,
                    help="comma mesh shape, e.g. 4,2 -> (data, model)")
    ap.add_argument("--mode", default="shard_map", choices=["shard_map", "auto"])
    ap.add_argument("--no-materialize", action="store_true",
                    help="recompute C on the fly (kernel-caching mode)")
    ap.add_argument("--max-iter", type=int, default=200)
    ap.add_argument("--lam", type=float, default=None)
    ap.add_argument("--sigma", type=float, default=None)
    args = ap.parse_args()

    t0 = time.time()
    X, y, Xt, yt, spec = make_dataset(args.dataset, jax.random.PRNGKey(0),
                                      scale=args.scale, d_cap=784)
    lam = args.lam if args.lam is not None else max(spec.lam * args.scale, 1e-4)
    sigma = args.sigma if args.sigma is not None else max(spec.sigma, 1.0)
    print(f"[step1] loaded {args.dataset}: n={X.shape[0]} d={X.shape[1]} "
          f"({time.time() - t0:.2f}s)")

    if args.mesh:
        shape = tuple(int(v) for v in args.mesh.split(","))
        names = ("data", "model")[: len(shape)]
        mesh = jax.make_mesh(shape, names,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
    else:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))

    # keep shard sizes divisible
    n_dp = mesh.shape["data"]
    n = (X.shape[0] // (n_dp * 8)) * n_dp * 8
    m = (args.m // max(n_dp * (mesh.shape.get("model", 1)), 1)) * \
        max(n_dp * mesh.shape.get("model", 1), 1)
    X, y = X[:n], y[:n]
    Xs = jax.device_put(X, NamedSharding(mesh, P(("data",), None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(("data",))))

    t0 = time.time()
    basis = select_basis(jax.random.PRNGKey(1), Xs, m, strategy=args.strategy,
                         mesh=mesh, data_axes=("data",))
    basis.block_until_ready()
    print(f"[step2] basis: m={m} strategy={args.strategy} "
          f"({time.time() - t0:.2f}s)")

    kern = KernelSpec("gaussian", sigma=sigma)
    dc = DistConfig(data_axes=("data",),
                    model_axis="model" if "model" in mesh.shape else None,
                    mode=args.mode, materialize=not args.no_materialize)
    solver = DistributedNystrom(mesh, lam, "squared_hinge", kern, dc)

    t0 = time.time()
    res = solver.solve(Xs, ys, basis, cfg=TronConfig(max_iter=args.max_iter))
    res.beta.block_until_ready()
    print(f"[step3+4] kernel+TRON: f={float(res.f):.4f} iters={int(res.n_iter)} "
          f"fg={int(res.n_fg)} hd={int(res.n_hd)} converged="
          f"{bool(res.converged)} ({time.time() - t0:.2f}s)")

    o = predict(Xt, basis, res.beta, kern)
    acc = float(jnp.mean(jnp.sign(o) == yt))
    otr = predict(X, basis, res.beta, kern)
    acc_tr = float(jnp.mean(jnp.sign(otr) == y))
    print(f"[eval ] train_acc={acc_tr:.4f} test_acc={acc:.4f}")


if __name__ == "__main__":
    main()
