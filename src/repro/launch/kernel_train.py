"""Distributed Nystrom kernel-machine training driver (the paper's system),
config-driven through the unified ``repro.api.KernelMachine``.

Single-host CPU example (1 device -> trivial mesh):
  PYTHONPATH=src python -m repro.launch.kernel_train --dataset covtype \
      --scale 0.01 --m 512 --basis auto --plan auto

Multi-device simulation:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.kernel_train --mesh 4,2 --plan shard_map

Any registered solver x plan combination is reachable from the CLI
(--solver tron|linearized|rff|ppacksvm,
 --plan local|shard_map|auto|otf|otf_shard — otf_shard is the fused
 mesh-sharded on-the-fly plan: no (n/p, m) C block on any device);
--save writes a serving checkpoint for repro.launch.kernel_serve.
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import (KernelMachine, MachineConfig, available_plans,
                       available_solvers, get_solver)
from repro.core import KernelSpec, TronConfig, select_basis
from repro.core.compat import make_mesh
from repro.data import PAPER_DATASETS, make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="covtype", choices=list(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--basis", default="auto",
                    dest="strategy", choices=["auto", "random", "kmeans"])
    ap.add_argument("--mesh", default=None,
                    help="comma mesh shape, e.g. 4,2 -> (data, model)")
    ap.add_argument("--solver", default="tron", choices=available_solvers())
    ap.add_argument("--plan", default="shard_map", choices=available_plans())
    ap.add_argument("--max-iter", type=int, default=200)
    ap.add_argument("--lam", type=float, default=None)
    ap.add_argument("--sigma", type=float, default=None)
    ap.add_argument("--save", default=None,
                    help="checkpoint path for repro.launch.kernel_serve")
    args = ap.parse_args()

    if args.mesh:
        shape = tuple(int(v) for v in args.mesh.split(","))
        names = ("data", "model")[: len(shape)]
    else:
        shape, names = (len(jax.devices()),), ("data",)
    mesh = make_mesh(shape, names)
    model_axis = "model" if "model" in mesh.shape else None
    needs_basis = get_solver(args.solver).needs_basis

    def build_config(lam, sigma, m):
        return MachineConfig(
            kernel=KernelSpec("gaussian", sigma=sigma), lam=lam,
            solver=args.solver, plan=args.plan,
            tron=TronConfig(max_iter=args.max_iter),
            rff_features=m, model_axis=model_axis)

    # fail on an invalid solver/plan pair before any data work
    KernelMachine(build_config(1.0, 1.0, args.m), mesh=mesh)

    t0 = time.time()
    X, y, Xt, yt, spec = make_dataset(args.dataset, jax.random.PRNGKey(0),
                                      scale=args.scale, d_cap=784)
    lam = args.lam if args.lam is not None else max(spec.lam * args.scale, 1e-4)
    sigma = args.sigma if args.sigma is not None else max(spec.sigma, 1.0)
    print(f"[step1] loaded {args.dataset}: n={X.shape[0]} d={X.shape[1]} "
          f"({time.time() - t0:.2f}s)")

    # keep shard sizes divisible
    n_dp = mesh.shape["data"]
    n = (X.shape[0] // (n_dp * 8)) * n_dp * 8
    per = max(n_dp * mesh.shape.get("model", 1), 1)
    m = (args.m // per) * per
    X, y = X[:n], y[:n]
    Xs = jax.device_put(X, NamedSharding(mesh, P(("data",), None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(("data",))))

    basis = None
    if needs_basis:
        t0 = time.time()
        basis = select_basis(jax.random.PRNGKey(1), Xs, m,
                             strategy=args.strategy, mesh=mesh,
                             data_axes=("data",))
        basis.block_until_ready()
        print(f"[step2] basis: m={m} strategy={args.strategy} "
              f"({time.time() - t0:.2f}s)")

    km = KernelMachine(build_config(lam, sigma, m), mesh=mesh)

    t0 = time.time()
    km.fit(Xs, ys, basis)
    jax.block_until_ready(km.state_["beta"])
    r = km.result_
    print(f"[step3+4] {r.solver}/{r.plan}: f={r.f:.4f} iters={r.n_iter} "
          f"fg={r.n_fg} hd={r.n_hd} converged={r.converged} "
          f"({time.time() - t0:.2f}s)")

    print(f"[eval ] train_acc={km.score(X, y):.4f} "
          f"test_acc={km.score(Xt, yt):.4f}")
    if args.save:
        print(f"[save ] {km.save(args.save)}")


if __name__ == "__main__":
    main()
