"""Batched serving driver for a saved KernelMachine.

Loads a checkpoint written by ``KernelMachine.save`` (any solver), binds a
decision endpoint through the execution-plan registry's decide arms
(``KernelMachine.decider`` — the same engine ``decision_function`` uses,
no private serving math), and drives a synthetic request stream through
it. Requests are padded up to power-of-two batch buckets so the jit cache
holds one executable per bucket instead of one per request size — the
standard shape-bucketing trick for latency-stable serving. Multiclass
machines serve all K per-class margins in ONE multi-RHS evaluation per
batch (β is the (m, K) block the kmvp kernels contract in one pass).

A ``stream``-trained machine serves through the ``local`` decide arm by
default (request batches are small and in memory; the host-driven chunk
pipeline is for scoring datasets, not requests) — the plan-override
symmetry the registry exists for. Pass ``--plan`` to pick any arm
explicitly (e.g. ``otf_shard`` to serve huge-m machines without ever
materializing the request gram).

  PYTHONPATH=src python -m repro.launch.kernel_serve --ckpt machine.npz \
      --requests 64 --max-batch 256

  # end-to-end self-test: train small machines (local + stream plans),
  # save, load, serve, and check served outputs equal decision_function
  PYTHONPATH=src python -m repro.launch.kernel_serve --selftest
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelMachine, MachineConfig


def _bucket(n: int, max_batch: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return min(b, max_batch)


def _serving_plan(km: KernelMachine, plan: Optional[str]) -> str:
    """Resolve which decide arm serves request batches. The stream arm is
    host-driven chunk I/O — wrong shape for latency serving — so stream
    machines flip to the dense local arm unless overridden."""
    plan = plan or km.config.plan
    if plan == "stream":
        plan = "local"
    return plan


class ServingEndpoint:
    """jit-cached batched margins over a loaded machine, one plan arm.

    One compiled executable per bucket size; the decide closure (state
    arrays, plan, mesh) is closed over as jit constants-by-reference, so
    recompilation only happens on new bucket sizes, never per request.
    """

    def __init__(self, km: KernelMachine, max_batch: int = 256,
                 plan: Optional[str] = None, backend: Optional[str] = None):
        self.km = km
        self.max_batch = max_batch
        self.plan = _serving_plan(km, plan)
        self._decide = km.decider(plan=self.plan, backend=backend)
        self._compiled = {}

    def _fn(self):
        return jax.jit(self._decide)

    def __call__(self, X) -> jnp.ndarray:
        X = jnp.asarray(X)
        n = X.shape[0]
        if n > self.max_batch:          # split oversize requests
            parts = [self(X[i:i + self.max_batch])
                     for i in range(0, n, self.max_batch)]
            return jnp.concatenate(parts)
        b = _bucket(n, self.max_batch)
        if b not in self._compiled:
            self._compiled[b] = self._fn()
        Xp = jnp.pad(X, ((0, b - n), (0, 0)))
        return self._compiled[b](Xp)[:n]

    @property
    def n_executables(self) -> int:
        return len(self._compiled)


def _train_demo_machine(path: str, n: int = 2048, m: int = 64,
                        classes: int = 2, plan: str = "local") -> str:
    from repro.core import KernelSpec, TronConfig, random_basis
    from repro.data import make_classification, make_multiclass

    if classes > 2:    # integer labels -> one multi-RHS one-vs-rest fit
        X, y = make_multiclass(jax.random.PRNGKey(0), n, 16, classes,
                               clusters_per_class=2)
    else:
        X, y = make_classification(jax.random.PRNGKey(0), n, 16,
                                   clusters_per_class=4)
    basis = random_basis(jax.random.PRNGKey(1), X, m)
    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=1.0,
                           plan=plan, tron=TronConfig(max_iter=60))
    km = KernelMachine(config).fit(X, y, basis)
    km.save(path)
    print(f"[train] demo machine: m={m} classes={classes} plan={plan} "
          f"train_acc={km.score(X, y):.4f} -> {path}")
    return path


def serve_stream(km: KernelMachine, *, requests: int, max_batch: int,
                 seed: int = 0, d: Optional[int] = None,
                 plan: Optional[str] = None):
    """Drive a random-size request stream; return latency stats."""
    if d is None:
        ref = km.state_.get("basis", km.state_.get("omega"))
        d = ref.shape[1] if "basis" in km.state_ else ref.shape[0]
    endpoint = ServingEndpoint(km, max_batch=max_batch, plan=plan)
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, max_batch + 1, size=requests)
    # warm every bucket so measured latencies are compile-free
    for b in sorted({_bucket(int(s), max_batch) for s in sizes}):
        jax.block_until_ready(endpoint(jnp.zeros((b, d), jnp.float32)))
    lat = []
    for s in sizes:
        Xq = jnp.asarray(rng.standard_normal((int(s), d)), jnp.float32)
        t0 = time.perf_counter()
        jax.block_until_ready(endpoint(Xq))
        lat.append(time.perf_counter() - t0)
    lat_ms = np.sort(np.array(lat)) * 1e3
    stats = {
        "requests": requests,
        "rows": int(sizes.sum()),
        "plan": endpoint.plan,
        "executables": endpoint.n_executables,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "rows_per_s": float(sizes.sum() / max(sum(lat), 1e-9)),
    }
    return endpoint, stats


def _selftest():
    path = "/tmp/repro_kernel_serve_selftest.npz"
    _train_demo_machine(path, n=512, m=32)
    km = KernelMachine.load(path)
    endpoint, stats = serve_stream(km, requests=16, max_batch=64)
    Xq = jax.random.normal(jax.random.PRNGKey(9), (37, 16))
    served = endpoint(Xq)
    direct = km.decision_function(Xq)
    err = float(jnp.max(jnp.abs(served - direct)))
    assert err < 1e-5, f"served != direct decision_function (max {err})"
    print(f"[serve] {stats}")

    # a stream-trained machine must serve too: the endpoint flips its
    # host-driven chunk plan to the local decide arm, and the served
    # margins must match BOTH the local arm and the machine's own
    # (chunked) decision path — the plan-override symmetry in one check
    _train_demo_machine(path, n=512, m=32, plan="stream")
    km = KernelMachine.load(path)
    endpoint = ServingEndpoint(km, max_batch=64)
    assert endpoint.plan == "local", endpoint.plan
    served = endpoint(Xq)
    local = km.decision_function(Xq, plan="local")
    chunked = km.decision_function(Xq)            # plan='stream' from config
    err_l = float(jnp.max(jnp.abs(served - local)))
    err_c = float(jnp.max(jnp.abs(served - jnp.asarray(chunked))))
    assert err_l < 1e-5, f"stream machine served != local arm ({err_l})"
    assert err_c < 1e-5, f"stream machine served != chunked arm ({err_c})"
    print(f"[serve] stream-plan machine served via local arm OK "
          f"(vs chunked decide max diff {err_c:.2e})")

    # multiclass round trip: checkpoint carries classes, served margins
    # are (b, K) from ONE multi-RHS evaluation, argmax labels match predict
    _train_demo_machine(path, n=512, m=32, classes=3)
    km = KernelMachine.load(path)
    endpoint = ServingEndpoint(km, max_batch=64)
    served = endpoint(Xq)
    assert served.shape == (37, 3), served.shape
    labels = km.state_["classes"][jnp.argmax(served, axis=-1)]
    assert bool(jnp.all(labels == km.predict(Xq))), \
        "served argmax labels != km.predict"
    print(f"[selftest] OK: served==direct (max diff {err:.2e}), "
          f"{stats['executables']} executables for {stats['requests']} "
          f"request sizes; stream-plan machine served; multiclass (K=3) "
          f"margins served + argmax labels verified")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default="/tmp/repro_kernel_machine.npz")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--plan", default=None,
                    help="decide arm override (default: the machine's plan; "
                         "stream machines serve via 'local')")
    ap.add_argument("--train-if-missing", action="store_true")
    ap.add_argument("--selftest", action="store_true",
                    help="train->save->load->serve->verify, tiny sizes")
    args = ap.parse_args()

    if args.selftest:
        _selftest()
        return

    import os
    if not os.path.exists(args.ckpt):
        if not args.train_if_missing:
            ap.error(f"{args.ckpt} not found (pass --train-if-missing to "
                     f"bootstrap a demo machine)")
        _train_demo_machine(args.ckpt)
    km = KernelMachine.load(args.ckpt)
    print(f"[load ] solver={km.config.solver} loss={km.config.loss} "
          f"state={ {k: tuple(v.shape) for k, v in km.state_.items()} }")
    _, stats = serve_stream(km, requests=args.requests,
                            max_batch=args.max_batch, plan=args.plan)
    print(f"[serve] {stats}")


if __name__ == "__main__":
    main()
