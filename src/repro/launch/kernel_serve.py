"""Batched serving driver for a saved KernelMachine.

Loads a checkpoint written by ``KernelMachine.save`` (any solver), builds a
jit-compiled decision endpoint, and drives a synthetic request stream
through it. Requests are padded up to power-of-two batch buckets so the
jit cache holds one executable per bucket instead of one per request size —
the standard shape-bucketing trick for latency-stable serving.

  PYTHONPATH=src python -m repro.launch.kernel_serve --ckpt machine.npz \
      --requests 64 --max-batch 256

  # end-to-end self-test: train a small machine on synthetic data, save,
  # load, serve, and check served outputs equal direct decision_function
  PYTHONPATH=src python -m repro.launch.kernel_serve --selftest
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelMachine, MachineConfig


def _bucket(n: int, max_batch: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return min(b, max_batch)


class ServingEndpoint:
    """jit-cached batched ``decision_function`` over a loaded machine.

    One compiled executable per (bucket size); state arrays are closed over
    as jit constants-by-reference, so recompilation only happens on new
    bucket sizes, never per request.
    """

    def __init__(self, km: KernelMachine, max_batch: int = 256):
        self.km = km
        self.max_batch = max_batch
        self._compiled = {}

    def _fn(self):
        km = self.km

        @jax.jit
        def decide(X):
            return km.decision_function(X)

        return decide

    def __call__(self, X) -> jnp.ndarray:
        X = jnp.asarray(X)
        n = X.shape[0]
        if n > self.max_batch:          # split oversize requests
            parts = [self(X[i:i + self.max_batch])
                     for i in range(0, n, self.max_batch)]
            return jnp.concatenate(parts)
        b = _bucket(n, self.max_batch)
        if b not in self._compiled:
            self._compiled[b] = self._fn()
        Xp = jnp.pad(X, ((0, b - n), (0, 0)))
        return self._compiled[b](Xp)[:n]

    @property
    def n_executables(self) -> int:
        return len(self._compiled)


def _train_demo_machine(path: str, n: int = 2048, m: int = 64,
                        classes: int = 2) -> str:
    from repro.core import KernelSpec, TronConfig, random_basis
    from repro.data import make_classification, make_multiclass

    if classes > 2:    # integer labels -> one multi-RHS one-vs-rest fit
        X, y = make_multiclass(jax.random.PRNGKey(0), n, 16, classes,
                               clusters_per_class=2)
    else:
        X, y = make_classification(jax.random.PRNGKey(0), n, 16,
                                   clusters_per_class=4)
    basis = random_basis(jax.random.PRNGKey(1), X, m)
    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=1.0,
                           tron=TronConfig(max_iter=60))
    km = KernelMachine(config).fit(X, y, basis)
    km.save(path)
    print(f"[train] demo machine: m={m} classes={classes} "
          f"train_acc={km.score(X, y):.4f} -> {path}")
    return path


def serve_stream(km: KernelMachine, *, requests: int, max_batch: int,
                 seed: int = 0, d: Optional[int] = None):
    """Drive a random-size request stream; return latency stats."""
    if d is None:
        ref = km.state_.get("basis", km.state_.get("omega"))
        d = ref.shape[1] if "basis" in km.state_ else ref.shape[0]
    endpoint = ServingEndpoint(km, max_batch=max_batch)
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, max_batch + 1, size=requests)
    # warm every bucket so measured latencies are compile-free
    for b in sorted({_bucket(int(s), max_batch) for s in sizes}):
        jax.block_until_ready(endpoint(jnp.zeros((b, d), jnp.float32)))
    lat = []
    for s in sizes:
        Xq = jnp.asarray(rng.standard_normal((int(s), d)), jnp.float32)
        t0 = time.perf_counter()
        jax.block_until_ready(endpoint(Xq))
        lat.append(time.perf_counter() - t0)
    lat_ms = np.sort(np.array(lat)) * 1e3
    stats = {
        "requests": requests,
        "rows": int(sizes.sum()),
        "executables": endpoint.n_executables,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "rows_per_s": float(sizes.sum() / max(sum(lat), 1e-9)),
    }
    return endpoint, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default="/tmp/repro_kernel_machine.npz")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--train-if-missing", action="store_true")
    ap.add_argument("--selftest", action="store_true",
                    help="train->save->load->serve->verify, tiny sizes")
    args = ap.parse_args()

    if args.selftest:
        path = "/tmp/repro_kernel_serve_selftest.npz"
        _train_demo_machine(path, n=512, m=32)
        km = KernelMachine.load(path)
        endpoint, stats = serve_stream(km, requests=16, max_batch=64)
        Xq = jax.random.normal(jax.random.PRNGKey(9), (37, 16))
        served = endpoint(Xq)
        direct = km.decision_function(Xq)
        err = float(jnp.max(jnp.abs(served - direct)))
        assert err < 1e-5, f"served != direct decision_function (max {err})"
        print(f"[serve] {stats}")
        # multiclass round trip: checkpoint carries classes, served margins
        # are (b, K), argmax labels match the direct predict path
        _train_demo_machine(path, n=512, m=32, classes=3)
        km = KernelMachine.load(path)
        endpoint = ServingEndpoint(km, max_batch=64)
        served = endpoint(Xq)
        assert served.shape == (37, 3), served.shape
        labels = km.state_["classes"][jnp.argmax(served, axis=-1)]
        assert bool(jnp.all(labels == km.predict(Xq))), \
            "served argmax labels != km.predict"
        print(f"[selftest] OK: served==direct (max diff {err:.2e}), "
              f"{stats['executables']} executables for {stats['requests']} "
              f"request sizes; multiclass (K=3) margins served + argmax "
              f"labels verified")
        return

    import os
    if not os.path.exists(args.ckpt):
        if not args.train_if_missing:
            ap.error(f"{args.ckpt} not found (pass --train-if-missing to "
                     f"bootstrap a demo machine)")
        _train_demo_machine(args.ckpt)
    km = KernelMachine.load(args.ckpt)
    print(f"[load ] solver={km.config.solver} loss={km.config.loss} "
          f"state={ {k: tuple(v.shape) for k, v in km.state_.items()} }")
    _, stats = serve_stream(km, requests=args.requests,
                            max_batch=args.max_batch)
    print(f"[serve] {stats}")


if __name__ == "__main__":
    main()
