"""Serving driver for saved KernelMachines over the repro.serve engine.

Loads checkpoints written by ``KernelMachine.save`` (any solver), registers
them in a :class:`repro.serve.ModelRegistry` (one bucketed jit-executable
cache per model, decide arms from the execution-plan registry — the same
engine ``decision_function`` uses, no private serving math), precompiles
every batch bucket (``warmup``; ``--no-warmup`` opts out), and drives a
concurrent synthetic client fleet through the asynchronous
continuous-batching :class:`repro.serve.ServeEngine`: queued rows from
many callers coalesce into ONE power-of-two-bucketed dispatch, multi-RHS
margins come back in one pass and are scattered to each caller's future.
Admission control (bounded queue, in-flight cap, per-request timeout)
turns overload into clean rejections.

A ``stream``-trained machine serves through the ``local`` decide arm by
default (request batches are small and in memory; the host-driven chunk
pipeline is for scoring datasets, not requests). Pass ``--plan`` to pick
any arm explicitly (e.g. ``otf_shard`` to serve huge-m machines without
ever materializing the request gram).

  # concurrent load against one machine (the default path)
  PYTHONPATH=src python -m repro.launch.kernel_serve --ckpt machine.npz \
      --clients 8 --requests 64 --max-batch 256

  # several checkpoints served side by side, traffic mixed across them
  PYTHONPATH=src python -m repro.launch.kernel_serve \
      --ckpt a.npz --ckpt b.npz

  # the old single-client request-at-a-time loop
  PYTHONPATH=src python -m repro.launch.kernel_serve --ckpt m.npz --serial

  # end-to-end self-test: train small machines (local + stream plans,
  # binary + multiclass), save, load, serve synchronously AND through the
  # concurrent engine, verify every response
  PYTHONPATH=src python -m repro.launch.kernel_serve --selftest
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelMachine, MachineConfig
from repro.api.infer import BucketedDecider, bucket_rows
from repro.launch.cli import plan_choices, registry_epilog
from repro.serve import (EngineConfig, ModelRegistry, ServeEngine,
                         baseline_target, engine_target, make_workload,
                         percentiles, run_load, serving_plan)
from repro.sharding import multihost

# back-compat aliases: tests and older scripts import these names from here
_bucket = bucket_rows
_serving_plan = serving_plan


class ServingEndpoint(BucketedDecider):
    """Deprecated single-caller shim over :class:`BucketedDecider`.

    The pre-engine synchronous endpoint: one caller, one request at a
    time. New code should register machines in a
    :class:`repro.serve.ModelRegistry` and serve through
    :class:`repro.serve.ServeEngine`; this class remains as the
    request-at-a-time baseline the SLO harness measures against.
    """

    def __init__(self, km: KernelMachine, max_batch: int = 256,
                 plan: Optional[str] = None, backend: Optional[str] = None):
        self.km = km
        self.plan = serving_plan(km, plan)
        super().__init__(km.decider(plan=self.plan, backend=backend),
                         max_batch=max_batch)


def _train_demo_machine(path: str, n: int = 2048, m: int = 64,
                        classes: int = 2, plan: str = "local") -> str:
    from repro.core import KernelSpec, TronConfig, random_basis
    from repro.data import make_classification, make_multiclass

    if classes > 2:    # integer labels -> one multi-RHS one-vs-rest fit
        X, y = make_multiclass(jax.random.PRNGKey(0), n, 16, classes,
                               clusters_per_class=2)
    else:
        X, y = make_classification(jax.random.PRNGKey(0), n, 16,
                                   clusters_per_class=4)
    basis = random_basis(jax.random.PRNGKey(1), X, m)
    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=1.0,
                           plan=plan, tron=TronConfig(max_iter=60))
    km = KernelMachine(config).fit(X, y, basis)
    km.save(path)
    print(f"[train] demo machine: m={m} classes={classes} plan={plan} "
          f"train_acc={km.score(X, y):.4f} -> {path}")
    return path


def serve_stream(km: KernelMachine, *, requests: int, max_batch: int,
                 seed: int = 0, d: Optional[int] = None,
                 plan: Optional[str] = None):
    """Single-client request-at-a-time loop; returns latency stats with
    tail percentiles (p50/p95/p99 via the shared serve-metrics helper, so
    this report and the SLO load harness can never disagree)."""
    if d is None:
        from repro.serve.registry import model_dim
        d = model_dim(km)
    endpoint = ServingEndpoint(km, max_batch=max_batch, plan=plan)
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, max_batch + 1, size=requests)
    # warm every bucket so measured latencies are compile-free
    endpoint.warmup(d)
    lat = []
    for s in sizes:
        Xq = jnp.asarray(rng.standard_normal((int(s), d)), jnp.float32)
        t0 = time.perf_counter()
        jax.block_until_ready(endpoint(Xq))
        lat.append(time.perf_counter() - t0)
    stats = {
        "requests": requests,
        "rows": int(sizes.sum()),
        "plan": endpoint.plan,
        "executables": endpoint.n_executables,
        **percentiles(lat),
        "rows_per_s": float(sizes.sum() / max(sum(lat), 1e-9)),
    }
    return endpoint, stats


def build_registry(ckpts, *, max_batch: int, plan: Optional[str] = None,
                   warmup: bool = True) -> ModelRegistry:
    """Load checkpoints into a registry (model names m0, m1, ... in CLI
    order) and optionally precompile every bucket of every model."""
    registry = ModelRegistry(max_batch=max_batch)
    for i, path in enumerate(ckpts):
        entry = registry.load(f"m{i}", path, plan=plan)
        beta = entry.km.state_["beta"]
        print(f"[load ] {entry.name}: {path} solver={entry.km.config.solver} "
              f"plan={entry.plan} d={entry.d} "
              f"K={beta.shape[1] if beta.ndim == 2 else 1}")
    if warmup:
        t0 = time.perf_counter()
        counts = registry.warmup()
        print(f"[warm ] precompiled {sum(counts.values())} executables "
              f"across {len(counts)} models in {time.perf_counter() - t0:.2f}s"
              f" (first-request latency is compile-free)")
    return registry


def serve_concurrent(registry: ModelRegistry, *, clients: int, requests: int,
                     max_batch: int, engine_config: EngineConfig,
                     seed: int = 0):
    """Drive a concurrent mixed-size client fleet through the engine."""
    streams = make_workload(registry, clients=clients,
                            requests_per_client=requests,
                            max_rows=max_batch, seed=seed)
    with ServeEngine(registry, engine_config) as engine:
        report = run_load(engine_target(engine), streams, label="engine")
        snap = engine.metrics.snapshot()   # health read while still live
    stats = {**report.row(),
             "occupancy": round(snap["occupancy"], 4),
             "requests_per_dispatch": round(snap["requests_per_dispatch"], 2),
             "rejection_rate": round(snap["rejection_rate"], 4),
             "health": snap["health"],
             "breaker_opened": snap["breaker_opened"]}
    return report, stats


def _selftest():
    path = "/tmp/repro_kernel_serve_selftest.npz"
    _train_demo_machine(path, n=512, m=32)
    km = KernelMachine.load(path)
    endpoint, stats = serve_stream(km, requests=16, max_batch=64)
    Xq = jax.random.normal(jax.random.PRNGKey(9), (37, 16))
    served = endpoint(Xq)
    direct = km.decision_function(Xq)
    err = float(jnp.max(jnp.abs(served - direct)))
    assert err < 1e-5, f"served != direct decision_function (max {err})"
    print(f"[serve] {stats}")

    # a stream-trained machine must serve too: the endpoint flips its
    # host-driven chunk plan to the local decide arm, and the served
    # margins must match BOTH the local arm and the machine's own
    # (chunked) decision path — the plan-override symmetry in one check
    path_stream = "/tmp/repro_kernel_serve_selftest_stream.npz"
    _train_demo_machine(path_stream, n=512, m=32, plan="stream")
    km_stream = KernelMachine.load(path_stream)
    endpoint = ServingEndpoint(km_stream, max_batch=64)
    assert endpoint.plan == "local", endpoint.plan
    served = endpoint(Xq)
    local = km_stream.decision_function(Xq, plan="local")
    chunked = km_stream.decision_function(Xq)     # plan='stream' from config
    err_l = float(jnp.max(jnp.abs(served - local)))
    err_c = float(jnp.max(jnp.abs(served - jnp.asarray(chunked))))
    assert err_l < 1e-5, f"stream machine served != local arm ({err_l})"
    assert err_c < 1e-5, f"stream machine served != chunked arm ({err_c})"
    print(f"[serve] stream-plan machine served via local arm OK "
          f"(vs chunked decide max diff {err_c:.2e})")

    # multiclass round trip: checkpoint carries classes, served margins
    # are (b, K) from ONE multi-RHS evaluation, argmax labels match predict
    path_mc = "/tmp/repro_kernel_serve_selftest_mc.npz"
    _train_demo_machine(path_mc, n=512, m=32, classes=3)
    km_mc = KernelMachine.load(path_mc)
    endpoint = ServingEndpoint(km_mc, max_batch=64)
    served = endpoint(Xq)
    assert served.shape == (37, 3), served.shape
    labels = km_mc.state_["classes"][jnp.argmax(served, axis=-1)]
    assert bool(jnp.all(labels == km_mc.predict(Xq))), \
        "served argmax labels != km.predict"

    # concurrent engine: all three machines (binary, stream-trained,
    # multiclass) registered side by side, 4 client threads firing a few
    # hundred interleaved mixed-size mixed-K requests — every response
    # must exactly equal its precomputed synchronous reference, and the
    # batcher must actually coalesce (requests per dispatch > 1)
    registry = build_registry([path, path_stream, path_mc],
                              max_batch=64, warmup=True)
    report, cstats = serve_concurrent(
        registry, clients=4, requests=60, max_batch=64,
        engine_config=EngineConfig(max_batch=64, timeout_s=30.0))
    assert report.mismatches == 0, \
        f"{report.mismatches} concurrent responses mismatched their " \
        f"synchronous reference"
    assert report.completed == report.requests, (report.completed,
                                                 report.requests)
    assert cstats["requests_per_dispatch"] > 1.0, \
        f"engine never coalesced (requests/dispatch = " \
        f"{cstats['requests_per_dispatch']})"
    print(f"[serve] concurrent engine OK: {cstats}")

    print(f"[selftest] OK: served==direct (max diff {err:.2e}), "
          f"{stats['executables']} executables; stream-plan machine served; "
          f"multiclass (K=3) margins served + argmax labels verified; "
          f"concurrent engine served {report.completed} requests from "
          f"{report.clients} clients with 0 mismatches "
          f"({cstats['requests_per_dispatch']:.1f} requests/dispatch, "
          f"occupancy {cstats['occupancy']:.2f})")


def serve_multihost(path: str, *, requests: int, max_batch: int,
                    seed: int = 0):
    """One engine fronting the process-spanning mesh (multi-controller).

    Every process loads the same checkpoint and holds its 1/P block of the
    basis/beta rows; process 0 drives the request loop and verifies every
    served batch against a dense single-device reference at 1e-4 rel,
    followers run the lockstep :meth:`SpanningServer.follow` loop until
    released. Returns (served rounds, worst relative diff) — followers
    report (rounds, None).
    """
    from repro.kernels.ops import otf_kmvp_fwd
    from repro.sharding.multihost import SpanningServer
    km = KernelMachine.load(path)
    st = km.state_
    basis = np.asarray(st["basis"])
    beta = np.asarray(st["beta"])
    server = SpanningServer(basis, beta, km.config.kernel,
                            multihost.spanning_mesh(),
                            backend=km.config.backend, max_batch=max_batch)
    nb = server.collective_payload_bytes()
    if not multihost.is_primary():
        return server.follow(), None
    print(f"[load ] {path} solver={km.config.solver} "
          f"plan={km.config.plan} m={basis.shape[0]} d={basis.shape[1]} "
          f"K={beta.shape[1] if beta.ndim == 2 else 1} spanning "
          f"{multihost.process_count()} processes")
    rng = np.random.default_rng(seed)
    worst, rows = 0.0, 0
    for _ in range(requests):
        b = int(rng.integers(1, max_batch + 1))
        Xq = rng.standard_normal((b, server.d)).astype(server.dtype)
        o = np.asarray(server.margins(Xq))
        ref = np.asarray(otf_kmvp_fwd(
            jnp.asarray(Xq), jnp.asarray(basis), jnp.asarray(beta),
            kind=km.config.kernel.kind, sigma=km.config.kernel.sigma,
            backend="jnp", block_rows=None))
        scale = max(float(np.max(np.abs(ref))), 1e-12)
        worst = max(worst, float(np.max(np.abs(o - ref))) / scale)
        rows += b
    server.stop()
    if worst >= 1e-4:
        raise AssertionError(
            f"spanning engine served margins diverged from the dense "
            f"reference: max rel diff {worst:.2e} >= 1e-4")
    print(f"[serve] spanning engine OK: processes="
          f"{multihost.process_count()} requests={requests} rows={rows} "
          f"max_rel_diff={worst:.2e} xhost_bytes/eval={nb}")
    return requests, worst


def main():
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        epilog=registry_epilog())
    ap.add_argument("--ckpt", action="append", default=None,
                    help="checkpoint path (repeat to serve several machines "
                         "side by side from one engine)")
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per client (concurrent) / total (serial)")
    ap.add_argument("--clients", type=int, default=8,
                    help="concurrent client threads driving the engine")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="rows per dispatch: the top batch bucket")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission bound on waiting requests")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request deadline seconds (default: none)")
    ap.add_argument("--plan", default=None, choices=plan_choices(),
                    help="decide arm override (default: each machine's own "
                         "plan; stream machines serve via 'local'; live "
                         "registry: %(choices)s)")
    ap.add_argument("--serial", action="store_true",
                    help="single-client request-at-a-time loop (the "
                         "pre-engine behavior) instead of the concurrent "
                         "engine")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip precompiling batch buckets at startup (first "
                         "request per bucket then pays its compile)")
    ap.add_argument("--train-if-missing", action="store_true")
    ap.add_argument("--selftest", action="store_true",
                    help="train->save->load->serve->verify (synchronous + "
                         "concurrent engine), tiny sizes")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="process 0's coordination address: serve one "
                         "machine from an engine spanning every process")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="total controller processes (hosts) in this run")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this host's index in [0, --num-processes)")
    args = ap.parse_args()

    if args.num_processes > 1 and not args.coordinator:
        ap.error("--num-processes > 1 needs --coordinator host:port")
    multihost.init(args.coordinator, args.num_processes, args.process_id)
    if multihost.active():
        if args.selftest or args.serial:
            ap.error("--selftest/--serial are single-process modes")
        if not args.ckpt or len(args.ckpt) != 1:
            ap.error("multi-controller serving fronts exactly one --ckpt")
        serve_multihost(args.ckpt[0], requests=args.requests,
                        max_batch=args.max_batch)
        return

    if args.selftest:
        _selftest()
        return

    import os
    ckpts = args.ckpt or ["/tmp/repro_kernel_machine.npz"]
    for path in ckpts:
        if not os.path.exists(path):
            if not args.train_if_missing:
                ap.error(f"{path} not found (pass --train-if-missing to "
                         f"bootstrap a demo machine)")
            _train_demo_machine(path)

    if args.serial:
        km = KernelMachine.load(ckpts[0])
        print(f"[load ] solver={km.config.solver} loss={km.config.loss} "
              f"state={ {k: tuple(v.shape) for k, v in km.state_.items()} }")
        _, stats = serve_stream(km, requests=args.requests,
                                max_batch=args.max_batch, plan=args.plan)
        print(f"[serve] {stats}")
        return

    registry = build_registry(ckpts, max_batch=args.max_batch,
                              plan=args.plan, warmup=not args.no_warmup)
    _, stats = serve_concurrent(
        registry, clients=args.clients, requests=args.requests,
        max_batch=args.max_batch,
        engine_config=EngineConfig(max_batch=args.max_batch,
                                   max_queue=args.max_queue,
                                   timeout_s=args.timeout))
    print(f"[serve] {stats}")


if __name__ == "__main__":
    main()
