"""Production mesh factory.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the "pod" axis is a
second data-parallel tier (per-pod gradient reduction happens over ICI; the
pod axis reduction maps to the inter-pod DCI links).

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import math

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for {'multi-pod' if multi_pod else 'single-pod'} "
            f"mesh, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many devices the test host exposes."""
    n = math.prod(shape)
    return make_mesh(shape, axes, devices=jax.devices()[:n])
