"""Shared CLI plumbing for the launch drivers.

``kernel_train`` and ``kernel_serve`` both advertise the live solver/plan
registries in ``--help``; the formatting lives here once so the two can
never drift (a newly registered solver or plan shows up in both drivers
without touching either file).
"""
from __future__ import annotations

from repro.api import available_plans, available_solvers


def registry_epilog() -> str:
    """The ``--help`` epilog enumerating the live registries."""
    return (f"registered solvers: {', '.join(available_solvers())} | "
            f"registered plans: {', '.join(available_plans())} "
            f"(see repro.api.registry; docs/paper_map.md maps each to "
            f"the paper)")


def plan_choices() -> list:
    """Live plan names, for ``choices=`` on a ``--plan`` argument."""
    return available_plans()


def solver_choices() -> list:
    """Live solver names, for ``choices=`` on a ``--solver`` argument."""
    return available_solvers()
