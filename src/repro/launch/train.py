"""LM training driver over the architecture zoo.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 20 --batch 2 --seq 64

``--reduced`` runs the smoke-scale family variant (CPU-friendly); without it
the full config is used (needs real accelerators; the dry-run path covers
full-scale validation in this container).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, get_arch
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.models.transformer import D_VISION
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


def synth_batch(cfg, key, batch, seq):
    kt, kf = jax.random.split(key)
    tokens = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab)
    out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.is_encdec:
        out["frames"] = jax.random.normal(
            kf, (batch, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
    if cfg.n_patches:
        out["patch_embeds"] = jax.random.normal(
            kf, (batch, cfg.n_patches, D_VISION), cfg.jnp_dtype)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = make_model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params / 1e6:.2f}M")

    ocfg = AdamWConfig(lr=args.lr)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    from repro.data.pipeline import synthetic_lm_loader
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((len(jax.devices()), 1), ("data", "model"))
    loader = iter(synthetic_lm_loader(mesh, cfg, args.batch, args.seq, seed=1))
    t0 = time.time()
    for i in range(args.steps):
        batch = next(loader)
        params, opt, metrics = step(params, opt, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['gnorm']):.3f} "
                  f"({time.time() - t0:.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, {"arch": cfg.name})
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
