"""Batched serving driver: prefill-free batched decode with KV/state caches.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.train.steps import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--variant", default=None,
                    choices=[None, "full", "sliding", "nystrom"])
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.variant:
        cfg = cfg.with_(attention_variant=args.variant)
    model = make_model(cfg, max_dec_seq=args.max_seq)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (args.batch, 1), 0, cfg.vocab)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_seq, cfg.d_model),
            cfg.jnp_dtype)
    cache = model.init_cache(params, batch, args.max_seq)
    serve = jax.jit(make_serve_step(model))

    toks = batch["tokens"]
    t0 = time.time()
    generated = [toks]
    for i in range(args.steps):
        toks, logits, cache = serve(params, toks, cache)
        generated.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    seq = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch} steps={args.steps} "
          f"tok/s={args.batch * args.steps / dt:.1f}")
    print("sample token ids:", seq[0, :16].tolist())


if __name__ == "__main__":
    main()
