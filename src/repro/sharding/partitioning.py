"""Logical-axis -> mesh-axis partitioning rules.

Parameters are annotated with logical axis names at creation (models/common
Leaf). Rules map each name to a mesh axis (or None). The standard 2-D layout:

    "embed"  -> fsdp axes (("pod","data") multi-pod, ("data",) single-pod)
    "ffn"/"heads"/"kv"/"vocab"/"ssm_inner" -> "model"  (tensor parallel)
    "experts" -> None (expert weights are 2-D sharded via embed x ffn,
                 which works for ANY expert count — grok's 8 < 16-way axis)

Decode caches shard sequence over "model" (context parallelism) and batch
over fsdp; long_500k (batch=1) shards sequence over BOTH.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "__fsdp__"   # sentinel resolved to the mesh's data axes

DEFAULT_RULES = {
    "embed": FSDP,
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "ffn": "model",
    "ssm_inner": "model",
    "experts": None,
    "layer": None,
    "kv_lora": None,
    "q_lora": None,
    "state": None,
    "ssm_heads": None,
    "head_dim": None,
    "conv": None,
    "vision": None,
    None: None,
}


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def spec_for_axes(axes, mesh: Mesh, rules=None) -> P:
    rules = rules or DEFAULT_RULES
    fa = fsdp_axes(mesh)
    out = []
    for name in axes:
        r = rules.get(name, None)
        out.append(fa if r == FSDP else r)
    return P(*out)


def param_specs(axes_tree, mesh: Mesh, rules=None):
    """Tree of PartitionSpecs from the annotated-axes tree."""
    return jax.tree.map(
        lambda axes: spec_for_axes(axes, mesh, rules),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def param_shardings(axes_tree, mesh: Mesh, rules=None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(axes_tree, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------------- inputs
def batch_specs(batch_tree, mesh: Mesh):
    """Batch inputs: leading (batch) dim over the fsdp axes."""
    fa = fsdp_axes(mesh)

    def spec(x):
        return P(fa, *([None] * (len(x.shape) - 1)))

    return jax.tree.map(spec, batch_tree)


def cache_pspecs(cache_tree, mesh: Mesh, *, shard_seq_over_fsdp: bool = False):
    """Decode-cache sharding. Cache leaves are (L, B, S, ...) for KV/MLA
    caches, (L, B, W, di) for SSM conv, (L, B, H, N, P) for SSM state.

    Axis assignment is divisibility-GUARDED (jit input shardings require
    exact divisibility): batch over fsdp when it divides; dim 2 (sequence /
    heads) over "model" — plus fsdp too when batch=1 (long_500k, context
    parallelism); when dim 2 does not divide (conv windows, whisper's 1500
    encoder frames) the LAST dim (d_inner / H*hd) takes the model axis.
    """
    fa = fsdp_axes(mesh)
    fsdp_sz = 1
    for a in fa:
        fsdp_sz *= mesh.shape[a]
    model_sz = mesh.shape.get("model", 1)

    def spec(x):
        nd = len(x.shape)
        if nd <= 1:
            return P()
        out = [None] * nd
        if not shard_seq_over_fsdp and x.shape[1] % fsdp_sz == 0:
            out[1] = fa
        if nd >= 4:
            seq_ax = (*fa, "model") if shard_seq_over_fsdp else ("model",)
            seq_sz = (fsdp_sz if shard_seq_over_fsdp else 1) * model_sz
            if x.shape[2] % seq_sz == 0:
                out[2] = seq_ax if len(seq_ax) > 1 else "model"
            elif x.shape[-1] % model_sz == 0:
                out[-1] = "model"
        elif nd == 3 and x.shape[-1] % model_sz == 0:
            out[-1] = "model"
        return P(*out)

    return jax.tree.map(spec, cache_tree)
