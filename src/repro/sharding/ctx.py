"""Activation-sharding hint context.

Model code calls ``hint(x, "batch", None, "model")`` at key activations;
outside a ``use_shard_hints(mesh)`` context this is a no-op (tests, single
device), inside it becomes with_sharding_constraint(NamedSharding(mesh, ...)).
The special entry "batch" resolves to the mesh's fsdp axes, entries naming
absent mesh axes resolve to None. Lowering (jit.lower / first call) must
happen inside the context — dryrun.py and the launchers do this.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


@contextlib.contextmanager
def use_shard_hints(mesh: Mesh):
    global _MESH
    old = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = old


def hint(x, *entries):
    if _MESH is None:
        return x
    names = set(_MESH.axis_names)
    spec = []
    for e in entries:
        if e == "batch":
            fa = tuple(a for a in ("pod", "data") if a in names)
            spec.append(fa if fa else None)
        elif e is None:
            spec.append(None)
        elif isinstance(e, tuple):
            t = tuple(a for a in e if a in names)
            spec.append(t if t else None)
        else:
            spec.append(e if e in names else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*spec)))
