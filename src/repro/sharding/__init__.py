from repro.sharding.partitioning import (FSDP, DEFAULT_RULES, spec_for_axes,
                                         param_specs, param_shardings,
                                         batch_specs, cache_pspecs)
