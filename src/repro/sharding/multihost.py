"""Multi-controller (multi-host) execution: the paper's pod-scale shape.

The paper deploys Algorithm 1 as an AllReduce tree over Hadoop nodes, each
node streaming its own disk partition every TRON iteration. The modern
equivalent implemented here is JAX's multi-controller model (one Python
process per host, every process running the *same* program):

* :func:`init` wires the process into the cluster —
  ``jax.distributed.initialize`` plus the CPU collectives backend needed
  for cross-process psums on CPU hosts (simulated pods included).
* :func:`spanning_mesh` builds a mesh over the *global* device list, so
  the existing fused/stream closures (``repro.core.distributed``) run
  unchanged: every ``lax.psum`` inside their shard_map bodies becomes a
  cross-host AllReduce of exactly the same O(m) payload the paper's tree
  carries.
* :func:`put_row_sharded` / :func:`global_rows` /
  :func:`shard_rows_from_replicated` assemble global arrays from
  process-local data (each host contributes only the rows its devices
  own — the per-host shard-directory partition of
  :class:`repro.data.chunks.HostPartition`).
* :class:`SpanningServer` is the serving arm: process 0 fronts an engine
  whose margin evaluation spans the mesh (basis rows partitioned over
  hosts, one O(batch) psum per request); follower processes run
  :meth:`SpanningServer.follow` in lockstep.

Simulation recipe (what ``tests/multihost`` and
``scripts/launch_multihost.sh`` do): run N copies of the same script with
``XLA_FLAGS=--xla_force_host_platform_device_count=K`` exported *before*
jax imports, each calling ``init("127.0.0.1:<port>", N, i)`` — N
single-machine processes then behave exactly like N hosts of a pod.

Process topology is tracked here (set once by :func:`init`) instead of
probing ``jax.process_count()`` so that pure validation helpers
(:func:`check_plan`) stay importable — and testable — without
initializing a backend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.compat import make_mesh, shard_map

# Plans whose training closures are safe over a process-spanning mesh:
# rows-only partitions whose every collective is an O(m) psum. The
# materialized plans (local/shard_map/auto/otf) would need a global C in
# HBM or a 2-D partition neither of which the multi-controller path routes.
MULTIHOST_PLANS = frozenset({"stream", "otf_shard"})


@dataclasses.dataclass(frozen=True)
class HostSpan:
    """This process's slot in the multi-controller topology."""
    process_id: int
    num_processes: int

    def __post_init__(self):
        if self.num_processes < 1:
            raise ValueError(f"num_processes must be >= 1, "
                             f"got {self.num_processes}")
        if not 0 <= self.process_id < self.num_processes:
            raise ValueError(
                f"process_id {self.process_id} out of range "
                f"[0, {self.num_processes})")


_SPAN: Optional[HostSpan] = None


def init(coordinator: Optional[str], num_processes: int,
         process_id: int) -> HostSpan:
    """Join the multi-controller cluster (idempotent for 1 process).

    ``coordinator`` is ``host:port`` of process 0's coordination service
    (every process passes the same address, including process 0 itself).
    Must run before any jax computation touches a backend: the CPU
    collectives implementation is chosen at backend-client creation.
    """
    global _SPAN
    span = HostSpan(int(process_id), int(num_processes))
    if _SPAN is not None:
        if _SPAN != span:
            raise RuntimeError(
                f"multihost already initialized as {_SPAN}, refusing "
                f"re-init as {span}")
        return _SPAN
    if span.num_processes > 1:
        if not coordinator:
            raise ValueError(
                "multi-process init needs a coordinator address "
                "(host:port of process 0)")
        import jax
        # gloo backs cross-process collectives on CPU hosts; it needs the
        # distributed client, so this must NOT be set for single-process
        # runs (the factory would fail at backend creation)
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=span.num_processes,
                                   process_id=span.process_id)
    _SPAN = span
    return _SPAN


def current_span() -> Optional[HostSpan]:
    """The :func:`init`-declared topology, or None outside multihost runs."""
    return _SPAN


def active() -> bool:
    return _SPAN is not None and _SPAN.num_processes > 1


def process_index() -> int:
    return _SPAN.process_id if _SPAN is not None else 0


def process_count() -> int:
    return _SPAN.num_processes if _SPAN is not None else 1


def is_primary() -> bool:
    """True on the process that fronts serving and owns persistence."""
    return process_index() == 0


def _reset_for_tests() -> None:
    """Clear the module topology (unit tests of the validation helpers)."""
    global _SPAN
    _SPAN = None


# --------------------------------------------------------------- validation
def check_plan(plan: str, num_processes: Optional[int] = None) -> None:
    """Reject plan compositions that cannot run multi-controller.

    Called by ``repro.api.registry.validate`` at machine *construction*
    (never deep inside a trace). ``num_processes`` defaults to the live
    topology so single-process runs are never constrained.
    """
    nproc = process_count() if num_processes is None else int(num_processes)
    if nproc > 1 and plan not in MULTIHOST_PLANS:
        raise ValueError(
            f"plan {plan!r} cannot run multi-controller ({nproc} "
            f"processes): it materializes per-device state a "
            f"process-spanning mesh cannot assemble from host-local rows; "
            f"use one of {sorted(MULTIHOST_PLANS)} (rows-only partitions "
            f"whose every collective is one O(m) psum)")


def check_mesh_spans(mesh, num_processes: Optional[int] = None) -> None:
    """Require ``mesh`` to cover every process's devices.

    A local-devices mesh under an active multi-controller topology would
    make each process solve a *different* subproblem while believing it
    solved the global one — fail loudly instead.
    """
    nproc = process_count() if num_processes is None else int(num_processes)
    if nproc <= 1:
        return
    import jax
    if mesh.size != jax.device_count():
        raise ValueError(
            f"multi-controller run ({nproc} processes) needs a mesh over "
            f"all {jax.device_count()} global devices, got one over "
            f"{mesh.size}; build it with "
            f"repro.sharding.multihost.spanning_mesh()")


# ------------------------------------------------------------- mesh/arrays
def spanning_mesh(axis_names: Tuple[str, ...] = ("data",)):
    """A 1-axis (by default) mesh over the *global* device list.

    ``jax.devices()`` orders devices process-major, so contiguous row
    blocks of a ``P(("data",))``-sharded array land on contiguous
    processes — the layout every helper below assumes.
    """
    import jax
    n = jax.device_count()
    shape = (n,) + (1,) * (len(axis_names) - 1)
    return make_mesh(shape, axis_names)


def put_row_sharded(sharding, local_rows: np.ndarray):
    """Global row-sharded array from this process's row block.

    Single-process: a plain ``device_put`` (identical to the historical
    path). Multi-process: every process contributes ``local_rows`` (its
    1/num_processes contiguous block, in process order) and receives the
    non-fully-addressable global array.
    """
    import jax
    if process_count() == 1:
        return jax.device_put(local_rows, sharding)
    return jax.make_array_from_process_local_data(
        sharding, np.ascontiguousarray(local_rows))


def global_rows(local_rows, mesh, data_axes: Tuple[str, ...] = ("data",)):
    """Row-sharded global array over ``mesh`` from per-host row blocks —
    how the in-memory fused plan (``otf_shard``) receives X/y whose rows
    live on different hosts."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    local_rows = np.asarray(local_rows)
    spec = P(tuple(data_axes), *([None] * (local_rows.ndim - 1)))
    return put_row_sharded(NamedSharding(mesh, spec), local_rows)


def shard_rows_from_replicated(arr, mesh,
                               data_axes: Tuple[str, ...] = ("data",)):
    """Row-shard an array every host already holds in full (basis, beta).

    Each process keeps only its contiguous 1/num_processes row block on
    device; the serving arm uses this to partition the basis over hosts.
    """
    arr = np.asarray(arr)
    nproc = process_count()
    if arr.shape[0] % nproc:
        raise ValueError(
            f"cannot row-shard {arr.shape[0]} rows over {nproc} processes "
            f"evenly; pad to a multiple of {nproc}")
    per = arr.shape[0] // nproc
    lo = process_index() * per
    return global_rows(arr[lo:lo + per], mesh, data_axes)


def replicate(arr, mesh):
    """Replicate a host array onto every device of ``mesh`` (valid even
    when the mesh spans processes — all hosts must hold the same value)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.device_put(np.asarray(arr), NamedSharding(mesh, P()))


def broadcast_from_primary(arr) -> np.ndarray:
    """Process 0's value on every process (identity when single-process).

    Every process must call this with a same-shaped, same-dtype array.
    """
    if process_count() == 1:
        return np.asarray(arr)
    from jax.experimental import multihost_utils
    return np.asarray(multihost_utils.broadcast_one_to_all(np.asarray(arr)))


def sum_across_processes(arr: np.ndarray) -> np.ndarray:
    """Elementwise sum of every process's ``arr`` (identity single-process).

    Used where each host holds a disjoint-support contribution to a small
    global array — e.g. basis rows gathered from per-host partition dirs,
    where every global row is owned by exactly one host. All processes
    must call in lockstep with same-shaped arrays.
    """
    arr = np.asarray(arr)
    if process_count() == 1:
        return arr
    from jax.experimental import multihost_utils
    gathered = multihost_utils.process_allgather(arr)
    return np.asarray(gathered).sum(axis=0).astype(arr.dtype)


def sync(tag: str = "barrier") -> None:
    """Cross-process barrier (no-op single-process)."""
    if process_count() == 1:
        return
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices(tag)


# ------------------------------------------------------------- serving arm
class SpanningServer:
    """One engine fronting a process-spanning mesh (the serving arm).

    The prediction map o(x) = k(x, basis)·β is partitioned over *basis
    rows*: host h holds basis/β rows [h·m/P, (h+1)·m/P) and contributes a
    fused partial ``k(X, basis_h)·β_h``; one psum of the (batch[, K])
    partial margins completes every request — O(batch·K) cross-host bytes
    per evaluation, independent of m (the basis never moves after load).

    Multi-controller serving is lockstep SPMD: the primary process calls
    :meth:`margins` per request (broadcasting the batch), every follower
    runs :meth:`follow`, which executes the identical broadcast + psum
    sequence until :meth:`stop`. Degenerates gracefully to a plain local
    decider when single-process (no broadcasts, same jitted psum body).
    """

    _OP_STOP, _OP_MARGINS = 0, 1

    def __init__(self, basis, beta, kernel, mesh, *, backend: str = "jnp",
                 block_rows: Optional[int] = None, max_batch: int = 64,
                 data_axes: Tuple[str, ...] = ("data",)):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.kernels.ops import otf_kmvp_fwd
        basis = np.asarray(basis)
        beta = np.asarray(beta)
        m = basis.shape[0]
        dp = 1
        for ax in data_axes:
            dp *= mesh.shape[ax]
        if m % dp:
            raise ValueError(
                f"SpanningServer partitions basis rows over the mesh: "
                f"m={m} must divide the data extent {dp}")
        check_mesh_spans(mesh)
        self.mesh = mesh
        self.max_batch = int(max_batch)
        self.d = int(basis.shape[1])
        self.n_classes = int(beta.shape[1]) if beta.ndim == 2 else 0
        self.dtype = np.dtype(basis.dtype)
        da = tuple(data_axes)
        kw = dict(kind=kernel.kind, sigma=kernel.sigma, backend=backend,
                  block_rows=block_rows)

        def part(Xq, basis_l, beta_l):
            return jax.lax.psum(otf_kmvp_fwd(Xq, basis_l, beta_l, **kw), da)

        beta_spec = P(da, None) if beta.ndim == 2 else P(da)
        self._body = shard_map(part, mesh=mesh, check_vma=False,
                               in_specs=(P(), P(da, None), beta_spec),
                               out_specs=P())
        self._eval = jax.jit(self._body)
        self._basis = shard_rows_from_replicated(basis, mesh, da)
        self._beta = shard_rows_from_replicated(beta, mesh, da)
        self._stopped = False

    # ------------------------------------------------------------ protocol
    def _round(self, header: np.ndarray, payload: np.ndarray):
        """One lockstep round: broadcast (header, payload), evaluate."""
        header = broadcast_from_primary(header)
        payload = broadcast_from_primary(payload)
        op, rows = int(header[0]), int(header[1])
        if op == self._OP_STOP:
            return None, None
        with self.mesh:
            o = self._eval(payload, self._basis, self._beta)
        return rows, np.asarray(o)

    def _zeros(self):
        return (np.zeros((2,), np.int32),
                np.zeros((self.max_batch, self.d), self.dtype))

    # ------------------------------------------------------------- primary
    def margins(self, X) -> np.ndarray:
        """Margins for a query batch (primary process only). Oversize
        batches split into ``max_batch``-row lockstep rounds."""
        X = np.asarray(X, self.dtype)
        if X.shape[0] > self.max_batch:
            return np.concatenate(
                [self.margins(X[i:i + self.max_batch])
                 for i in range(0, X.shape[0], self.max_batch)])
        rows = X.shape[0]
        pad = np.zeros((self.max_batch, self.d), self.dtype)
        pad[:rows] = X
        _, o = self._round(
            np.asarray([self._OP_MARGINS, rows], np.int32), pad)
        return o[:rows]

    def stop(self) -> None:
        """Release the followers (primary process only)."""
        if self._stopped or process_count() == 1:
            self._stopped = True
            return
        header, payload = self._zeros()
        header[0] = self._OP_STOP
        self._round(header, payload)
        self._stopped = True

    # ------------------------------------------------------------ follower
    def follow(self) -> int:
        """Serve lockstep rounds until the primary stops; returns the
        number of evaluation rounds participated in."""
        served = 0
        while True:
            rows, _ = self._round(*self._zeros())
            if rows is None:
                return served
            served += 1

    # -------------------------------------------------------- introspection
    def collective_payload_bytes(self) -> int:
        """Instrumentation-counted cross-host bytes of ONE margin
        evaluation (the psum payload in the traced jaxpr — measured from
        the program, not claimed)."""
        import jax
        from repro.core.introspect import collective_payload_bytes_jaxpr
        shape = (self.max_batch, self.d)
        closed = jax.make_jaxpr(self._body)(
            jax.ShapeDtypeStruct(shape, self.dtype),
            jax.ShapeDtypeStruct(self._basis.shape, self.dtype),
            jax.ShapeDtypeStruct(self._beta.shape, self.dtype))
        return collective_payload_bytes_jaxpr(closed.jaxpr)
