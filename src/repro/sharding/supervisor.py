"""Automatic fleet recovery: supervise training workers, restart from the
latest committed checkpoint.

The paper runs its AllReduce tree on Hadoop precisely to inherit
Map-Reduce's fault tolerance (§4) — a lost worker's task is re-run, the
job survives. The repo's simulated fleet (PR 8) proves worker death is
*detected* (fail-fast watchdog) and PR 7 proves a human can ``--resume``
bitwise; this module closes the loop so nobody has to be awake: the
:class:`Supervisor` spawns the training processes, watches them with the
same poll-loop idiom as the test rig, and on any worker death tears the
fleet down, waits a capped exponential backoff (with the deterministic
jitter of :class:`repro.util.retry.RetryPolicy`), and relaunches — with
``--resume`` as soon as the checkpoint directory holds a committed step.

Because PR 7's restore is *elastic*, recovery composes with degradation:
after ``shrink_after`` consecutive failures at the current process count
the supervisor shrinks the fleet P → P−1 (down to ``min_processes``) and
keeps going — forward progress on fewer hosts instead of a crash loop on
a persistently bad one. Single-topology restarts stay on PR 7's
canonical-trajectory guarantee: the recovered β is bitwise identical to
an uninterrupted run (tests/test_supervisor.py asserts this end to end).

Deliberately jax-free: the supervisor is a process manager. Children do
the jax work; the parent only needs subprocess, sockets and the stdlib.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional

from repro.util.retry import RetryPolicy

#: build_cmd(process_id, num_processes, port, resume) -> argv for one worker.
#: ``port`` is None for single-process fleets; ``resume`` is True once the
#: checkpoint directory holds a committed step.
BuildCmd = Callable[[int, int, Optional[int], bool], List[str]]


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Restart policy knobs.

    ``max_restarts`` bounds relaunches across the whole run (0 = fail on
    the first death, i.e. PR 8's fail-fast behavior). Backoff before each
    relaunch is ``min(max_backoff_s, backoff_s * backoff_mult**(k-1))``
    for the k-th restart, plus deterministic jitter. ``shrink_after``
    consecutive failures at one process count shrink the fleet by one
    process (elastic degraded mode) down to ``min_processes``;
    ``attempt_timeout_s`` bounds any single attempt's wall time (a hung
    fleet counts as a failure)."""
    max_restarts: int = 3
    backoff_s: float = 0.5
    backoff_mult: float = 2.0
    max_backoff_s: float = 15.0
    jitter: float = 0.1
    poll_s: float = 0.05
    attempt_timeout_s: float = 900.0
    shrink_after: int = 2
    min_processes: int = 1


@dataclasses.dataclass
class SupervisorResult:
    """Outcome + per-attempt records (the fault-recovery benchmark's raw
    material: MTTR = ``death_detect_s``→next spawn = teardown + backoff)."""
    ok: bool
    restarts: int
    final_processes: int
    shrunk: bool
    total_s: float
    attempts: List[Dict[str, Any]]

    @property
    def final_attempt(self) -> Dict[str, Any]:
        return self.attempts[-1]


class SupervisorError(RuntimeError):
    """Raised when the restart budget is exhausted; carries log tails."""

    def __init__(self, message: str, attempts: List[Dict[str, Any]]):
        super().__init__(message)
        self.attempts = attempts


class Supervisor:
    """Spawn, watch, and restart a fleet of training processes.

    ``build_cmd`` maps (process_id, num_processes, port, resume) to one
    worker's argv — ``repro.launch.kernel_train`` builds its own child
    command line here, tests substitute ``python -c`` stubs. ``ckpt_dir``
    is polled (by file name only — no heavy imports) to decide when a
    relaunch can ``--resume``; None means every restart is from scratch.
    ``env`` is the base environment for every worker (default: inherit).
    """

    def __init__(self, build_cmd: BuildCmd, *, num_processes: int = 1,
                 ckpt_dir: Optional[str] = None,
                 config: SupervisorConfig = SupervisorConfig(),
                 env: Optional[dict] = None,
                 log_dir: Optional[str] = None,
                 say: Callable[[str], None] = print,
                 sleep: Callable[[float], None] = time.sleep):
        if num_processes < 1:
            raise ValueError(f"num_processes must be >= 1, got "
                             f"{num_processes}")
        self.build_cmd = build_cmd
        self.num_processes = int(num_processes)
        self.ckpt_dir = ckpt_dir
        self.cfg = config
        self.env = dict(os.environ if env is None else env)
        self.log_dir = log_dir or tempfile.mkdtemp(prefix="supervise-")
        self.say = say
        self.sleep = sleep
        # the backoff schedule reuses RetryPolicy's capped-exponential +
        # deterministic-jitter math; attempts map 1:1 onto retry attempts
        self._backoff = RetryPolicy(
            max_attempts=max(2, config.max_restarts + 1),
            backoff_s=config.backoff_s, backoff_mult=config.backoff_mult,
            max_backoff_s=config.max_backoff_s, jitter=config.jitter)

    # ----------------------------------------------------------- internals
    def latest_step(self) -> Optional[int]:
        """Newest committed step number in ``ckpt_dir`` (by file name —
        the commit protocol guarantees named step files are complete)."""
        if not self.ckpt_dir:
            return None
        import re
        try:
            names = os.listdir(self.ckpt_dir)
        except FileNotFoundError:
            return None
        steps = [int(mm.group(1)) for name in names
                 if (mm := re.match(r"^step-(\d{8})\.npz$", name))]
        return max(steps) if steps else None

    def _log_path(self, attempt: int, pid: int) -> str:
        return os.path.join(self.log_dir, f"attempt{attempt}.proc{pid}.log")

    def _tail(self, path: str, lines: int = 8) -> str:
        try:
            with open(path, "r", errors="replace") as fh:
                return "\n".join(fh.read().splitlines()[-lines:])
        except OSError:
            return "<no log>"

    def _run_attempt(self, attempt: int, nproc: int,
                     resume: bool) -> Dict[str, Any]:
        port = free_port() if nproc > 1 else None
        # captured BEFORE spawning: by the end of the attempt latest_step()
        # reflects the attempt's own commits, not where it started
        resumed_from = self.latest_step() if resume else None
        cmd0 = None
        procs, logs = [], []
        t0 = time.monotonic()
        for pid in range(nproc):
            cmd = self.build_cmd(pid, nproc, port, resume)
            if pid == 0:
                cmd0 = cmd
            log_path = self._log_path(attempt, pid)
            logs.append(log_path)
            fh = open(log_path, "w")
            procs.append(subprocess.Popen(
                cmd, stdout=fh, stderr=subprocess.STDOUT, env=self.env))
            fh.close()               # Popen duped the fd
        self.say(f"[supervise] attempt {attempt}: launched {nproc} "
                 f"process(es)" + (f", resuming from step "
                                   f"{resumed_from}" if resume else
                                   ", fresh start")
                 + (f" ({' '.join(cmd0[:3])} ...)" if cmd0 else ""))
        rcs: List[Optional[int]] = [None] * nproc
        death_detect_s = None
        timed_out = False
        while any(rc is None for rc in rcs):
            for i, p in enumerate(procs):
                if rcs[i] is None:
                    rcs[i] = p.poll()
            if any(rc not in (None, 0) for rc in rcs):
                if death_detect_s is None:
                    death_detect_s = time.monotonic() - t0
                break
            if time.monotonic() - t0 > self.cfg.attempt_timeout_s:
                timed_out = True
                death_detect_s = time.monotonic() - t0
                break
            time.sleep(self.cfg.poll_s)
        # tear down survivors (no-op when everything exited cleanly)
        for i, p in enumerate(procs):
            if p.poll() is None:
                p.kill()
        for i, p in enumerate(procs):
            try:
                p.wait(timeout=30)
            except subprocess.TimeoutExpired:
                pass
            rcs[i] = p.returncode
        teardown_s = (time.monotonic() - t0 - death_detect_s) \
            if death_detect_s is not None else 0.0
        return {
            "attempt": attempt,
            "num_processes": nproc,
            "resumed_from": resumed_from,
            "returncodes": rcs,
            "ok": not timed_out and all(rc == 0 for rc in rcs),
            "timed_out": timed_out,
            "elapsed_s": time.monotonic() - t0,
            "death_detect_s": death_detect_s,
            "teardown_s": teardown_s,
            "backoff_s": 0.0,        # filled in by run() before relaunch
            "logs": logs,
        }

    # ---------------------------------------------------------------- API
    def run(self) -> SupervisorResult:
        t0 = time.monotonic()
        attempts: List[Dict[str, Any]] = []
        restarts = 0
        nproc = self.num_processes
        consecutive = 0               # failures at the current nproc
        shrunk = False
        while True:
            resume = self.latest_step() is not None
            rec = self._run_attempt(len(attempts) + 1, nproc, resume)
            attempts.append(rec)
            if rec["ok"]:
                self.say(f"[supervise] attempt {rec['attempt']} succeeded "
                         f"after {restarts} restart(s)")
                return SupervisorResult(
                    ok=True, restarts=restarts, final_processes=nproc,
                    shrunk=shrunk, total_s=time.monotonic() - t0,
                    attempts=attempts)
            dead = [i for i, rc in enumerate(rec["returncodes"]) if rc != 0]
            why = "timed out" if rec["timed_out"] else (
                f"worker(s) {dead} died "
                f"(returncodes={rec['returncodes']})")
            if restarts >= self.cfg.max_restarts:
                tails = "\n".join(
                    f"--- proc {i} (rc={rec['returncodes'][i]}) ---\n"
                    f"{self._tail(rec['logs'][i])}"
                    for i in range(len(rec["logs"])))
                raise SupervisorError(
                    f"[supervise] giving up: {why} and the restart budget "
                    f"({self.cfg.max_restarts}) is exhausted\n{tails}",
                    attempts)
            restarts += 1
            consecutive += 1
            if consecutive >= self.cfg.shrink_after and \
                    nproc > self.cfg.min_processes:
                nproc -= 1
                consecutive = 0
                shrunk = True
                self.say(f"[supervise] {self.cfg.shrink_after} consecutive "
                         f"failures — shrinking fleet to {nproc} "
                         f"process(es) (elastic degraded mode)")
            delay = self._backoff.delay(min(restarts,
                                            self._backoff.max_attempts - 1),
                                        label=f"supervise-{restarts}")
            rec["backoff_s"] = delay
            step = self.latest_step()
            self.say(f"[supervise] {why}; restarting "
                     + (f"from step {step}" if step is not None
                        else "from scratch")
                     + f" in {delay:.2f}s (restart {restarts}/"
                     f"{self.cfg.max_restarts})")
            self.sleep(delay)
