"""Synthetic data matched to the paper's dataset signatures (appendix/Table 3).

Paper-scale data cannot ship in this container, so each benchmark dataset is
simulated by a generator matched on (n, d, class hardness): a Gaussian
mixture in d dims where cluster count and inter-class overlap control how
many basis points are needed — reproducing the paper's central empirical
regime ('hard datasets need large m', Fig. 1). ``scale`` shrinks n for
CPU-budget runs; full-scale shapes are exercised via the dry-run path only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    n_test: int
    d: int
    lam: float        # paper Table 3 hyperparameters
    sigma: float
    clusters_per_class: int = 8   # hardness knob
    margin: float = 1.0           # inter-class separation (smaller = harder)


# Paper Table 3. CCAT's d=47,236 sparse bag-of-words is represented by a
# dense d capped for CPU; the dry-run path still uses the full d.
PAPER_DATASETS: Dict[str, DatasetSpec] = {
    "vehicle": DatasetSpec("vehicle", 78_823, 19_705, 100, lam=8.0, sigma=2.0,
                           clusters_per_class=6, margin=1.2),
    "covtype": DatasetSpec("covtype", 522_910, 58_102, 54, lam=0.005, sigma=0.09,
                           clusters_per_class=64, margin=0.35),
    "ccat": DatasetSpec("ccat", 781_265, 23_149, 47_236, lam=8.0, sigma=0.7,
                        clusters_per_class=12, margin=0.9),
    "mnist8m": DatasetSpec("mnist8m", 8_000_000, 10_000, 784, lam=8.0, sigma=7.0,
                           clusters_per_class=20, margin=1.1),
}


def make_classification(key: jax.Array, n: int, d: int, *,
                        clusters_per_class: int = 8, margin: float = 1.0,
                        dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Binary Gaussian-mixture classification data; y in {-1, +1}.

    Cluster centers are drawn on a sphere of radius ~sqrt(d)*margin scaled
    down as cluster count rises, so class regions interleave — a nonlinear
    boundary a linear machine cannot fit (the paper's setting).
    """
    kc, kx, ky, ka = jax.random.split(key, 4)
    n_clusters = 2 * clusters_per_class
    centers = jax.random.normal(kc, (n_clusters, d), dtype) * margin
    cls = jax.random.randint(ky, (n,), 0, n_clusters)
    x = centers[cls] + jax.random.normal(kx, (n, d), dtype) * (margin * 0.6 + 0.2)
    y = jnp.where(cls % 2 == 0, 1.0, -1.0).astype(dtype)
    return x, y


def make_multiclass(key: jax.Array, n: int, d: int, n_classes: int, *,
                    clusters_per_class: int = 4, margin: float = 1.0,
                    dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K-class Gaussian-mixture data; integer labels 0..K-1.

    The one-vs-rest workload of the paper's large benchmarks (and of
    EigenPro-style multi-output solvers): passing the integer labels to
    ``KernelMachine.fit`` trains all K classes in one multi-RHS TRON pass.
    Same mixture geometry as :func:`make_classification`, classes assigned
    round-robin over clusters.
    """
    kc, kx, ky = jax.random.split(key, 3)
    n_clusters = n_classes * clusters_per_class
    centers = jax.random.normal(kc, (n_clusters, d), dtype) * margin
    cls = jax.random.randint(ky, (n,), 0, n_clusters)
    x = centers[cls] + jax.random.normal(kx, (n, d), dtype) * (margin * 0.6 + 0.2)
    y = (cls % n_classes).astype(jnp.int32)
    return x, y


def make_dataset(name: str, key: jax.Array, scale: float = 1.0,
                 d_cap: int = 512, dtype=jnp.float32):
    """Simulated (X, y, Xt, yt, spec) for a paper dataset at reduced scale."""
    spec = PAPER_DATASETS[name]
    n = max(int(spec.n * scale), 256)
    nt = max(int(spec.n_test * scale), 128)
    d = min(spec.d, d_cap)
    import zlib
    k1 = jax.random.fold_in(key, zlib.crc32(name.encode()) % (2 ** 31))
    xall, yall = make_classification(
        k1, n + nt, d, clusters_per_class=spec.clusters_per_class,
        margin=spec.margin, dtype=dtype)
    return xall[:n], yall[:n], xall[n:], yall[n:], spec


def make_token_batches(key: jax.Array, n_batches: int, batch: int, seq: int,
                       vocab: int):
    """Random LM token stream for substrate training examples/tests."""
    def gen(i):
        k = jax.random.fold_in(key, i)
        tokens = jax.random.randint(k, (batch, seq + 1), 0, vocab)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    return [gen(i) for i in range(n_batches)]
