"""Sharded data pipeline: host-side batch assembly -> device placement.

Production pattern: the host constructs global batches (here from the
synthetic generators; a real deployment would swap in file readers behind
the same iterator contract), places each under the mesh's batch sharding
(leading dim over the fsdp axes), and keeps ``prefetch`` batches in flight
so host assembly overlaps device compute.

Also provides the kernel-machine loader used by launch.kernel_train: rows
of (X, y) sharded over the data axes — paper Algorithm 1 step 1.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.partitioning import fsdp_axes


@dataclasses.dataclass
class ShardedLoader:
    """Wraps a host-batch iterator with device placement + prefetch."""

    mesh: Mesh
    make_batch: Callable[[int], Dict[str, Any]]   # step -> host batch
    prefetch: int = 2

    def _sharding_for(self, x):
        fa = fsdp_axes(self.mesh)
        spec = P(fa, *([None] * (x.ndim - 1)))
        return NamedSharding(self.mesh, spec)

    def _put(self, batch):
        return {k: jax.device_put(v, self._sharding_for(v))
                for k, v in batch.items()}

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        buf = collections.deque()
        for step in itertools.count():
            buf.append(self._put(self.make_batch(step)))
            if len(buf) > self.prefetch:
                yield buf.popleft()


def synthetic_lm_loader(mesh: Mesh, cfg, batch: int, seq: int,
                        seed: int = 0, prefetch: int = 2) -> ShardedLoader:
    """Token-stream loader for the LM zoo (matches train.steps batch dicts)."""
    from repro.models.transformer import D_VISION

    def make_batch(step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        kt, kf = jax.random.split(key)
        tokens = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if cfg.is_encdec:
            out["frames"] = jax.random.normal(
                kf, (batch, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
        if cfg.n_patches:
            out["patch_embeds"] = jax.random.normal(
                kf, (batch, cfg.n_patches, D_VISION), cfg.jnp_dtype)
        return out

    return ShardedLoader(mesh=mesh, make_batch=make_batch, prefetch=prefetch)


def shard_kernel_dataset(mesh: Mesh, X, y, data_axes=("data",)):
    """Paper Algorithm 1 step 1: rows of the training set scattered over the
    data axes (truncates to a divisible row count)."""
    n_dp = 1
    for a in data_axes:
        n_dp *= mesh.shape[a]
    n = (X.shape[0] // n_dp) * n_dp
    Xs = jax.device_put(X[:n], NamedSharding(mesh, P(data_axes, None)))
    ys = jax.device_put(y[:n], NamedSharding(mesh, P(data_axes)))
    return Xs, ys
