from repro.data.synthetic import (DatasetSpec, PAPER_DATASETS, make_classification,
                                  make_dataset, make_multiclass,
                                  make_token_batches)
from repro.data.chunks import (ArrayChunkSource, ChunkSource, MmapChunkSource,
                               as_chunk_source, ovr_targets,
                               random_basis_from_source, save_chunks)
