from repro.data.synthetic import (DatasetSpec, PAPER_DATASETS, make_classification,
                                  make_dataset, make_token_batches)
