"""Chunked dataset sources for the out-of-core ``stream`` execution plan.

The paper's Map-Reduce nodes *stream their data partition from disk* on
every TRON iteration — f, g, and Hd are sums over examples, so nothing in
formulation (4) requires X resident in memory. A :class:`ChunkSource`
exposes the training set as a sequence of ``(X_chunk, y_chunk)`` row
blocks the streaming solver consumes one at a time:

* :class:`ArrayChunkSource` — view over an in-memory (X, y) pair; lets the
  ``stream`` plan run on ordinary arrays (plan-equivalence tests, small
  jobs) with zero copies.
* :class:`MmapChunkSource` — a directory of ``.npy`` shard pairs
  (``X_00000.npy`` / ``y_00000.npy``, written by :func:`save_chunks`) or
  ``.npz`` shards with ``X``/``y`` keys. ``.npy`` shards open under
  ``numpy`` memory mapping, so a chunk read touches only ``chunk_rows``
  rows of disk — n can exceed host RAM.

Chunk ``i`` is always rows ``[i*chunk_rows, min(n, (i+1)*chunk_rows))`` of
the logical concatenation; only the last chunk may be short. The solver
pads every chunk to exactly ``chunk_rows`` rows with a zero example-weight
mask, so one compiled evaluation body serves all chunks.
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro import faults
from repro.util.retry import RetryPolicy, call_with_retry

_SHARD_RE = re.compile(r"^X_(\d+)\.npy$")

#: Transient-read policy for chunk gathers outside the stream feeder
#: (basis selection via ``take_rows``). The feeder applies its own copy of
#: this policy to the per-iteration chunk stream; together every disk read
#: on the stream-plan fit path survives faults below the retry cap.
READ_RETRY = RetryPolicy(max_attempts=3, backoff_s=0.02, max_backoff_s=0.5)


def _fire_read(i: int) -> None:
    # Chaos hook: every chunk read across source types funnels through
    # this one site so a FaultPlan rule covers mmap, in-memory and
    # partitioned layouts alike.
    faults.fire("chunk.read", detail=f"chunk={i}")


class ChunkSource:
    """Base chunked view of an (X, y) dataset.

    Subclasses implement :meth:`_rows`; everything else (chunk addressing,
    row gathers for basis selection) is shared. ``shape``/``dtype`` mirror
    the array interface closely enough for estimator code that only
    inspects metadata (``X.shape[0]``, ``X.dtype``).
    """

    # (process_id, num_processes) when this source is one host's view of a
    # multi-controller dataset partition; None for ordinary local sources.
    # The stream feeder keys on this to pad/transfer per-host blocks.
    process_span: Optional[Tuple[int, int]] = None

    def __init__(self, n: int, d: int, dtype, chunk_rows: Optional[int]):
        if n <= 0 or d <= 0:
            raise ValueError(f"empty dataset: n={n}, d={d}")
        self.n = int(n)
        self.d = int(d)
        self.dtype = np.dtype(dtype)
        self.chunk_rows = int(chunk_rows) if chunk_rows else min(self.n, 16384)
        if self.chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")

    # ------------------------------------------------------------- interface
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.d)

    @property
    def n_chunks(self) -> int:
        return -(-self.n // self.chunk_rows)

    def _rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def chunk(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """(X_chunk, y_chunk) for chunk ``i``; the last chunk may be short."""
        if not 0 <= i < self.n_chunks:
            raise IndexError(f"chunk {i} out of range [0, {self.n_chunks})")
        _fire_read(i)
        lo = i * self.chunk_rows
        return self._rows(lo, min(self.n, lo + self.chunk_rows))

    def iter_chunks(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for i in range(self.n_chunks):
            yield self.chunk(i)

    def iter_y(self) -> Iterator[np.ndarray]:
        """Yield the label vector in order, in source-defined segments.

        Subclasses that store y separately from X (mmap shard dirs)
        override this to avoid touching any X bytes — label scans (class
        discovery for one-vs-rest) then cost O(n) label reads, not a full
        dataset pass."""
        for i in range(self.n_chunks):
            yield self.chunk(i)[1]

    def unique_labels(self) -> np.ndarray:
        """Sorted distinct y values (one pass over y via :meth:`iter_y`)."""
        out: Optional[np.ndarray] = None
        for yc in self.iter_y():
            u = np.unique(np.asarray(yc))
            out = u if out is None else np.union1d(out, u)
        return out

    def take_rows(self, idx) -> np.ndarray:
        """Gather X rows by global index (basis selection: O(m) rows read,
        never the full set)."""
        idx = np.asarray(idx, np.int64)
        out = np.empty((idx.shape[0], self.d), self.dtype)
        order = np.argsort(idx, kind="stable")
        sorted_idx = idx[order]
        lo = 0
        while lo < sorted_idx.shape[0]:
            c = int(sorted_idx[lo]) // self.chunk_rows
            hi = lo
            while (hi < sorted_idx.shape[0]
                   and int(sorted_idx[hi]) // self.chunk_rows == c):
                hi += 1
            Xc, _ = call_with_retry(READ_RETRY, self.chunk, c,
                                    label=f"take-rows-chunk-{c}")
            local = sorted_idx[lo:hi] - c * self.chunk_rows
            out[order[lo:hi]] = np.asarray(Xc)[local]
            lo = hi
        return out

    def with_chunk_rows(self, chunk_rows: int) -> "ChunkSource":
        """Same data, different chunking (used to round chunk_rows up to a
        multiple of the mesh's data extent)."""
        raise NotImplementedError


class ArrayChunkSource(ChunkSource):
    """In-memory adapter: chunked view over arrays already in RAM.

    ``y=None`` builds a label-less view — the shape inference-only callers
    (``KernelMachine.decision_function`` under the ``stream`` plan) need;
    training paths always pass real labels (:func:`as_chunk_source`
    enforces it). Chunk reads substitute a zero vector (margin evaluation
    never looks at it), but any *label* read (:meth:`iter_y`, and thus
    label-from-source scoring or class discovery) raises instead of
    silently serving zeros as ground truth.
    """

    def __init__(self, X, y, chunk_rows: Optional[int] = None):
        X = np.asarray(X)
        if X.ndim != 2:
            raise ValueError(f"X must be (n, d), got shape {X.shape}")
        self.has_y = y is not None
        y = np.zeros((X.shape[0],), X.dtype) if y is None else np.asarray(y)
        if y.shape != (X.shape[0],):
            raise ValueError(
                f"y shape {y.shape} does not match X rows {X.shape[0]}")
        super().__init__(X.shape[0], X.shape[1], X.dtype, chunk_rows)
        self.X, self.y = X, y

    def _rows(self, lo, hi):
        return self.X[lo:hi], self.y[lo:hi]

    def take_rows(self, idx):
        return self.X[np.asarray(idx, np.int64)]

    def with_chunk_rows(self, chunk_rows):
        return ArrayChunkSource(self.X, self.y if self.has_y else None,
                                chunk_rows)

    def iter_y(self):
        if not self.has_y:
            raise ValueError(
                "this ArrayChunkSource was built without labels (y=None, "
                "an inference-only view); pass y explicitly to score "
                "against it")
        yield self.y


class MmapChunkSource(ChunkSource):
    """Chunks streamed from ``.npy``/``.npz`` shards in ``data_dir``.

    Layout (written by :func:`save_chunks`): ``X_00000.npy, y_00000.npy,
    X_00001.npy, ...`` — or ``shard_*.npz`` files each holding ``X`` and
    ``y`` arrays. ``mmap=True`` opens ``.npy`` shards with
    ``np.load(mmap_mode="r")`` so only the rows a chunk touches are read
    (``.npz`` is a zip container numpy cannot map; those shards are loaded
    lazily per chunk access instead).
    """

    def __init__(self, data_dir, chunk_rows: Optional[int] = None,
                 mmap: bool = True, _layout=None):
        self.data_dir = Path(data_dir)
        self.mmap = bool(mmap)
        self._cache: dict = {}
        if _layout is not None:      # rechunk: reuse the probed layout
            self._paths, self._npz, self._offsets, d, dtype = _layout
        else:
            if not self.data_dir.is_dir():
                raise FileNotFoundError(
                    f"{self.data_dir}: not a directory (create one with "
                    f"repro.data.chunks.save_chunks)")
            npy = sorted(p for p in self.data_dir.iterdir()
                         if _SHARD_RE.match(p.name))
            npz = sorted(self.data_dir.glob("shard_*.npz"))
            if npy and npz:
                raise ValueError(f"{self.data_dir}: mixed .npy and .npz shards")
            if not npy and not npz:
                raise FileNotFoundError(
                    f"{self.data_dir}: no X_*.npy / shard_*.npz shards found")
            self._paths = npy or npz
            self._npz = bool(npz)
            d, dtype, offsets = self._probe_layout()
            self._offsets = np.asarray(offsets, np.int64)
        super().__init__(self._offsets[-1], d, dtype, chunk_rows)

    def _probe_layout(self):
        """(d, dtype, offsets) without inflating shards: save_chunks'
        meta.json answers directly; otherwise open each shard (cheap header
        read for mmap .npy, a full decompress only for foreign .npz)."""
        meta_path = self.data_dir / "meta.json"
        if meta_path.exists():
            meta = json.loads(meta_path.read_text())
            fmt = "npz" if self._npz else "npy"
            if (meta.get("format") == fmt
                    and meta.get("n_shards") == len(self._paths)):
                rps, n = meta["rows_per_shard"], meta["n"]
                offsets = [min(i * rps, n) for i in range(len(self._paths) + 1)]
                return meta["d"], np.dtype(meta["dtype"]), offsets
        offsets = [0]
        d = dtype = None
        for p in self._paths:
            Xs, _ = self._load_shard(p)
            if d is None:
                d, dtype = Xs.shape[1], Xs.dtype
            elif Xs.shape[1] != d:
                raise ValueError(f"{p}: feature dim {Xs.shape[1]} != {d}")
            offsets.append(offsets[-1] + Xs.shape[0])
        return d, dtype, offsets

    def _load_shard(self, path):
        if path in self._cache:
            return self._cache[path]
        if self._npz:
            with np.load(path) as z:
                pair = (z["X"], z["y"])
        else:
            mode = "r" if self.mmap else None
            pair = (np.load(path, mmap_mode=mode),
                    np.load(path.parent / ("y_" + path.name[2:]),
                            mmap_mode=mode))
        if pair[0].shape[0] != pair[1].shape[0]:
            raise ValueError(f"{path}: X/y row mismatch "
                             f"{pair[0].shape[0]} != {pair[1].shape[0]}")
        # cache ONLY cheap memmap handles; fully-materialized pairs (npz,
        # mmap=False) are re-read per access — keeping them would quietly
        # accumulate the whole dataset in host RAM, the exact thing the
        # stream plan exists to avoid
        if self.mmap and not self._npz:
            self._cache[path] = pair
        return pair

    def _rows(self, lo, hi):
        s0 = int(np.searchsorted(self._offsets, lo, side="right")) - 1
        Xs, ys = [], []
        s = s0
        while lo < hi:
            Xa, ya = self._load_shard(self._paths[s])
            a = lo - int(self._offsets[s])
            b = min(hi - int(self._offsets[s]), Xa.shape[0])
            Xs.append(np.asarray(Xa[a:b]))
            ys.append(np.asarray(ya[a:b]))
            lo += b - a
            s += 1
        if len(Xs) == 1:
            return Xs[0], ys[0]
        return np.concatenate(Xs, axis=0), np.concatenate(ys, axis=0)

    def with_chunk_rows(self, chunk_rows):
        return MmapChunkSource(
            self.data_dir, chunk_rows, self.mmap,
            _layout=(self._paths, self._npz, self._offsets, self.d,
                     self.dtype))

    def iter_y(self):
        if self._npz:                 # zip container: no y-only read exists
            for p in self._paths:
                yield self._load_shard(p)[1]
            return
        for p in self._paths:         # .npy pairs: read ONLY the y shard
            mode = "r" if self.mmap else None
            yield np.asarray(np.load(p.parent / ("y_" + p.name[2:]),
                                     mmap_mode=mode))


def save_chunks(data_dir, X, y, rows_per_shard: int = 65536,
                compress: bool = False) -> Path:
    """Write (X, y) as a shard directory :class:`MmapChunkSource` can open.

    Default is ``.npy`` pairs (memory-mappable); ``compress=True`` writes
    ``shard_*.npz`` instead. A ``meta.json`` records the logical shape so
    tooling can size jobs without opening shards.
    """
    data_dir = Path(data_dir)
    data_dir.mkdir(parents=True, exist_ok=True)
    X = np.asarray(X)
    y = np.asarray(y)
    if y.shape != (X.shape[0],):
        raise ValueError(f"y shape {y.shape} does not match X rows")
    n_shards = -(-X.shape[0] // rows_per_shard)
    for s in range(n_shards):
        lo, hi = s * rows_per_shard, min(X.shape[0], (s + 1) * rows_per_shard)
        if compress:
            np.savez_compressed(data_dir / f"shard_{s:05d}.npz",
                                X=X[lo:hi], y=y[lo:hi])
        else:
            np.save(data_dir / f"X_{s:05d}.npy", X[lo:hi])
            np.save(data_dir / f"y_{s:05d}.npy", y[lo:hi])
    (data_dir / "meta.json").write_text(json.dumps(
        {"n": int(X.shape[0]), "d": int(X.shape[1]),
         "dtype": str(X.dtype), "n_shards": n_shards,
         "rows_per_shard": rows_per_shard,
         "format": "npz" if compress else "npy"}, indent=2))
    return data_dir


def as_chunk_source(X, y=None, chunk_rows: Optional[int] = None,
                    mmap: bool = True) -> ChunkSource:
    """Coerce (X, y) into a :class:`ChunkSource`.

    Accepts an existing source (rechunked if ``chunk_rows`` differs), a
    directory path (opened with :class:`MmapChunkSource`), or in-memory
    arrays (wrapped by :class:`ArrayChunkSource`).
    """
    if isinstance(X, ChunkSource):
        if chunk_rows and chunk_rows != X.chunk_rows:
            return X.with_chunk_rows(chunk_rows)
        return X
    if isinstance(X, (str, Path)):
        return MmapChunkSource(X, chunk_rows, mmap)
    if y is None:
        raise ValueError("as_chunk_source needs y when X is an array")
    return ArrayChunkSource(X, y, chunk_rows)


# --------------------------------------------------------------- multihost
def _span_block(gl: int, gh: int, chunk_rows: int,
                process_id: int, num_processes: int) -> Tuple[int, int]:
    """Global row range of one host's block of chunk ``[gl, gh)``.

    The chunk is cut into ``num_processes`` equal slots of
    ``chunk_rows / num_processes`` rows; host p owns slot p, clipped to the
    chunk's real rows. Because real rows fill the chunk from the front,
    every host's block is a *prefix* of its slot — so per-host blocks,
    each zero-padded to the slot size and concatenated in process order,
    reproduce the zero-padded global chunk exactly. That identity is what
    makes multi-controller streaming bitwise-comparable to single-process
    runs (same padded global array enters the same compiled psum body).
    """
    lcr = chunk_rows // num_processes
    a = min(gl + process_id * lcr, gh)
    return a, min(a + lcr, gh)


class HostPartition(ChunkSource):
    """One host's view of a *shared* chunked dataset (NFS-dir deployment).

    Reports the global ``n``/``chunk_rows`` geometry — the solver's
    iteration structure must be identical on every process — but
    :meth:`chunk` reads only this host's block of each global chunk
    (see :func:`_span_block`), so per-host disk traffic is 1/P of the
    dataset per TRON pass. Row gathers (:meth:`take_rows`, basis
    selection) and label scans stay global: the base source can read any
    row of the shared directory. For physically separate per-host
    directories use :func:`save_partition_dirs` / :func:`open_partition`
    instead.
    """

    def __init__(self, base: ChunkSource, process_id: int,
                 num_processes: int):
        if base.chunk_rows % num_processes:
            raise ValueError(
                f"chunk_rows={base.chunk_rows} must be a multiple of "
                f"num_processes={num_processes} so every host streams an "
                f"equal block per chunk; round it up first "
                f"(with_chunk_rows)")
        if getattr(base, "process_span", None) is not None:
            raise ValueError("base source is already a host partition")
        super().__init__(base.n, base.d, base.dtype, base.chunk_rows)
        self.base = base
        self.process_span = (int(process_id), int(num_processes))

    @property
    def local_chunk_rows(self) -> int:
        """Rows each host contributes per global chunk (the pad target)."""
        return self.chunk_rows // self.process_span[1]

    def chunk(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """This host's block of global chunk ``i`` — possibly short (the
        tail chunk) or empty (tail shorter than this host's slot)."""
        if not 0 <= i < self.n_chunks:
            raise IndexError(f"chunk {i} out of range [0, {self.n_chunks})")
        _fire_read(i)
        gl = i * self.chunk_rows
        a, b = _span_block(gl, min(self.n, gl + self.chunk_rows),
                           self.chunk_rows, *self.process_span)
        if a >= b:
            return (np.empty((0, self.d), self.dtype),
                    np.empty((0,), np.int64))
        return self.base._rows(a, b)

    def _rows(self, lo, hi):
        raise NotImplementedError(
            "HostPartition addresses data by chunk, not row range")

    def take_rows(self, idx):
        return self.base.take_rows(idx)       # shared dir: global reads OK

    def iter_y(self):
        return self.base.iter_y()             # label scans stay global

    def with_chunk_rows(self, chunk_rows):
        return HostPartition(self.base.with_chunk_rows(chunk_rows),
                             *self.process_span)


class PartitionChunkSource(ChunkSource):
    """One host's *physically separate* partition directory.

    Layout written by :func:`save_partition_dirs`: shard ``i`` of
    ``part-p-of-P/`` holds exactly host p's block of global chunk ``i``
    (the :func:`_span_block` rows — the paper's "each node owns its data
    partition" deployment, with no shared filesystem assumed). The source
    reports the *global* geometry recorded in ``partition.json`` so every
    process runs the same iteration structure; only local bytes exist on
    this host's disk.

    Cross-host reads are impossible by construction, so the two global
    operations delegate differently: ``unique_labels`` returns the class
    inventory recorded at save time, and ``take_rows`` fills the rows this
    host owns and sums the buffer across processes (every global row is
    owned by exactly one host; all processes call with identical indices —
    basis selection under a shared seed — making the collective lockstep).
    """

    def __init__(self, part_dir, mmap: bool = True):
        part_dir = Path(part_dir)
        meta_path = part_dir / "partition.json"
        if not meta_path.exists():
            raise FileNotFoundError(
                f"{part_dir}: no partition.json — not a partition dir "
                f"(write one with repro.data.chunks.save_partition_dirs)")
        meta = json.loads(meta_path.read_text())
        self.meta = meta
        self.local = MmapChunkSource(part_dir, chunk_rows=None, mmap=mmap)
        super().__init__(meta["n"], meta["d"], np.dtype(meta["dtype"]),
                         meta["chunk_rows"])
        self.process_span = (int(meta["process_id"]),
                             int(meta["num_processes"]))
        # shard i <-> global chunk i: the layout invariant everything here
        # relies on (local shards may be ragged, offsets handle that)
        if len(self.local._paths) != self.n_chunks:
            raise ValueError(
                f"{part_dir}: {len(self.local._paths)} shards but the "
                f"global geometry implies {self.n_chunks} chunks — "
                f"partition dir does not match its partition.json")

    @property
    def local_chunk_rows(self) -> int:
        return self.chunk_rows // self.process_span[1]

    def chunk(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        if not 0 <= i < self.n_chunks:
            raise IndexError(f"chunk {i} out of range [0, {self.n_chunks})")
        _fire_read(i)
        lo = int(self.local._offsets[i])
        hi = int(self.local._offsets[i + 1])
        if lo >= hi:
            return (np.empty((0, self.d), self.dtype),
                    np.empty((0,), np.int64))
        return self.local._rows(lo, hi)

    def _rows(self, lo, hi):
        raise NotImplementedError(
            "PartitionChunkSource addresses data by chunk, not row range")

    def unique_labels(self):
        return np.asarray(self.meta["classes"])

    def iter_y(self):
        return self.local.iter_y()            # local labels only

    def take_rows(self, idx):
        from repro.sharding import multihost
        idx = np.asarray(idx, np.int64)
        pid, nproc = self.process_span
        out = np.zeros((idx.shape[0], self.d), self.dtype)
        for j, g in enumerate(idx):
            g = int(g)
            c, off = divmod(g, self.chunk_rows)
            a, b = _span_block(c * self.chunk_rows,
                               min(self.n, (c + 1) * self.chunk_rows),
                               self.chunk_rows, pid, nproc)
            if a <= g < b:
                lo = int(self.local._offsets[c])
                out[j] = self.local._rows(lo + (g - a), lo + (g - a) + 1)[0]
        return multihost.sum_across_processes(out)

    def with_chunk_rows(self, chunk_rows):
        if int(chunk_rows) == self.chunk_rows:
            return self
        raise ValueError(
            f"a partition dir is physically laid out at "
            f"chunk_rows={self.chunk_rows} (one shard per global chunk) "
            f"and cannot be re-chunked to {chunk_rows}; re-export with "
            f"save_partition_dirs(chunk_rows=...) — pick a multiple of "
            f"the mesh's data extent so the solver needs no rounding")


def save_partition_dirs(root, X, y, num_processes: int,
                        chunk_rows: int) -> list:
    """Split (X, y) into per-host partition directories.

    Writes ``root/part-{p:05d}-of-{P:05d}/`` for each host: shard ``i``
    is host p's :func:`_span_block` of global chunk ``i`` plus a
    ``partition.json`` recording the global geometry (and the class
    inventory, so one-vs-rest class discovery needs no cross-host label
    scan). Returns the directory paths in process order.
    """
    X = np.asarray(X)
    y = np.asarray(y)
    if y.shape != (X.shape[0],):
        raise ValueError(f"y shape {y.shape} does not match X rows")
    n = X.shape[0]
    chunk_rows = int(chunk_rows)
    if chunk_rows % num_processes:
        raise ValueError(
            f"chunk_rows={chunk_rows} must be a multiple of "
            f"num_processes={num_processes}")
    root = Path(root)
    n_chunks = -(-n // chunk_rows)
    classes = np.unique(y)
    dirs = []
    for p in range(num_processes):
        part = root / f"part-{p:05d}-of-{num_processes:05d}"
        part.mkdir(parents=True, exist_ok=True)
        for i in range(n_chunks):
            gl = i * chunk_rows
            a, b = _span_block(gl, min(n, gl + chunk_rows), chunk_rows,
                               p, num_processes)
            np.save(part / f"X_{i:05d}.npy", X[a:b])
            np.save(part / f"y_{i:05d}.npy", y[a:b])
        (part / "partition.json").write_text(json.dumps(
            {"n": int(n), "d": int(X.shape[1]), "dtype": str(X.dtype),
             "chunk_rows": chunk_rows, "num_processes": int(num_processes),
             "process_id": p, "classes": classes.tolist()}, indent=2))
        dirs.append(part)
    return dirs


def open_partition(part_dir, mmap: bool = True) -> PartitionChunkSource:
    """Open one host's partition directory (see :func:`save_partition_dirs`)."""
    return PartitionChunkSource(part_dir, mmap=mmap)


def is_partition_dir(data_dir) -> bool:
    """True when ``data_dir`` is a per-host partition directory."""
    return (Path(data_dir) / "partition.json").exists()


def ovr_targets(y, classes, dtype=np.float32) -> np.ndarray:
    """One-vs-rest targets: (n,) labels -> (n, K) ±1 columns.

    Column k is the binary problem "class ``classes[k]`` vs rest" — the
    K independent formulation-(4) objectives a multi-RHS TRON solve
    optimizes in one pass. Pure numpy so the stream plan can expand each
    label chunk on the host right before transfer (the source keeps its
    compact integer labels; the ±1 expansion never hits disk).
    """
    y = np.asarray(y)
    classes = np.asarray(classes)
    return np.where(y[:, None] == classes[None, :], 1.0, -1.0).astype(dtype)


def random_basis_from_source(key, source: ChunkSource, m: int) -> np.ndarray:
    """m rows sampled uniformly without replacement from a chunked source —
    the streaming counterpart of :func:`repro.core.basis.random_basis`.

    Only O(m) rows are *read* (the full set never leaves disk). The index
    draw itself matches ``random_basis`` bit-for-bit, which costs an
    O(n)-element permutation like every ``jax.random.choice(replace=False)``
    — n int32s, a factor 4·d smaller than the X bytes the source avoids
    holding; switch to a host-side reservoir draw if even that binds.
    """
    import jax
    idx = jax.random.choice(key, source.n, shape=(m,), replace=False)
    return source.take_rows(np.asarray(idx))
