"""Cross-cutting utilities shared by the data, checkpoint, serving and
sharding layers. Stdlib-only: importing this package must stay cheap
enough for process supervisors and test rigs that never touch jax."""
from repro.util.retry import RetryPolicy, call_with_retry

__all__ = ["RetryPolicy", "call_with_retry"]
