"""Shared retry policy: capped exponential backoff + deterministic jitter.

One policy type serves every transient-failure consumer in the repo — the
stream chunk feeder (per-chunk disk reads), the async checkpoint writer
(step-file commits), and the multi-host supervisor (fleet restarts reuse
:meth:`RetryPolicy.delay` for its backoff schedule). Keeping them on one
implementation means the retry semantics can be proven once
(tests/test_retry.py) and fault-injection tests (tests/test_faults.py)
exercise the same code path production uses.

Jitter is *deterministic*: a hash of ``(label, attempt)`` spreads
concurrent retriers apart without an RNG whose state would differ between
a run and its bitwise resume. Stdlib-only by design — the supervisor and
the multihost test rig import this without paying for jax.
"""
from __future__ import annotations

import dataclasses
import hashlib
import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.retry")


def _is_transient_io(exc: BaseException) -> bool:
    """Default retryable predicate: plain I/O errors (the transient class
    chunk reads and checkpoint commits actually see). Everything else —
    ValueError, BadZipFile, KeyboardInterrupt — is not retried."""
    return isinstance(exc, OSError)


def _jitter_frac(label: str, attempt: int) -> float:
    """Deterministic pseudo-uniform fraction in [0, 1) from (label, attempt)."""
    h = hashlib.sha256(f"{label}:{attempt}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How many times to retry, how long to wait, and what counts as
    transient.

    ``max_attempts`` bounds total calls (1 = no retry). The delay before
    attempt k+1 is ``min(max_backoff_s, backoff_s * backoff_mult**(k-1))``
    stretched by up to ``jitter`` (a fraction) of deterministic jitter.
    ``retryable`` is the exception predicate; the default retries
    ``OSError`` only.
    """
    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.1
    retryable: Callable[[BaseException], bool] = _is_transient_io

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff times must be non-negative")

    def delay(self, attempt: int, label: str = "") -> float:
        """Backoff before retrying after failed attempt ``attempt`` (1-based)."""
        base = min(self.max_backoff_s,
                   self.backoff_s * self.backoff_mult ** (attempt - 1))
        return base * (1.0 + self.jitter * _jitter_frac(label, attempt))


def call_with_retry(policy: RetryPolicy, fn: Callable, *args,
                    label: str = "",
                    on_retry: Optional[Callable] = None,
                    sleep: Callable[[float], None] = time.sleep,
                    **kwargs):
    """Call ``fn(*args, **kwargs)``, retrying per ``policy``.

    Each failed-but-retryable attempt is logged (per-attempt, with the
    delay) and reported to ``on_retry(attempt, exc, delay_s)`` so callers
    can count retries in their accounting. The final failure (attempt cap
    reached, or a non-retryable exception) propagates unchanged.
    """
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn(*args, **kwargs)
        except BaseException as exc:
            if attempt >= policy.max_attempts or not policy.retryable(exc):
                raise
            d = policy.delay(attempt, label)
            log.warning("retryable failure in %s (attempt %d/%d): %s — "
                        "retrying in %.3fs",
                        label or getattr(fn, "__name__", "call"), attempt,
                        policy.max_attempts, exc, d)
            if on_retry is not None:
                on_retry(attempt, exc, d)
            if d > 0:
                sleep(d)
