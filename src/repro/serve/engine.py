"""Asynchronous continuous-batching serve engine over the decide arms.

The paper's deployment punchline — prediction is row-partitioned, needs no
AllReduce, and is one kmvp — means serving is pure batched matrix work,
and the only thing standing between a single-caller endpoint and
production throughput is *batch formation*. This engine does exactly
that: many client threads ``submit`` rows concurrently, a single batcher
thread continuously drains the admission queue, coalesces queued requests
for the same model into one block, runs ONE bucketed jit dispatch
(:class:`~repro.api.infer.BucketedDecider` pads to the power-of-two
bucket), and scatters the margin rows back to each caller's future
(:func:`~repro.api.infer.scatter_rows`). Continuous means no waiting for
full batches: whatever is queued when the dispatcher frees up forms the
next batch, so latency stays request-bounded at low load and occupancy
climbs with pressure.

Correctness contract: per-row margins are batch-composition independent
(each row reduces over m alone), so a request's rows served inside any
coalesced block are bitwise-identical to the same rows served alone
through the same jitted decide family — asserted, not assumed, by
``tests/test_serve_engine.py``. No cross-request leakage is possible by
construction: scatter slices are disjoint row ranges of one output block.

Admission control: a bounded waiting queue and an in-flight cap reject at
``submit`` with :class:`~repro.serve.batching.QueueFull`; per-request
deadlines reject queued-too-long work with
:class:`~repro.serve.batching.RequestTimeout` before it wastes a dispatch.
Rejections are clean — the batcher never wedges, and ``stop()`` fails
stragglers with :class:`~repro.serve.batching.EngineStopped`.

Self-healing: a dispatch exception fails only its batch (the guard in
:meth:`ServeEngine._dispatch`), a per-model
:class:`~repro.serve.health.CircuitBreaker` turns a persistently failing
model into fast :class:`~repro.serve.batching.CircuitOpen` rejections at
submit (then probes its way closed again after a cooldown), and the
engine-level health gauge (STARTING/READY/DEGRADED/DRAINING) is exposed
through ``ServeMetrics``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Sequence

import numpy as np

from repro import faults
from repro.api.infer import scatter_rows
from repro.serve.batching import (CircuitOpen, EngineStopped, QueueFull,
                                  Request, RequestQueue, RequestTimeout,
                                  ServeFuture)
from repro.serve.health import (DEGRADED, DRAINING, READY, STARTING,
                                CircuitBreaker)
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import ModelRegistry

_UNSET = object()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """SLO knobs for :class:`ServeEngine`.

    ``max_batch`` caps rows per dispatch (the top bucket). ``max_queue``
    bounds *waiting* requests; ``max_inflight`` bounds admitted-but-
    uncompleted requests (waiting + being dispatched) — both reject at
    submit. ``timeout_s`` is the default per-request deadline (None =
    wait forever); ``poll_s`` is the batcher's idle wait between queue
    checks (latency floor when the queue is empty is one notify, not one
    poll — the queue wakes the batcher on push).

    ``breaker_threshold`` consecutive dispatch failures open a model's
    circuit (submits fast-reject with ``CircuitOpen`` until a probe
    succeeds after ``breaker_cooldown_s``); 0 disables the breaker. The
    default is deliberately above one so an isolated failure — a model
    swapped out for a single batch — never trips it."""
    max_batch: int = 256
    max_queue: int = 1024
    max_inflight: int = 4096
    timeout_s: Optional[float] = None
    poll_s: float = 0.05
    breaker_threshold: int = 5
    breaker_cooldown_s: float = 5.0


class ServeEngine:
    """Continuous batcher over a :class:`~repro.serve.registry.ModelRegistry`.

    Use as a context manager (``with ServeEngine(reg) as eng:``) or call
    :meth:`start`/:meth:`stop`. ``submit`` returns a
    :class:`~repro.serve.batching.ServeFuture`; ``__call__`` is the
    blocking convenience. Construct with ``autostart=False`` to submit
    before any dispatching happens (tests use this to force saturation
    and timeouts deterministically).
    """

    def __init__(self, registry: ModelRegistry,
                 config: EngineConfig = EngineConfig(), *,
                 autostart: bool = True):
        self.registry = registry
        self.config = config
        self.metrics = ServeMetrics()
        self._queue = RequestQueue(config.max_queue)
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._breaker_lock = threading.Lock()
        self.metrics.set_health(STARTING)
        if autostart:
            self.start()

    # ---------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ServeEngine":
        if self.running:
            return self
        self._queue.open()           # accept submits again after a stop()
        self._stop.clear()
        self._thread = threading.Thread(target=self._batch_loop,
                                        name="serve-batcher", daemon=True)
        self._thread.start()
        self._update_health()        # READY, or DEGRADED if circuits stayed
        return self                  # open across a stop/start cycle

    def stop(self, timeout: float = 10.0) -> None:
        """Stop the batcher and fail every still-pending request with
        :class:`EngineStopped` (clean shutdown, never a hang).

        The queue is closed *before* the drain, so a ``submit`` racing this
        call either lands in the queue (and is failed here) or raises
        :class:`EngineStopped` at push — it cannot be stranded after the
        drain with its in-flight slot leaked. ``start()`` afterwards
        restores a fully serviceable engine."""
        self.metrics.set_health(DRAINING)
        self._queue.close()
        self._stop.set()
        self._queue.notify()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        for req in self._queue.drain():
            self._finish(req, exc=EngineStopped("serve engine stopped"),
                         counter="cancelled")
        self.metrics.set_health(STARTING)   # stopped = not serving yet

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def health(self) -> str:
        """STARTING / READY / DEGRADED / DRAINING (see repro.serve.health)."""
        return self.metrics.health

    def _breaker(self, model: str) -> CircuitBreaker:
        with self._breaker_lock:
            br = self._breakers.get(model)
            if br is None:
                br = CircuitBreaker(self.config.breaker_threshold,
                                    self.config.breaker_cooldown_s)
                self._breakers[model] = br
            return br

    def _update_health(self) -> None:
        if not self.running:
            return                    # stop() owns the gauge while draining
        with self._breaker_lock:
            degraded = any(b.state != CircuitBreaker.CLOSED
                           for b in self._breakers.values())
        self.metrics.set_health(DEGRADED if degraded else READY)

    # ---------------------------------------------------------- admission
    def submit(self, X, *, model: Optional[str] = None,
               timeout: object = _UNSET) -> ServeFuture:
        """Admit one request (rows for one model); returns its future.

        Raises :class:`QueueFull` when the waiting queue or in-flight cap
        is at capacity — the caller's clean backpressure signal. ``timeout``
        overrides ``EngineConfig.timeout_s`` for this request (None = no
        deadline)."""
        entry = self.registry.get(model)
        X = np.asarray(X, dtype=np.float32)
        if X.ndim != 2 or X.shape[1] != entry.d:
            raise ValueError(f"model {entry.name!r} serves (rows, {entry.d}) "
                             f"requests, got {X.shape}")
        self.metrics.add(submitted=1)
        if not self._breaker(entry.name).allow():
            self.metrics.add(rejected_open=1)
            raise CircuitOpen(
                f"model {entry.name!r}: circuit open after repeated "
                f"dispatch failures; retry after "
                f"{self.config.breaker_cooldown_s:g}s cooldown")
        future = ServeFuture()
        if X.shape[0] == 0:              # nothing to dispatch: empty margins
            shape = (0, entry.n_classes) if entry.n_classes else (0,)
            future.set_result(np.zeros(shape, np.float32))
            self.metrics.add(completed=1)
            return future
        timeout_s = self.config.timeout_s if timeout is _UNSET else timeout
        now = time.monotonic()
        req = Request(model=entry.name, X=X, future=future,
                      deadline=None if timeout_s is None else now + timeout_s,
                      submitted_at=now)
        with self._inflight_lock:
            if self._inflight >= self.config.max_inflight:
                self.metrics.add(rejected_full=1)
                raise QueueFull(
                    f"engine at max_inflight={self.config.max_inflight}")
            self._inflight += 1
        try:
            self._queue.push(req)
        except BaseException as exc:
            # EVERY push failure (QueueFull, EngineStopped from a racing
            # stop(), anything else) must release the in-flight slot, or
            # restarts inherit phantom occupancy and eventually reject
            # all traffic with a spurious QueueFull
            with self._inflight_lock:
                self._inflight -= 1
            if isinstance(exc, QueueFull):
                self.metrics.add(rejected_full=1)
            raise
        return future

    def __call__(self, X, *, model: Optional[str] = None,
                 timeout: object = _UNSET) -> np.ndarray:
        """Blocking convenience: submit and wait for this caller's margins."""
        return self.submit(X, model=model, timeout=timeout).result()

    @property
    def inflight(self) -> int:
        with self._inflight_lock:
            return self._inflight

    # ----------------------------------------------------------- batching
    def _finish(self, req: Request, *, result: Optional[np.ndarray] = None,
                exc: Optional[BaseException] = None,
                counter: str = "completed") -> None:
        with self._inflight_lock:
            self._inflight -= 1
        self.metrics.add(**{counter: 1})
        if exc is not None:
            req.future.set_exception(exc)
        else:
            req.future.set_result(result)

    def _batch_loop(self) -> None:
        cfg = self.config
        while not self._stop.is_set():
            batch = self._queue.next_batch(cfg.max_batch, cfg.poll_s)
            if batch is None:
                continue
            model, live, expired = batch
            for req in expired:
                self._finish(req, exc=_timeout_error(req),
                             counter="rejected_timeout")
            if live:
                self._dispatch(model, live)

    def _dispatch(self, model: str, reqs: Sequence[Request]) -> None:
        sizes = [r.n for r in reqs]
        rows = sum(sizes)
        try:
            # registry lookup and block assembly are inside the guard too: a
            # model unregistered mid-flight (or a bad request that slipped
            # admission) must fail ITS batch, not kill the batcher thread
            # with every in-flight slot still held
            faults.fire("serve.dispatch", detail=model)
            entry = self.registry.get(model)
            block = reqs[0].X if len(reqs) == 1 \
                else np.concatenate([r.X for r in reqs], axis=0)
            margins = np.asarray(entry.decider(block))
        except Exception as exc:         # fail the batch, keep serving
            if self._breaker(model).record_failure():
                self.metrics.add(breaker_opened=1)
                self._update_health()
            for req in reqs:
                self._finish(req, exc=exc, counter="failed")
            return
        if self._breaker(model).record_success():
            self.metrics.add(breaker_closed=1)
            self._update_health()
        self.metrics.add(dispatches=1, dispatched_rows=rows,
                         padded_rows=entry.decider.padded_rows(rows),
                         coalesced_requests=len(reqs))
        for req, part in zip(reqs, scatter_rows(margins, sizes)):
            # copy: the caller's slice must not pin the whole block alive
            self._finish(req, result=np.array(part, copy=True))


def _timeout_error(req: Request) -> RequestTimeout:
    waited = time.monotonic() - req.submitted_at
    return RequestTimeout(
        f"request for model {req.model!r} ({req.n} rows) expired after "
        f"{waited * 1e3:.0f} ms in queue")
