"""Asynchronous continuous-batching serve engine over the decide arms.

The paper's deployment punchline — prediction is row-partitioned, needs no
AllReduce, and is one kmvp — means serving is pure batched matrix work,
and the only thing standing between a single-caller endpoint and
production throughput is *batch formation*. This engine does exactly
that: many client threads ``submit`` rows concurrently, a single batcher
thread continuously drains the admission queue, coalesces queued requests
for the same model into one block, runs ONE bucketed jit dispatch
(:class:`~repro.api.infer.BucketedDecider` pads to the power-of-two
bucket), and scatters the margin rows back to each caller's future
(:func:`~repro.api.infer.scatter_rows`). Continuous means no waiting for
full batches: whatever is queued when the dispatcher frees up forms the
next batch, so latency stays request-bounded at low load and occupancy
climbs with pressure.

Correctness contract: per-row margins are batch-composition independent
(each row reduces over m alone), so a request's rows served inside any
coalesced block are bitwise-identical to the same rows served alone
through the same jitted decide family — asserted, not assumed, by
``tests/test_serve_engine.py``. No cross-request leakage is possible by
construction: scatter slices are disjoint row ranges of one output block.

Admission control: a bounded waiting queue and an in-flight cap reject at
``submit`` with :class:`~repro.serve.batching.QueueFull`; per-request
deadlines reject queued-too-long work with
:class:`~repro.serve.batching.RequestTimeout` before it wastes a dispatch.
Rejections are clean — the batcher never wedges, and ``stop()`` fails
stragglers with :class:`~repro.serve.batching.EngineStopped`.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.api.infer import scatter_rows
from repro.serve.batching import (EngineStopped, QueueFull, Request,
                                  RequestQueue, RequestTimeout, ServeFuture)
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import ModelRegistry

_UNSET = object()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """SLO knobs for :class:`ServeEngine`.

    ``max_batch`` caps rows per dispatch (the top bucket). ``max_queue``
    bounds *waiting* requests; ``max_inflight`` bounds admitted-but-
    uncompleted requests (waiting + being dispatched) — both reject at
    submit. ``timeout_s`` is the default per-request deadline (None =
    wait forever); ``poll_s`` is the batcher's idle wait between queue
    checks (latency floor when the queue is empty is one notify, not one
    poll — the queue wakes the batcher on push)."""
    max_batch: int = 256
    max_queue: int = 1024
    max_inflight: int = 4096
    timeout_s: Optional[float] = None
    poll_s: float = 0.05


class ServeEngine:
    """Continuous batcher over a :class:`~repro.serve.registry.ModelRegistry`.

    Use as a context manager (``with ServeEngine(reg) as eng:``) or call
    :meth:`start`/:meth:`stop`. ``submit`` returns a
    :class:`~repro.serve.batching.ServeFuture`; ``__call__`` is the
    blocking convenience. Construct with ``autostart=False`` to submit
    before any dispatching happens (tests use this to force saturation
    and timeouts deterministically).
    """

    def __init__(self, registry: ModelRegistry,
                 config: EngineConfig = EngineConfig(), *,
                 autostart: bool = True):
        self.registry = registry
        self.config = config
        self.metrics = ServeMetrics()
        self._queue = RequestQueue(config.max_queue)
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if autostart:
            self.start()

    # ---------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ServeEngine":
        if self.running:
            return self
        self._queue.open()           # accept submits again after a stop()
        self._stop.clear()
        self._thread = threading.Thread(target=self._batch_loop,
                                        name="serve-batcher", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        """Stop the batcher and fail every still-pending request with
        :class:`EngineStopped` (clean shutdown, never a hang).

        The queue is closed *before* the drain, so a ``submit`` racing this
        call either lands in the queue (and is failed here) or raises
        :class:`EngineStopped` at push — it cannot be stranded after the
        drain with its in-flight slot leaked. ``start()`` afterwards
        restores a fully serviceable engine."""
        self._queue.close()
        self._stop.set()
        self._queue.notify()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        for req in self._queue.drain():
            self._finish(req, exc=EngineStopped("serve engine stopped"),
                         counter="cancelled")

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---------------------------------------------------------- admission
    def submit(self, X, *, model: Optional[str] = None,
               timeout: object = _UNSET) -> ServeFuture:
        """Admit one request (rows for one model); returns its future.

        Raises :class:`QueueFull` when the waiting queue or in-flight cap
        is at capacity — the caller's clean backpressure signal. ``timeout``
        overrides ``EngineConfig.timeout_s`` for this request (None = no
        deadline)."""
        entry = self.registry.get(model)
        X = np.asarray(X, dtype=np.float32)
        if X.ndim != 2 or X.shape[1] != entry.d:
            raise ValueError(f"model {entry.name!r} serves (rows, {entry.d}) "
                             f"requests, got {X.shape}")
        self.metrics.add(submitted=1)
        future = ServeFuture()
        if X.shape[0] == 0:              # nothing to dispatch: empty margins
            shape = (0, entry.n_classes) if entry.n_classes else (0,)
            future.set_result(np.zeros(shape, np.float32))
            self.metrics.add(completed=1)
            return future
        timeout_s = self.config.timeout_s if timeout is _UNSET else timeout
        now = time.monotonic()
        req = Request(model=entry.name, X=X, future=future,
                      deadline=None if timeout_s is None else now + timeout_s,
                      submitted_at=now)
        with self._inflight_lock:
            if self._inflight >= self.config.max_inflight:
                self.metrics.add(rejected_full=1)
                raise QueueFull(
                    f"engine at max_inflight={self.config.max_inflight}")
            self._inflight += 1
        try:
            self._queue.push(req)
        except BaseException as exc:
            # EVERY push failure (QueueFull, EngineStopped from a racing
            # stop(), anything else) must release the in-flight slot, or
            # restarts inherit phantom occupancy and eventually reject
            # all traffic with a spurious QueueFull
            with self._inflight_lock:
                self._inflight -= 1
            if isinstance(exc, QueueFull):
                self.metrics.add(rejected_full=1)
            raise
        return future

    def __call__(self, X, *, model: Optional[str] = None,
                 timeout: object = _UNSET) -> np.ndarray:
        """Blocking convenience: submit and wait for this caller's margins."""
        return self.submit(X, model=model, timeout=timeout).result()

    @property
    def inflight(self) -> int:
        with self._inflight_lock:
            return self._inflight

    # ----------------------------------------------------------- batching
    def _finish(self, req: Request, *, result: Optional[np.ndarray] = None,
                exc: Optional[BaseException] = None,
                counter: str = "completed") -> None:
        with self._inflight_lock:
            self._inflight -= 1
        self.metrics.add(**{counter: 1})
        if exc is not None:
            req.future.set_exception(exc)
        else:
            req.future.set_result(result)

    def _batch_loop(self) -> None:
        cfg = self.config
        while not self._stop.is_set():
            batch = self._queue.next_batch(cfg.max_batch, cfg.poll_s)
            if batch is None:
                continue
            model, live, expired = batch
            for req in expired:
                self._finish(req, exc=_timeout_error(req),
                             counter="rejected_timeout")
            if live:
                self._dispatch(model, live)

    def _dispatch(self, model: str, reqs: Sequence[Request]) -> None:
        sizes = [r.n for r in reqs]
        rows = sum(sizes)
        try:
            # registry lookup and block assembly are inside the guard too: a
            # model unregistered mid-flight (or a bad request that slipped
            # admission) must fail ITS batch, not kill the batcher thread
            # with every in-flight slot still held
            entry = self.registry.get(model)
            block = reqs[0].X if len(reqs) == 1 \
                else np.concatenate([r.X for r in reqs], axis=0)
            margins = np.asarray(entry.decider(block))
        except Exception as exc:         # fail the batch, keep serving
            for req in reqs:
                self._finish(req, exc=exc, counter="failed")
            return
        self.metrics.add(dispatches=1, dispatched_rows=rows,
                         padded_rows=entry.decider.padded_rows(rows),
                         coalesced_requests=len(reqs))
        for req, part in zip(reqs, scatter_rows(margins, sizes)):
            # copy: the caller's slice must not pin the whole block alive
            self._finish(req, result=np.array(part, copy=True))


def _timeout_error(req: Request) -> RequestTimeout:
    waited = time.monotonic() - req.submitted_at
    return RequestTimeout(
        f"request for model {req.model!r} ({req.n} rows) expired after "
        f"{waited * 1e3:.0f} ms in queue")
