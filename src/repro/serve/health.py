"""Self-healing serving: per-model circuit breaker + health states.

A dispatch exception already fails only its batch (the engine thread
survives — ``ServeEngine._dispatch``'s guard). What that alone cannot do
is protect *callers* from a model that fails every batch: each doomed
request still waits in queue, occupies an in-flight slot, and burns a
dispatch before erroring. The :class:`CircuitBreaker` converts a
persistently failing model into fast, cheap rejections at ``submit``
(:class:`~repro.serve.batching.CircuitOpen`) and then probes its way back
once the fault clears — the classic CLOSED → OPEN → HALF_OPEN machine.

Health is the engine-level summary the ops surface (``kernel_serve``,
``ServeMetrics.snapshot()``) exposes:

    STARTING  constructed / stopped, batcher not serving
    READY     batcher live, every model circuit closed
    DEGRADED  batcher live, at least one circuit open or probing
    DRAINING  stop() in progress, failing stragglers

Validated against injected ``serve.dispatch`` faults in
tests/test_serve_health.py.
"""
from __future__ import annotations

import threading
import time
from typing import Callable

STARTING = "starting"
READY = "ready"
DEGRADED = "degraded"
DRAINING = "draining"
HEALTH_STATES = (STARTING, READY, DEGRADED, DRAINING)


class CircuitBreaker:
    """Thread-safe per-model circuit breaker.

    CLOSED counts consecutive dispatch failures; at ``threshold`` the
    circuit OPENs and :meth:`allow` answers False (the engine fast-rejects
    without queueing). After ``cooldown_s`` the next :meth:`allow` admits
    exactly one probe (HALF_OPEN); the probe's outcome either re-CLOSEs
    the circuit or re-OPENs it for another cooldown. A probe that never
    reports back (its request timed out in queue, the engine stopped) is
    presumed lost after another ``cooldown_s`` and a new probe is allowed
    — the breaker can never wedge in HALF_OPEN.

    ``threshold=0`` disables the breaker (always allows, never opens).
    """

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"

    def __init__(self, threshold: int = 5, cooldown_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        if threshold < 0:
            raise ValueError(f"threshold must be >= 0, got {threshold}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0          # consecutive, while CLOSED
        self._opened_at = 0.0
        self._probe_at = 0.0        # when the in-flight probe was admitted

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    @property
    def consecutive_failures(self) -> int:
        with self._lock:
            return self._failures

    def allow(self) -> bool:
        """May a new request for this model be admitted right now?"""
        if self.threshold == 0:
            return True
        with self._lock:
            if self._state == self.CLOSED:
                return True
            now = self._clock()
            if self._state == self.OPEN:
                if now < self._opened_at + self.cooldown_s:
                    return False
                self._state = self.HALF_OPEN
                self._probe_at = now
                return True
            # HALF_OPEN: one probe at a time, but a lost probe expires
            if now < self._probe_at + self.cooldown_s:
                return False
            self._probe_at = now
            return True

    def record_success(self) -> bool:
        """Report a successful dispatch; True if this re-closed the circuit."""
        with self._lock:
            reopened = self._state != self.CLOSED
            self._state = self.CLOSED
            self._failures = 0
            return reopened

    def record_failure(self) -> bool:
        """Report a failed dispatch; True if this transition OPENed the
        circuit (first open or a failed probe re-opening it)."""
        if self.threshold == 0:
            return False
        with self._lock:
            if self._state == self.HALF_OPEN:
                self._state = self.OPEN
                self._opened_at = self._clock()
                return True
            self._failures += 1
            if self._state == self.CLOSED and \
                    self._failures >= self.threshold:
                self._state = self.OPEN
                self._opened_at = self._clock()
                return True
            return False
