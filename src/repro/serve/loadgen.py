"""SLO load generator: N concurrent clients against a serving target.

One harness drives both serving shapes with the SAME offered load so
their numbers are comparable:

* the request-at-a-time baseline — a lock-serialized
  :class:`~repro.api.infer.BucketedDecider` per model, exactly what the
  pre-engine ``ServingEndpoint`` gave one caller at a time, and
* the continuous-batching :class:`~repro.serve.engine.ServeEngine`.

Each client thread fires its own deterministic mixed-size (and
mixed-model, hence mixed-K) request stream, keeping up to ``window``
requests outstanding (window=1 is a fully synchronous caller).  Every
request is timed submit-to-result; verification against the precomputed
synchronous references happens AFTER the timed region, so correctness
checking never masks the throughput difference under test.  Latency
percentiles come from the one shared helper
(:func:`repro.serve.metrics.percentiles`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.batching import Rejected
from repro.serve.metrics import percentiles
from repro.serve.registry import ModelRegistry


@dataclasses.dataclass(frozen=True)
class LoadRequest:
    """One scripted request: rows for a model plus its precomputed
    reference margins (None skips verification)."""
    model: str
    X: np.ndarray
    reference: Optional[np.ndarray]


@dataclasses.dataclass
class LoadReport:
    """What one load phase measured. ``mismatches`` counts responses whose
    margins did not match the precomputed synchronous reference (bitwise
    at atol=0, else within atol) — the acceptance criterion is zero."""
    label: str
    clients: int
    requests: int
    completed: int = 0
    rejected: int = 0
    mismatches: int = 0
    rows: int = 0
    wall_s: float = 0.0
    rows_per_s: float = 0.0
    latency_ms: Dict[str, float] = dataclasses.field(default_factory=dict)

    def row(self) -> Dict:
        """Flat dict for BENCH_serve.json / CSV emission."""
        out = dataclasses.asdict(self)
        out.update(out.pop("latency_ms"))
        return out


def make_workload(registry: ModelRegistry, *, clients: int,
                  requests_per_client: int, max_rows: int,
                  models: Optional[Sequence[str]] = None,
                  seed: int = 0, d_fallback: int = 0,
                  verify: bool = True) -> List[List[LoadRequest]]:
    """Script one mixed request stream per client.

    Sizes are drawn uniformly from [1, max_rows] and models uniformly from
    ``models`` (default: every registered model), so a stream interleaves
    small/large and binary/multiclass traffic — the shape continuous
    batching has to get right. References are computed synchronously
    through each model's own bucketed decider BEFORE any load runs, so
    verification compares the concurrent path against the identical jit
    family."""
    names = list(models) if models else registry.names()
    streams: List[List[LoadRequest]] = []
    for c in range(clients):
        rng = np.random.default_rng(seed * 1000 + c)
        stream = []
        for _ in range(requests_per_client):
            name = names[int(rng.integers(len(names)))]
            entry = registry.get(name)
            n = int(rng.integers(1, max_rows + 1))
            X = rng.standard_normal((n, entry.d or d_fallback)) \
                   .astype(entry.dtype)
            ref = np.asarray(entry.decider(X)) if verify else None
            stream.append(LoadRequest(model=name, X=X, reference=ref))
        streams.append(stream)
    return streams


def run_load(target: Callable[[str, np.ndarray], object],
             streams: List[List[LoadRequest]], *,
             label: str, window: int = 1,
             atol: float = 0.0) -> LoadReport:
    """Fire every client stream concurrently at ``target``.

    ``target(model, X)`` submits one request and returns a future-like
    object whose ``.result()`` blocks until the margins are available (a
    plain ndarray is also accepted as an already-complete result). Each
    client keeps up to ``window`` submissions outstanding before awaiting
    the oldest — window=1 is a synchronous caller. Rejections
    (:class:`~repro.serve.batching.Rejected`, at submit or resolve time)
    are counted, not fatal. Responses are verified against each request's
    reference AFTER all clients finish, bitwise when ``atol`` is 0 and
    within ``atol`` otherwise, so verification cost never lands inside
    the timed region. Returns the aggregated :class:`LoadReport`."""
    window = max(int(window), 1)
    report = LoadReport(label=label, clients=len(streams),
                        requests=sum(len(s) for s in streams))
    lock = threading.Lock()
    latencies: List[float] = []
    responses: List[Tuple[LoadRequest, np.ndarray]] = []
    start_gate = threading.Barrier(len(streams) + 1)

    def client(stream: List[LoadRequest]) -> None:
        done = rejected = rows = 0
        lats: List[float] = []
        outs: List[Tuple[LoadRequest, np.ndarray]] = []
        pending: List[Tuple[float, LoadRequest, object]] = []

        def harvest(entry) -> None:
            nonlocal done, rejected, rows
            t0, req, fut = entry
            try:
                out = fut.result() if hasattr(fut, "result") else fut
            except Rejected:
                rejected += 1
                return
            lats.append(time.perf_counter() - t0)
            done += 1
            rows += req.X.shape[0]
            outs.append((req, np.asarray(out)))

        start_gate.wait()
        for req in stream:
            t0 = time.perf_counter()
            try:
                fut = target(req.model, req.X)
            except Rejected:
                rejected += 1
                continue
            pending.append((t0, req, fut))
            if len(pending) >= window:
                harvest(pending.pop(0))
        while pending:
            harvest(pending.pop(0))
        with lock:
            report.completed += done
            report.rejected += rejected
            report.rows += rows
            latencies.extend(lats)
            responses.extend(outs)

    threads = [threading.Thread(target=client, args=(s,), daemon=True)
               for s in streams]
    for t in threads:
        t.start()
    start_gate.wait()                    # all clients released together
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    report.wall_s = time.perf_counter() - t0
    report.rows_per_s = report.rows / max(report.wall_s, 1e-9)
    report.latency_ms = percentiles(latencies)

    # verification happens outside the timed region on purpose
    for req, out in responses:
        if req.reference is None:
            continue
        if out.shape != req.reference.shape:
            ok = False
        elif atol:
            ok = bool(np.allclose(out, req.reference, rtol=0.0, atol=atol))
        else:
            ok = bool(np.array_equal(out, req.reference))
        if not ok:
            report.mismatches += 1
    return report


def baseline_target(registry: ModelRegistry, *, workers: int = 64
                    ) -> Callable[[str, np.ndarray], object]:
    """The request-at-a-time strawman: one request holds the (single)
    dispatch slot start to finish — the old synchronous ``ServingEndpoint``
    semantics under concurrency. A worker pool accepts windowed
    submissions, but the global lock still serializes every dispatch;
    that serialization is the architecture under test, not the client
    pattern."""
    lock = threading.Lock()
    pool = ThreadPoolExecutor(max_workers=workers,
                              thread_name_prefix="serve-baseline")

    def work(model: str, X: np.ndarray) -> np.ndarray:
        with lock:
            return np.asarray(registry.get(model).decider(X))

    def call(model: str, X: np.ndarray):
        return pool.submit(work, model, X)

    call.close = lambda: pool.shutdown(wait=False)
    return call


def engine_target(engine) -> Callable[[str, np.ndarray], object]:
    """Adapter from the load harness calling convention to ServeEngine."""
    def call(model: str, X: np.ndarray):
        return engine.submit(X, model=model)

    return call
