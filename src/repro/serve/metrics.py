"""Serving metrics: thread-safe counters + the one percentile helper.

Every latency summary in the repo — ``kernel_serve``'s single-client
``serve_stream`` report, the :mod:`repro.serve.engine` selftest, and the
``benchmarks/serve_slo.py`` load harness — computes tail percentiles
through :func:`percentiles`, so the numbers can never disagree on
interpolation or unit conventions. Counters live in one lock-guarded
:class:`ServeMetrics` the engine mutates from its batcher thread and
readers snapshot atomically.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Sequence

import numpy as np


def percentiles(samples_s: Sequence[float],
                pcts: Iterable[int] = (50, 95, 99)) -> Dict[str, float]:
    """Latency percentiles in milliseconds from samples in seconds.

    Returns ``{"p50_ms": ..., "p95_ms": ..., "p99_ms": ...}`` (keys follow
    ``pcts``). Empty input yields zeros rather than NaN so a fully-rejected
    load phase still produces a well-formed report row.

    Tail percentiles use the "higher" order statistic — the smallest
    sample at or above the requested rank, index
    ``min(n-1, ceil(p/100 * (n-1)))`` into the sorted samples — never
    linear interpolation. On small samples (a smoke run with n < 100)
    interpolation would manufacture a p99 *below* the worst observation
    (with n=2 it reports ~the fast sample, silently collapsing the tail
    into the median); an SLO tail must be a latency some request actually
    paid. The index clamps at both ends, so n=1 reports that sample for
    every percentile instead of indexing out of range.
    """
    if not len(samples_s):
        return {f"p{p}_ms": 0.0 for p in pcts}
    lat_ms = np.sort(np.asarray(samples_s, dtype=np.float64)) * 1e3
    n = lat_ms.shape[0]
    out = {}
    for p in pcts:
        idx = min(n - 1, max(0, int(np.ceil(p / 100.0 * (n - 1)))))
        out[f"p{p}_ms"] = float(lat_ms[idx])
    return out


class ServeMetrics:
    """Monotonic serving counters (admission, batching, completion).

    ``occupancy()`` is the continuous-batching figure of merit: real rows
    dispatched / padded bucket rows dispatched — 1.0 means every bucket was
    exactly full, low values mean padding dominated. ``coalesced_requests /
    dispatches`` is how many callers each executable launch served.
    """

    _FIELDS = ("submitted", "completed", "rejected_full", "rejected_timeout",
               "rejected_open", "failed", "cancelled", "dispatches",
               "dispatched_rows", "padded_rows", "coalesced_requests",
               "breaker_opened", "breaker_closed")

    def __init__(self):
        self._lock = threading.Lock()
        for f in self._FIELDS:
            setattr(self, f, 0)
        # health is a gauge, not a counter: the engine's readiness state
        # ("starting"/"ready"/"degraded"/"draining") as of the last update
        self._health = "starting"

    def set_health(self, state: str) -> None:
        with self._lock:
            self._health = state

    @property
    def health(self) -> str:
        with self._lock:
            return self._health

    def add(self, **deltas: int) -> None:
        with self._lock:
            for name, dv in deltas.items():
                if name not in self._FIELDS:
                    raise AttributeError(f"unknown metric {name!r}")
                setattr(self, name, getattr(self, name) + dv)

    def occupancy(self) -> float:
        with self._lock:
            return self.dispatched_rows / self.padded_rows \
                if self.padded_rows else 0.0

    def requests_per_dispatch(self) -> float:
        with self._lock:
            return self.coalesced_requests / self.dispatches \
                if self.dispatches else 0.0

    def rejection_rate(self) -> float:
        with self._lock:
            rej = (self.rejected_full + self.rejected_timeout
                   + self.rejected_open)
            return rej / self.submitted if self.submitted else 0.0

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            snap = {f: getattr(self, f) for f in self._FIELDS}
            health = self._health
        snap["occupancy"] = (snap["dispatched_rows"] / snap["padded_rows"]
                            if snap["padded_rows"] else 0.0)
        snap["requests_per_dispatch"] = (
            snap["coalesced_requests"] / snap["dispatches"]
            if snap["dispatches"] else 0.0)
        rej = (snap["rejected_full"] + snap["rejected_timeout"]
               + snap["rejected_open"])
        snap["rejection_rate"] = (rej / snap["submitted"]
                                  if snap["submitted"] else 0.0)
        snap["health"] = health
        return snap
