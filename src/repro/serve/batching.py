"""Admission queue and futures for the continuous-batching engine.

The queue is the concurrency boundary of the serving subsystem: client
threads ``push`` requests under a single lock, the batcher thread calls
``next_batch`` to pop a *coalescible* run — FIFO requests for ONE model
whose total rows fit one ``max_rows`` dispatch — and everything else
(padding, jit, scatter) happens outside the lock. Admission control lives
here too: a bounded waiting queue (``QueueFull`` at push), and per-request
deadlines checked at pop time, so an expired request is rejected cleanly
instead of wasting a dispatch slot. Because the batcher wakes whenever the
queue is non-empty, an expired request is failed within one dispatch
interval — timeouts cannot wedge behind live traffic.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np


class Rejected(RuntimeError):
    """Base of every clean admission-control rejection."""


class QueueFull(Rejected):
    """The bounded waiting queue (or the in-flight cap) is at capacity."""


class RequestTimeout(Rejected):
    """The request's deadline expired before its rows were dispatched."""


class CircuitOpen(Rejected):
    """This model's circuit breaker is open after repeated dispatch
    failures — the request is fast-rejected without queueing. Retry after
    the breaker's cooldown (the next caller through probes the model)."""


class EngineStopped(RuntimeError):
    """The engine shut down while this request was pending."""


class ServeFuture:
    """One caller's pending margins. ``result()`` blocks until the batcher
    scatters this request's row slice back (or fails it)."""

    __slots__ = ("_event", "_value", "_exc")

    def __init__(self):
        self._event = threading.Event()
        self._value: Optional[np.ndarray] = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def set_result(self, value: np.ndarray) -> None:
        self._value = value
        self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """This request's (rows[, K]) margins. Raises the request's failure
        (:class:`RequestTimeout`, :class:`EngineStopped`, or the dispatch
        error) — or :class:`TimeoutError` if ``timeout`` seconds pass with
        the request still pending."""
        if not self._event.wait(timeout):
            raise TimeoutError("request still pending")
        if self._exc is not None:
            raise self._exc
        return self._value


@dataclasses.dataclass
class Request:
    """One admitted request: rows for one model plus its completion slot."""
    model: str
    X: np.ndarray
    future: ServeFuture
    deadline: Optional[float]      # time.monotonic() cutoff, None = never
    submitted_at: float

    @property
    def n(self) -> int:
        return self.X.shape[0]


class RequestQueue:
    """Bounded multi-model FIFO with coalescing pops.

    Requests are kept FIFO *per model* (coalescing never reorders one
    client's stream) and models with pending work are served round-robin,
    so a chatty model cannot starve a quiet one. ``next_batch`` returns
    ``(model, live, expired)``: the longest FIFO prefix of one model's
    queue whose rows sum to at most ``max_rows`` (always at least one
    request — oversize requests dispatch alone and split downstream),
    plus any requests whose deadline lapsed while queued.
    """

    def __init__(self, max_queue: int):
        self.max_queue = int(max_queue)
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._pending: Dict[str, Deque[Request]] = {}
        self._order: Deque[str] = collections.deque()   # round-robin cursor
        self._total = 0
        self._closed = False

    def __len__(self) -> int:
        with self._lock:
            return self._total

    def close(self) -> None:
        """Refuse pushes from now on (:class:`EngineStopped`).

        Called FIRST in engine shutdown, so a ``submit`` racing ``stop()``
        either lands before the close (and is failed by the drain) or is
        rejected here — it can never strand a request in a queue nobody
        will ever pop again."""
        with self._lock:
            self._closed = True
            self._nonempty.notify_all()

    def open(self) -> None:
        """Accept pushes again (engine restart after ``stop()``)."""
        with self._lock:
            self._closed = False

    def push(self, req: Request) -> None:
        with self._lock:
            if self._closed:
                raise EngineStopped("serve engine stopped")
            if self._total >= self.max_queue:
                raise QueueFull(
                    f"serving queue at capacity ({self.max_queue} waiting "
                    f"requests); retry or raise EngineConfig.max_queue")
            dq = self._pending.get(req.model)
            if dq is None:
                dq = self._pending[req.model] = collections.deque()
            if not dq:
                self._order.append(req.model)
            dq.append(req)
            self._total += 1
            self._nonempty.notify()

    def next_batch(self, max_rows: int, wait_s: float
                   ) -> Optional[Tuple[str, List[Request], List[Request]]]:
        """Pop one coalescible run, waiting up to ``wait_s`` for work.

        Returns ``None`` on timeout with an empty queue. ``live`` may be
        empty if every popped request had already expired."""
        now = time.monotonic()
        with self._lock:
            if not self._total:
                self._nonempty.wait(wait_s)
                if not self._total:
                    return None
                now = time.monotonic()
            model = self._order[0]
            dq = self._pending[model]
            live: List[Request] = []
            expired: List[Request] = []
            rows = 0
            while dq:
                head = dq[0]
                if head.deadline is not None and now > head.deadline:
                    expired.append(dq.popleft())
                    self._total -= 1
                    continue
                if live and rows + head.n > max_rows:
                    break                 # next dispatch picks it up
                live.append(dq.popleft())
                self._total -= 1
                rows += head.n
                if rows >= max_rows:
                    break
            self._order.popleft()
            if dq:
                self._order.append(model)   # rotate: other models next
            else:
                del self._pending[model]
            return model, live, expired

    def drain(self) -> List[Request]:
        """Remove and return every pending request (engine shutdown)."""
        with self._lock:
            out: List[Request] = []
            for dq in self._pending.values():
                out.extend(dq)
            self._pending.clear()
            self._order.clear()
            self._total = 0
            return out

    def notify(self) -> None:
        """Wake a blocked ``next_batch`` (used by engine stop)."""
        with self._lock:
            self._nonempty.notify_all()
