"""repro.serve — production serving over the plan registry's decide arms.

The paper stops at training (AllReduce on Hadoop); this package is the
deployment half the ROADMAP's "millions of users" north star asks for.
Prediction under every plan is collective-free batched kmvp work, so
serving reduces to batch formation: :class:`ServeEngine` continuously
coalesces concurrent clients' rows into the bucketed jit executables
(:class:`~repro.api.infer.BucketedDecider`), a :class:`ModelRegistry`
routes across side-by-side checkpoints, and admission control (bounded
queue, in-flight cap, per-request deadlines) turns overload into clean
:class:`Rejected` errors instead of collapse. ``repro.serve.loadgen``
is the SLO harness that proves the coalescing wins
(``benchmarks/serve_slo.py`` -> ``BENCH_serve.json``).

Self-healing (``repro.serve.health``): dispatch failures are contained to
their batch, a per-model :class:`CircuitBreaker` fast-rejects a
persistently failing model with :class:`CircuitOpen` until a cooldown
probe closes it again, and the engine publishes a readiness gauge
(STARTING/READY/DEGRADED/DRAINING) through :class:`ServeMetrics`.
"""
from repro.api.infer import BucketedDecider, bucket_rows, scatter_rows
from repro.serve.batching import (CircuitOpen, EngineStopped, QueueFull,
                                  Rejected, Request, RequestQueue,
                                  RequestTimeout, ServeFuture)
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.health import (DEGRADED, DRAINING, HEALTH_STATES, READY,
                                STARTING, CircuitBreaker)
from repro.serve.loadgen import (LoadReport, LoadRequest, baseline_target,
                                 engine_target, make_workload, run_load)
from repro.serve.metrics import ServeMetrics, percentiles
from repro.serve.registry import (ModelRegistry, ServedModel, model_dim,
                                  serving_plan)

__all__ = [
    "BucketedDecider", "bucket_rows", "scatter_rows",
    "ServeEngine", "EngineConfig",
    "ModelRegistry", "ServedModel", "model_dim", "serving_plan",
    "ServeFuture", "Request", "RequestQueue",
    "Rejected", "QueueFull", "RequestTimeout", "EngineStopped",
    "CircuitOpen", "CircuitBreaker", "HEALTH_STATES",
    "STARTING", "READY", "DEGRADED", "DRAINING",
    "ServeMetrics", "percentiles",
    "LoadRequest", "LoadReport", "make_workload", "run_load",
    "baseline_target", "engine_target",
]
