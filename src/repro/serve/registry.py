"""Model registry: several KernelMachine checkpoints served side by side.

Each registered model owns its plan-resolved decide arm and its own
:class:`~repro.api.infer.BucketedDecider` executable cache, so machines
with different solvers, plans, feature dimensions, or class counts never
share (or thrash) compiled buckets. The engine routes each request to its
model's decider; ``warmup()`` precompiles every bucket of every model so
first-request latency is compile-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.api.infer import BucketedDecider
from repro.api.machine import KernelMachine


def serving_plan(km: KernelMachine, plan: Optional[str]) -> str:
    """Resolve which decide arm serves request batches for ``km``. The
    stream arm is host-driven chunk I/O — wrong shape for latency serving —
    so stream machines flip to the dense local arm unless overridden."""
    plan = plan or km.config.plan
    if plan == "stream":
        plan = "local"
    return plan


def model_dim(km: KernelMachine) -> int:
    """Feature dimension d a machine's requests must carry: basis rows are
    (m, d) for Nyström solvers, omega is (d, D) for rff."""
    if "basis" in km.state_:
        return int(km.state_["basis"].shape[1])
    return int(km.state_["omega"].shape[0])


@dataclasses.dataclass(frozen=True)
class ServedModel:
    """One registry entry: the machine, its resolved plan, expected request
    feature dimension, margin class count (0 = binary (n,) margins), and
    the bucketed executable cache all its traffic runs through."""
    name: str
    km: KernelMachine
    plan: str
    d: int
    n_classes: int
    decider: BucketedDecider
    #: Request payload dtype: the machine's compute dtype, so clients under
    #: a bf16 policy ship half the wire bytes and warmup compiles the same
    #: jit family live traffic hits (dtype is part of the executable key).
    dtype: np.dtype = np.dtype(np.float32)


class ModelRegistry:
    """Name -> :class:`ServedModel` routing table for the serve engine."""

    def __init__(self, max_batch: int = 256):
        self.max_batch = int(max_batch)
        self._models: Dict[str, ServedModel] = {}
        self._default: Optional[str] = None

    def add(self, name: str, km: KernelMachine, *,
            plan: Optional[str] = None, max_batch: Optional[int] = None,
            backend: Optional[str] = None) -> ServedModel:
        """Register a fitted machine under ``name``. The first registration
        becomes the default route for requests that name no model."""
        if name in self._models:
            raise ValueError(f"model {name!r} already registered")
        if km.state_ is None:
            raise ValueError(f"model {name!r}: machine is not fitted")
        resolved = serving_plan(km, plan)
        beta = km.state_["beta"]
        entry = ServedModel(
            name=name, km=km, plan=resolved, d=model_dim(km),
            n_classes=int(beta.shape[1]) if beta.ndim == 2 else 0,
            decider=BucketedDecider(
                km.decider(plan=resolved, backend=backend),
                max_batch=self.max_batch if max_batch is None else max_batch),
            dtype=km.config.get_policy().np_compute_dtype())
        self._models[name] = entry
        if self._default is None:
            self._default = name
        return entry

    def load(self, name: str, path: str, **kwargs) -> ServedModel:
        """Register a checkpoint written by :meth:`KernelMachine.save`."""
        return self.add(name, KernelMachine.load(path), **kwargs)

    def get(self, name: Optional[str] = None) -> ServedModel:
        if name is None:
            if self._default is None:
                raise KeyError("registry is empty")
            name = self._default
        if name not in self._models:
            raise KeyError(f"unknown model {name!r}; registered: "
                           f"{self.names()}")
        return self._models[name]

    def remove(self, name: str) -> None:
        """Unregister a model. In-flight requests for it fail cleanly at
        dispatch (the engine guards its registry lookup); new submits are
        rejected at admission."""
        del self._models[name]
        if self._default == name:
            self._default = min(self._models) if self._models else None

    def names(self) -> List[str]:
        return sorted(self._models)

    def __len__(self) -> int:
        return len(self._models)

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def warmup(self) -> Dict[str, int]:
        """Precompile every bucket of every registered model; returns
        model -> executable count. Called by ``kernel_serve`` before it
        accepts traffic (``--no-warmup`` opts out)."""
        return {name: entry.decider.warmup(entry.d, entry.dtype)
                for name, entry in sorted(self._models.items())}
