"""Mixture-of-Experts FFN: top-k routing with capacity-bounded dispatch.

Mesh-TF/MaxText-style dense dispatch: tokens -> (E, capacity, d) via one-hot
einsums, expert SwiGLU applied batched over the expert dim, combine with
router weights. Compiled FLOPs are proportional to E * capacity * d * ff =
tokens * top_k * cf * d * ff — i.e. ACTIVE parameters only, so the roofline
table's MODEL_FLOPS = 6 * N_active * D comparison is honest.

Expert weights are stacked (E, d, ff); sharding: experts over the fsdp axes,
ff over the model axis (works for any expert count, incl. grok's 8 < 16).
An auxiliary load-balance loss (Switch-style) is returned to the caller.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, leaf
from repro.models.config import ArchConfig


def init_moe(key, cfg: ArchConfig):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": leaf(dense_init(ks[0], (d, E), dt), "embed", "experts"),
        "w1": leaf(dense_init(ks[1], (E, d, ff), dt, scale=d ** -0.5),
                   "experts", "embed", "ffn"),
        "w3": leaf(dense_init(ks[2], (E, d, ff), dt, scale=d ** -0.5),
                   "experts", "embed", "ffn"),
        "w2": leaf(dense_init(ks[3], (E, ff, d), dt, scale=ff ** -0.5),
                   "experts", "ffn", "embed"),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * ff
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": leaf(dense_init(kss[0], (d, sff), dt), "embed", "ffn"),
            "w3": leaf(dense_init(kss[1], (d, sff), dt), "embed", "ffn"),
            "w2": leaf(dense_init(kss[2], (sff, d), dt), "ffn", "embed"),
        }
    return p


MOE_GROUP = 512   # tokens per dispatch group (GShard-style)


def apply_moe(p, cfg: ArchConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    GShard-style grouped dispatch: tokens are split into groups of
    ``MOE_GROUP``; routing capacity is per-group, so the dispatch/combine
    tensors are (G, Sg, E, C) with Sg*E*C ~ Sg^2*k*cf elements per group —
    bounded and shardable over the token/group dim. (A single global-capacity
    dispatch tensor would be O(T^2) at 1M-token batches — untenable.)
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    Sg = min(MOE_GROUP, T)
    assert T % Sg == 0, (T, Sg)
    G = T // Sg
    xt = x.reshape(G, Sg, d)

    logits = (xt @ p["router"]).astype(jnp.float32)       # (G, Sg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)         # (G, Sg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(int(cfg.capacity_factor * Sg * k / E), 4)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)       # (G, Sg, k, E)
    flat = onehot.reshape(G, Sg * k, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(G, Sg, k, E)
    pos = jnp.sum(pos_in_e * onehot, axis=-1).astype(jnp.int32)    # (G, Sg, k)
    keep = pos < capacity
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * keep[..., None]

    dispatch = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot, pos_oh, gate_vals)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xt)  # (G,E,C,d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w1"])) * \
        jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])                    # (G,E,C,d)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    if cfg.n_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(xt @ sp["w1"]) * (xt @ sp["w3"])) @ sp["w2"]

    # Switch-style load-balance auxiliary loss
    frac_tokens = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))         # top-1 share
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef
    return y.reshape(B, S, d), aux
