"""Decoder-only LM assembling all families (dense / moe / ssm / hybrid / vlm).

Layer-stacking strategy: layers are grouped into PERIODS (jamba: 8 layers =
7 mamba + 1 attention; every other FFN is MoE; all other archs: period of 1).
Within a period the structure is static and unrolled; across periods the
structure repeats exactly, so parameters are stacked on a leading "layer"
axis and the period is a single ``lax.scan`` body (wrapped in jax.checkpoint
for training). This keeps the lowered HLO small — essential for compiling
88-layer/314B configs on the CPU dry-run host — and is the standard
production pattern (MaxText does the same).

VLM (phi-3-vision): the stub frontend supplies patch embeddings (B, P, d_vis)
which a learned projector maps to d_model and prepends to the token
embeddings; CE loss is masked to text positions.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (apply_mlp, cross_entropy, dense_init,
                                 init_mlp, leaf, prepend_axis, pscan, rms_norm,
                                 unzip)
from repro.models.config import ArchConfig
from repro.sharding.ctx import hint

D_VISION = 1024   # stubbed vision-encoder output dim (CLIP ViT-L/14)


def period_len(cfg: ArchConfig) -> int:
    return cfg.attn_period if cfg.family == "hybrid" else 1


def n_periods(cfg: ArchConfig) -> int:
    pl = period_len(cfg)
    assert cfg.n_layers % pl == 0, (cfg.n_layers, pl)
    return cfg.n_layers // pl


# ==================================================================== params
def init_lm(key, cfg: ArchConfig):
    """Annotated param tree; call common.unzip to split params/axes."""
    dt = cfg.jnp_dtype
    pl_ = period_len(cfg)
    np_ = n_periods(cfg)
    keys = jax.random.split(key, 8)

    def period_params(k):
        sub = {}
        kj = jax.random.split(k, pl_ * 4)
        for j in range(pl_):
            is_attn = cfg.is_attn_layer(j)
            is_moe = cfg.is_moe_layer(j)
            sub[f"ln1_{j}"] = leaf(jnp.ones((cfg.d_model,), dt), "embed")
            if is_attn:
                sub[f"mixer_{j}"] = attn.init_attention(kj[4 * j], cfg)
            else:
                sub[f"mixer_{j}"] = ssm_mod.init_ssm(kj[4 * j], cfg)
            if cfg.family == "ssm":
                continue  # mamba2: no separate FFN (d_ff = 0)
            sub[f"ln2_{j}"] = leaf(jnp.ones((cfg.d_model,), dt), "embed")
            if is_moe:
                sub[f"ffn_{j}"] = moe_mod.init_moe(kj[4 * j + 1], cfg)
            else:
                sub[f"ffn_{j}"] = init_mlp(kj[4 * j + 1], cfg.d_model,
                                           cfg.d_ff, cfg.mlp_variant, dt)
        return sub

    # stack periods on a leading "layer" axis via vmap over keys
    period_keys = jax.random.split(keys[0], np_)
    stacked = prepend_axis(jax.vmap(period_params)(period_keys), "layer")

    p = {
        "embed": leaf(dense_init(keys[1], (cfg.vocab_padded, cfg.d_model), dt, scale=0.02),
                      "vocab", "embed"),
        "final_norm": leaf(jnp.ones((cfg.d_model,), dt), "embed"),
        "blocks": stacked,
    }
    if not cfg.tie_embeddings:
        p["unembed"] = leaf(dense_init(keys[2], (cfg.d_model, cfg.vocab_padded), dt),
                            "embed", "vocab")
    if cfg.n_patches:
        p["vision_proj"] = leaf(dense_init(keys[3], (D_VISION, cfg.d_model), dt),
                                "vision", "embed")
    return p


# ==================================================================== forward
def _mixer_train(pj, cfg: ArchConfig, j: int, h, positions):
    if cfg.is_attn_layer(j):
        if cfg.use_mla:
            return attn.mla_train(pj, cfg, h, positions), 0.0
        return attn.attn_train(pj, cfg, h, positions), 0.0
    return ssm_mod.ssm_train(pj, cfg, h), 0.0


def _ffn_train(pj, cfg: ArchConfig, j: int, h):
    if cfg.is_moe_layer(j):
        return moe_mod.apply_moe(pj, cfg, h)
    return apply_mlp(pj, h, cfg.mlp_variant), 0.0


def period_body(cfg: ArchConfig, h, positions, pp):
    """One period (pl_ layers), pre-norm residual blocks."""
    aux = 0.0
    for j in range(period_len(cfg)):
        hn = rms_norm(h, pp[f"ln1_{j}"], cfg.norm_eps)
        mix, a1 = _mixer_train(pp[f"mixer_{j}"], cfg, j, hn, positions)
        h = h + mix
        if cfg.family != "ssm":
            hn = rms_norm(h, pp[f"ln2_{j}"], cfg.norm_eps)
            ff, a2 = _ffn_train(pp[f"ffn_{j}"], cfg, j, hn)
            h = h + ff
            aux = aux + a1 + a2
    return h, aux


def embed_inputs(params, cfg: ArchConfig, batch: Dict[str, Any]):
    """Token (+ patch) embedding. Returns (h, positions, loss_mask)."""
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.n_patches and "patch_embeds" in batch:
        pe = batch["patch_embeds"] @ params["vision_proj"]   # (B, P, d)
        h = jnp.concatenate([pe.astype(h.dtype), h], axis=1)[:, : S_tok + cfg.n_patches]
        S = h.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((B, pe.shape[1])), jnp.ones((B, S_tok))], axis=1)
    else:
        S = S_tok
        mask = jnp.ones((B, S))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return hint(h, "batch", None, None), positions, mask


def forward_lm(params, cfg: ArchConfig, batch, *, remat: bool = True):
    """Full-sequence forward. Returns (logits, aux_loss, loss_mask)."""
    h, positions, mask = embed_inputs(params, cfg, batch)

    carry_spec = ("batch", None, "model") if cfg.shard_carry else \
        ("batch", None, None)
    pps = max(cfg.periods_per_scan_step, 1)
    blocks = params["blocks"]
    if pps > 1:
        assert n_periods(cfg) % pps == 0, (n_periods(cfg), pps)
        blocks = jax.tree.map(
            lambda x: x.reshape(x.shape[0] // pps, pps, *x.shape[1:]), blocks)

    def body(carry, pp):
        h, aux = carry
        if pps > 1:
            for j in range(pps):
                h, a = period_body(cfg, h, positions,
                                   jax.tree.map(lambda x: x[j], pp))
                aux = aux + a
        else:
            h, a = period_body(cfg, h, positions, pp)
            aux = aux + a
        return (hint(h, *carry_spec), aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), _ = pscan(body, (h, 0.0), blocks)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = hint(h @ unembed, "batch", None, "model")
    return logits, aux, mask


def lm_loss(params, cfg: ArchConfig, batch, *, remat: bool = True):
    logits, aux, mask = forward_lm(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    if cfg.n_patches and "patch_embeds" in batch:
        # loss over text positions only; logits for text start after patches
        P = batch["patch_embeds"].shape[1]
        logits = logits[:, P:, :]
    logits_f = logits.astype(jnp.float32)
    vocab_iota = jnp.arange(cfg.vocab_padded)
    if cfg.vocab_padded != cfg.vocab:   # mask the padded vocab ids out
        logits_f = jnp.where(vocab_iota < cfg.vocab, logits_f, -1e30)
    lse = jax.scipy.special.logsumexp(logits_f, axis=-1)
    # label logit via fused masked-reduce: partition-friendly over a
    # vocab-sharded logits tensor (no gather / no one-hot materialization)
    gold = jnp.sum(jnp.where(vocab_iota[None, None, :] == labels[..., None],
                             logits_f, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    return ce + aux, {"ce": ce, "aux": aux}


# ===================================================================== decode
class LMCache(NamedTuple):
    layers: Any          # dict keyed by f"{kind}_{j}" of stacked caches
    pos: jnp.ndarray     # scalar int32 — next position to write


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> LMCache:
    np_ = n_periods(cfg)
    caches = {}
    for j in range(period_len(cfg)):
        if cfg.is_attn_layer(j):
            if cfg.use_mla:
                caches[f"mla_{j}"] = attn.init_mla_cache(cfg, batch, max_seq, np_)
            else:
                caches[f"kv_{j}"] = attn.init_kv_cache(cfg, batch, max_seq, np_)
        else:
            caches[f"ssm_{j}"] = ssm_mod.init_ssm_cache(cfg, batch, np_)
    return LMCache(layers=caches, pos=jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ArchConfig, tokens, cache: LMCache):
    """One-token decode. tokens: (B, 1). Returns (logits, new_cache)."""
    pos = cache.pos
    h = jnp.take(params["embed"], tokens, axis=0)

    def body(h, xs):
        pp, layer_caches = xs
        new_caches = {}
        for j in range(period_len(cfg)):
            hn = rms_norm(h, pp[f"ln1_{j}"], cfg.norm_eps)
            if cfg.is_attn_layer(j):
                key = f"mla_{j}" if cfg.use_mla else f"kv_{j}"
                fn = attn.mla_decode if cfg.use_mla else attn.attn_decode
                mix, nc = fn(pp[f"mixer_{j}"], cfg, hn, layer_caches[key], pos)
                new_caches[key] = nc
            else:
                mix, nc = ssm_mod.ssm_decode(pp[f"mixer_{j}"], cfg, hn,
                                             layer_caches[f"ssm_{j}"], pos)
                new_caches[f"ssm_{j}"] = nc
            h = h + mix
            if cfg.family != "ssm":
                hn = rms_norm(h, pp[f"ln2_{j}"], cfg.norm_eps)
                ff, _ = _ffn_train(pp[f"ffn_{j}"], cfg, j, hn)
                h = h + ff
        return h, new_caches

    h, new_layer_caches = pscan(body, h, (params["blocks"], cache.layers))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = h @ unembed
    return logits, LMCache(layers=new_layer_caches, pos=pos + 1)
