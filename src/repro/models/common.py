"""Shared model-building blocks (pure-JAX, pytree params, no flax).

Every parameter leaf is created through ``leaf(value, axes)`` where ``axes``
names each dim logically ("embed", "heads", "ffn", "vocab", "experts",
"layer", ...). ``unzip`` splits the annotated tree into (params, axes);
repro.sharding.partitioning maps logical names -> mesh PartitionSpecs.
Init functions are jit/eval_shape-traceable, so the dry-run builds the full
236B/314B parameter trees as ShapeDtypeStructs with zero allocation.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Leaf:
    value: Any
    axes: Tuple[Optional[str], ...]


# Registered pytree node: value is a child (vmap/jit can batch/trace it),
# axes ride along as static aux data.
jax.tree_util.register_pytree_node(
    Leaf,
    lambda l: ((l.value,), l.axes),
    lambda axes, ch: Leaf(ch[0], axes),
)


def leaf(value, *axes):
    return Leaf(value, tuple(axes))


def _is_leaf(x):
    return isinstance(x, Leaf)


def unzip(tree):
    """Split an annotated tree into (params, axes) plain trees."""
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=_is_leaf)
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=_is_leaf)
    return params, axes


def prepend_axis(tree, name: str):
    """Prepend a logical axis name to every Leaf (used after vmap-stacking)."""
    return jax.tree.map(lambda l: Leaf(l.value, (name,) + l.axes),
                        tree, is_leaf=_is_leaf)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    if scale is None:
        scale = shape[0] ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ----------------------------------------------------------------- scan
# XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
# which would corrupt the roofline FLOP/byte/collective accounting. The
# dry-run therefore lowers shallow probe models with every scan UNROLLED
# (exact op counts), extrapolating depth linearly; production lowering keeps
# rolled scans (small HLO). All model scans go through pscan().
_UNROLL_SCANS = False


@contextlib.contextmanager
def unrolled_scans():
    global _UNROLL_SCANS
    old = _UNROLL_SCANS
    _UNROLL_SCANS = True
    try:
        yield
    finally:
        _UNROLL_SCANS = old


def pscan(body, carry, xs, length=None):
    """lax.scan honouring the unrolled_scans() context."""
    if _UNROLL_SCANS:
        n = length if length is not None else len(jax.tree.leaves(xs)[0])
        return jax.lax.scan(body, carry, xs, length=length, unroll=n)
    return jax.lax.scan(body, carry, xs, length=length)


# ----------------------------------------------------------------- norms
def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------- rope
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) or (S,). Llama half-split rotation."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    ang = ang[..., None, :]                                 # (B, S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- mlp
def init_mlp(key, d: int, ff: int, variant: str, dtype):
    ks = jax.random.split(key, 3)
    if variant == "swiglu":
        return {
            "w1": leaf(dense_init(ks[0], (d, ff), dtype), "embed", "ffn"),
            "w3": leaf(dense_init(ks[1], (d, ff), dtype), "embed", "ffn"),
            "w2": leaf(dense_init(ks[2], (ff, d), dtype), "ffn", "embed"),
        }
    return {  # gelu
        "w1": leaf(dense_init(ks[0], (d, ff), dtype), "embed", "ffn"),
        "b1": leaf(jnp.zeros((ff,), dtype), "ffn"),
        "w2": leaf(dense_init(ks[2], (ff, d), dtype), "ffn", "embed"),
        "b2": leaf(jnp.zeros((d,), dtype), "embed"),
    }


def apply_mlp(p, x, variant: str):
    if variant == "swiglu":
        return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


# ----------------------------------------------------------------- loss
def cross_entropy(logits, labels, z_coef: float = 0.0):
    """Mean token CE in f32; optional z-loss for stability."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - gold)
    if z_coef:
        ce = ce + z_coef * jnp.mean(jnp.square(lse))
    return ce
