"""Whisper-style encoder-decoder (arXiv:2212.04356), transformer backbone only.

Per the brief, the mel-spectrogram + conv frontend is STUBBED: the model
consumes precomputed frame embeddings (B, T_enc, d_model) supplied by
input_specs(). Encoder: bidirectional self-attention, GELU MLP, LayerNorm,
learned positions. Decoder: causal self-attention + cross-attention to the
encoder output. Decode caches: self-attn KV ring + precomputed cross KV.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (dense_init, layer_norm, leaf, prepend_axis,
                                 pscan, rms_norm, unzip)
from repro.models.config import ArchConfig
from repro.sharding.ctx import hint


def _init_ln(d, dt):
    return {"w": leaf(jnp.ones((d,), dt), "embed"),
            "b": leaf(jnp.zeros((d,), dt), "embed")}


def _apply_ln(p, x, eps):
    return layer_norm(x, p["w"], p["b"], eps)


def _init_xattn(key, cfg: ArchConfig):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    return {
        "wq": leaf(dense_init(ks[0], (d, H * hd), dt), "embed", "heads"),
        "wk": leaf(dense_init(ks[1], (d, H * hd), dt), "embed", "heads"),
        "wv": leaf(dense_init(ks[2], (d, H * hd), dt), "embed", "heads"),
        "wo": leaf(dense_init(ks[3], (H * hd, d), dt), "heads", "embed"),
    }


def _init_gelu_mlp(key, cfg: ArchConfig):
    from repro.models.common import init_mlp
    return init_mlp(key, cfg.d_model, cfg.d_ff, "gelu", cfg.jnp_dtype)


def init_encdec(key, cfg: ArchConfig, max_dec_seq: int = 4096):
    dt = cfg.jnp_dtype
    keys = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": _init_ln(cfg.d_model, dt),
                "self": _init_xattn(k1, cfg),
                "ln2": _init_ln(cfg.d_model, dt),
                "mlp": _init_gelu_mlp(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": _init_ln(cfg.d_model, dt),
                "self": attn.init_attention(k1, cfg),
                "ln_x": _init_ln(cfg.d_model, dt),
                "cross": _init_xattn(k2, cfg),
                "ln2": _init_ln(cfg.d_model, dt),
                "mlp": _init_gelu_mlp(k3, cfg)}

    enc = prepend_axis(jax.vmap(enc_layer)(
        jax.random.split(keys[0], cfg.encoder_layers)), "layer")
    dec = prepend_axis(jax.vmap(dec_layer)(
        jax.random.split(keys[1], cfg.n_layers)), "layer")
    return {
        "enc_pos": leaf(dense_init(keys[2], (cfg.encoder_seq, cfg.d_model), dt,
                                   scale=0.02), None, "embed"),
        "enc_blocks": enc,
        "enc_final_ln": _init_ln(cfg.d_model, dt),
        "embed": leaf(dense_init(keys[3], (cfg.vocab_padded, cfg.d_model), dt, scale=0.02),
                      "vocab", "embed"),
        "dec_pos": leaf(dense_init(keys[4], (max_dec_seq, cfg.d_model), dt, scale=0.02),
                        None, "embed"),
        "dec_blocks": dec,
        "dec_final_ln": _init_ln(cfg.d_model, dt),
    }


def _bidir_attn(p, cfg: ArchConfig, q_in, kv_in):
    """Plain bidirectional MHA (encoder self-attn / decoder cross-attn)."""
    B, Sq, d = q_in.shape
    Sk = kv_in.shape[1]
    H, hd = cfg.n_heads, cfg.hd
    q = (q_in @ p["wq"]).reshape(B, Sq, H, hd)
    k = (kv_in @ p["wk"]).reshape(B, Sk, H, hd)
    v = (kv_in @ p["wv"]).reshape(B, Sk, H, hd)
    qg = q[:, :, :, None, :]                              # Kv=H, G=1
    pos_q = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    out = attn._flash(qg, k, v, pos_q, 0, causal=False, window=0, blk=1024)
    return out.reshape(B, Sq, H * hd) @ p["wo"]


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, T_enc, d_model) — stub frontend output."""
    h = hint(frames + params["enc_pos"][None, : frames.shape[1]],
             "batch", None, None)

    def body(h, pp):
        x = _apply_ln(pp["ln1"], h, cfg.norm_eps)
        h = h + _bidir_attn(pp["self"], cfg, x, x)
        x = _apply_ln(pp["ln2"], h, cfg.norm_eps)
        from repro.models.common import apply_mlp
        h = h + apply_mlp(pp["mlp"], x, "gelu")
        return h, None

    h, _ = pscan(jax.checkpoint(body, prevent_cse=False), h,
                 params["enc_blocks"])
    return _apply_ln(params["enc_final_ln"], h, cfg.norm_eps)


def decoder_forward(params, cfg: ArchConfig, tokens, enc_out):
    """Teacher-forced decoder. tokens: (B, S)."""
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][None, :S]
    h = hint(h, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(h, pp):
        x = _apply_ln(pp["ln1"], h, cfg.norm_eps)
        h = h + attn.attn_train(pp["self"], cfg, x, positions)
        x = _apply_ln(pp["ln_x"], h, cfg.norm_eps)
        h = h + _bidir_attn(pp["cross"], cfg, x, enc_out)
        x = _apply_ln(pp["ln2"], h, cfg.norm_eps)
        from repro.models.common import apply_mlp
        h = h + apply_mlp(pp["mlp"], x, "gelu")
        return h, None

    h, _ = pscan(jax.checkpoint(body, prevent_cse=False), h,
                 params["dec_blocks"])
    h = _apply_ln(params["dec_final_ln"], h, cfg.norm_eps)
    return hint(h @ params["embed"].T, "batch", None, "model")  # tied unembed


def encdec_loss(params, cfg: ArchConfig, batch):
    enc_out = encode(params, cfg, batch["frames"])
    logits = decoder_forward(params, cfg, batch["tokens"], enc_out)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    vocab_iota = jnp.arange(cfg.vocab_padded)
    if cfg.vocab_padded != cfg.vocab:
        lf = jnp.where(vocab_iota < cfg.vocab, lf, -1e30)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.sum(jnp.where(vocab_iota[None, None, :] == labels[..., None],
                             lf, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    return ce, {"ce": ce, "aux": jnp.zeros(())}


# ------------------------------------------------------------------- decode
class EncDecCache(NamedTuple):
    self_kv: attn.KVCache        # (L, B, S, H, hd) decoder self-attn
    cross_k: jnp.ndarray         # (L, B, T_enc, H*hd) precomputed
    cross_v: jnp.ndarray
    pos: jnp.ndarray


def init_encdec_cache(params, cfg: ArchConfig, frames, max_seq: int):
    """Runs the encoder and precomputes per-layer cross K/V."""
    B = frames.shape[0]
    enc_out = encode(params, cfg, frames)

    def kv(pp):
        return enc_out @ pp["cross"]["wk"], enc_out @ pp["cross"]["wv"]

    ck, cv = jax.vmap(kv)(params["dec_blocks"])           # (L, B, T, H*hd)
    self_kv = attn.init_kv_cache(cfg, B, max_seq, cfg.n_layers)
    return EncDecCache(self_kv=self_kv, cross_k=ck, cross_v=cv,
                       pos=jnp.zeros((), jnp.int32))


def encdec_decode_step(params, cfg: ArchConfig, tokens, cache: EncDecCache):
    """One-token decode with cross-attention to cached encoder K/V."""
    B = tokens.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    pos = cache.pos
    h = jnp.take(params["embed"], tokens, axis=0) + \
        jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)[None, 0:1]

    def body(h, xs):
        pp, kvc, ck, cv = xs
        x = _apply_ln(pp["ln1"], h, cfg.norm_eps)
        sa, kvc = attn.attn_decode(pp["self"], cfg, x, kvc, pos)
        h = h + sa
        x = _apply_ln(pp["ln_x"], h, cfg.norm_eps)
        q = (x @ pp["cross"]["wq"]).reshape(B, 1, H, hd)
        k = ck.reshape(B, -1, H, hd)
        v = cv.reshape(B, -1, H, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * hd ** -0.5
        w = jax.nn.softmax(s, axis=-1)
        ca = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
        h = h + ca.reshape(B, 1, H * hd).astype(h.dtype) @ pp["cross"]["wo"]
        x = _apply_ln(pp["ln2"], h, cfg.norm_eps)
        from repro.models.common import apply_mlp
        h = h + apply_mlp(pp["mlp"], x, "gelu")
        return h, kvc

    h, new_kv = pscan(
        body, h, (params["dec_blocks"], cache.self_kv, cache.cross_k,
                  cache.cross_v))
    h = _apply_ln(params["dec_final_ln"], h, cfg.norm_eps)
    logits = h @ params["embed"].T
    return logits, EncDecCache(self_kv=new_kv, cross_k=cache.cross_k,
                               cross_v=cache.cross_v, pos=pos + 1)
