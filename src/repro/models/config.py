"""Architecture configuration. One frozen dataclass covers all 6 families;
family-specific fields are zero/empty when unused. Each assigned arch gets a
module in repro/configs/ instantiating this with its exact published values.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 32000
    # --- attention options -------------------------------------------------
    attention_variant: str = "full"  # full | sliding | nystrom
    window: int = 8192               # sliding-window width
    n_landmarks: int = 128           # nystrom attention landmarks
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10_000.0
    # --- MLA (deepseek-v2) --------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden size
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1               # MoE FFN on layers with i % moe_every == moe_offset
    moe_offset: int = 0
    router_aux_coef: float = 0.01    # load-balance loss coefficient
    # --- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4
    # --- hybrid (jamba) -------------------------------------------------------
    attn_period: int = 0             # 1 attention layer per `attn_period` layers
    attn_index: int = 0              # position of the attn layer inside the period
    # --- enc-dec (whisper) ----------------------------------------------------
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30s audio -> 1500 frames
    # --- vlm ------------------------------------------------------------------
    n_patches: int = 0               # image patch embeddings prepended (stub frontend)
    # --- misc ------------------------------------------------------------------
    periods_per_scan_step: int = 1   # periods grouped per scan step: saves
                                     # 1/k of the remat carries (k-1 extra
                                     # within-group recomputes in bwd)
    shard_carry: bool = False        # shard remat-saved residual stream over
                                     # the model axis (adds a per-period
                                     # all-gather; cuts the saved-activation
                                     # stack by the model-axis size)
    mlp_variant: str = "swiglu"      # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # params/activations dtype for dry-run
    citation: str = ""

    # ------------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab dim shards
        evenly over the 16-way model axis (standard practice; logits for
        padded ids are masked out of the loss)."""
        return ((self.vocab + 255) // 256) * 256

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return i % self.attn_period == self.attn_index
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return i % self.moe_every == self.moe_offset

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, **kw) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny dims (brief: <=2
        layers, d_model <= 512, <= 4 experts)."""
        small = dict(
            n_layers=2 if self.family != "hybrid" else max(self.attn_period, 2),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=64 if self.n_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            kv_lora_rank=min(self.kv_lora_rank, 64) if self.kv_lora_rank else 0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            qk_nope_dim=32 if self.use_mla else self.qk_nope_dim,
            qk_rope_dim=16 if self.use_mla else self.qk_rope_dim,
            v_head_dim=32 if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=16,
            encoder_layers=2 if self.is_encdec else 0,
            encoder_seq=64 if self.is_encdec else self.encoder_seq,
            n_patches=min(self.n_patches, 16),
            n_landmarks=16,
            window=64,
            dtype="float32",
        )
        small.update(kw)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
