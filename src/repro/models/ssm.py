"""Mamba-2 (SSD, state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: within-chunk quadratic (attention-like, MXU-friendly)
term + inter-chunk state recurrence carried by lax.scan — the TPU-idiomatic
split of the paper's blocked algorithm. Decode is an O(1) per-token state
update (this is what makes long_500k native for ssm/hybrid archs).

Projections are kept as separate matrices (z, x, B, C, dt) so each shards
cleanly over the model axis without resharding the fused projection.
Adaptations vs the CUDA reference (noted in DESIGN.md): causal conv applied
to x only; B/C shared across heads (single group); chunk state carried in
f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, leaf, pscan, rms_norm
from repro.models.config import ArchConfig


def init_ssm(key, cfg: ArchConfig):
    d, di, N, Hs = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 8)
    return {
        "in_z": leaf(dense_init(ks[0], (d, di), dt), "embed", "ssm_inner"),
        "in_x": leaf(dense_init(ks[1], (d, di), dt), "embed", "ssm_inner"),
        "in_B": leaf(dense_init(ks[2], (d, N), dt), "embed", "state"),
        "in_C": leaf(dense_init(ks[3], (d, N), dt), "embed", "state"),
        "in_dt": leaf(dense_init(ks[4], (d, Hs), dt), "embed", "ssm_heads"),
        "conv_w": leaf(dense_init(ks[5], (cfg.conv_width, di), dt, scale=0.5),
                       "conv", "ssm_inner"),
        "conv_b": leaf(jnp.zeros((di,), dt), "ssm_inner"),
        "A_log": leaf(jnp.log(jnp.linspace(1.0, 16.0, Hs)).astype(jnp.float32),
                      "ssm_heads"),
        "dt_bias": leaf(jnp.zeros((Hs,), jnp.float32), "ssm_heads"),
        "D": leaf(jnp.ones((Hs,), jnp.float32), "ssm_heads"),
        "out_norm": leaf(jnp.ones((di,), dt), "ssm_inner"),
        "out_w": leaf(dense_init(ks[6], (di, d), dt), "ssm_inner", "embed"),
    }


def _causal_conv(x, w, b):
    """x: (B, S, di); w: (W, di) depthwise causal conv."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def _segsum(dA):
    """Cumulative decay matrix: L[i,j] = sum_{j<k<=i} dA_k for j<=i else -inf.
    dA: (..., Q). Returns (..., Q, Q) lower-triangular log-decay."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_{j<k<=i}
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(xh, dt, Bm, Cm, A, chunk: int):
    """Chunked SSD. xh: (B,S,H,P); dt: (B,S,H) (post-softplus);
    Bm, Cm: (B,S,N); A: (H,) negative decay rates. Returns (B,S,H,P).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    xc = xh.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    dA = dtc * A[None, None, None, :]                   # (B,nc,Q,H) log-decay

    # ---- within-chunk (quadratic, MXU) term
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))      # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)      # (B,nc,Q,Q)
    M = scores[:, :, None] * L                          # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]                           # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M, xdt)

    # ---- chunk summary states
    dA_cs = jnp.cumsum(dA, axis=2)                      # (B,nc,Q,H)
    dA_tot = dA_cs[:, :, -1:, :]                        # (B,nc,1,H)
    decay_to_end = jnp.exp(dA_tot - dA_cs)              # (B,nc,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                        Bc, dtc * decay_to_end, xc)     # (B,nc,H,N,P)

    # ---- inter-chunk recurrence (sequential scan over chunks)
    def step(h, inp):
        st, da_tot = inp                                # (B,H,N,P), (B,H)
        h_new = jnp.exp(da_tot)[:, :, None, None] * h + st
        return h_new, h                                 # emit PREVIOUS state

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, h_prev = pscan(
        step, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(dA_tot[:, :, 0, :], 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                 # (B,nc,H,N,P)

    # ---- inter-chunk contribution
    decay_from_start = jnp.exp(dA_cs)                   # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, decay_from_start, h_prev)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(xh.dtype)


def ssm_train(p, cfg: ArchConfig, h):
    """Full-sequence SSD block. h: (B, S, d)."""
    B, S, d = h.shape
    Hs, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = h @ p["in_z"]
    x = _causal_conv(h @ p["in_x"], p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    Bm = h @ p["in_B"]
    Cm = h @ p["in_C"]
    dt = jax.nn.softplus((h @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                            # (Hs,)
    xh = x.reshape(B, S, Hs, P)
    y = ssd_scan(xh, dt, Bm, Cm, A, cfg.ssm_chunk)
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, Hs * P)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["out_w"]


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # (B, W-1, di) — last conv_width-1 inputs
    state: jnp.ndarray   # (B, H, N, P) f32 recurrent state


def init_ssm_cache(cfg: ArchConfig, batch: int, layers: int):
    di = cfg.d_inner
    return SSMCache(
        conv=jnp.zeros((layers, batch, cfg.conv_width - 1, di), cfg.jnp_dtype),
        state=jnp.zeros((layers, batch, cfg.ssm_heads, cfg.ssm_state,
                         cfg.ssm_headdim), jnp.float32),
    )


def ssm_decode(p, cfg: ArchConfig, h, cache: SSMCache, pos):
    """O(1) single-token state update. h: (B, 1, d)."""
    del pos
    B = h.shape[0]
    Hs, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = h @ p["in_z"]                                   # (B,1,di)
    xin = h @ p["in_x"]
    conv_in = jnp.concatenate([cache.conv, xin], axis=1)  # (B, W, di)
    x = jnp.einsum("bwd,wd->bd", conv_in, p["conv_w"]) + p["conv_b"]
    x = jax.nn.silu(x)                                  # (B, di)
    new_conv = conv_in[:, 1:, :]
    Bm = (h @ p["in_B"])[:, 0].astype(jnp.float32)      # (B,N)
    Cm = (h @ p["in_C"])[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus((h @ p["in_dt"])[:, 0].astype(jnp.float32)
                         + p["dt_bias"])                # (B,Hs)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, Hs, P).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])                    # (B,Hs)
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bm, dt, xh)
    state = decay[:, :, None, None] * cache.state + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm, state)           # (B,Hs,P)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, Hs * P).astype(h.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["out_w"], SSMCache(conv=new_conv, state=state)
