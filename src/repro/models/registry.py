"""Model API registry: uniform (init / loss / decode) surface over families.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given assigned input shape — weak-type-correct,
shardable, no device allocation — consumed by both the dry-run and tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_mod
from repro.models import transformer as lm_mod
from repro.models.common import unzip
from repro.models.config import ArchConfig, ShapeSpec, INPUT_SHAPES
from repro.models.transformer import D_VISION


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable          # key -> annotated param tree (use common.unzip)
    loss: Callable          # (params, batch) -> (loss, metrics)
    init_cache: Callable    # (params, batch, max_seq) -> cache
    decode_step: Callable   # (params, tokens, cache) -> (logits, cache)


def make_model(cfg: ArchConfig, *, max_dec_seq: int = 4096) -> ModelAPI:
    if cfg.is_encdec:
        return ModelAPI(
            cfg=cfg,
            init=lambda key: encdec_mod.init_encdec(key, cfg, max_dec_seq),
            loss=lambda p, b: encdec_mod.encdec_loss(p, cfg, b),
            init_cache=lambda p, b, s: encdec_mod.init_encdec_cache(
                p, cfg, b["frames"], s),
            decode_step=lambda p, t, c: encdec_mod.encdec_decode_step(
                p, cfg, t, c),
        )
    return ModelAPI(
        cfg=cfg,
        init=lambda key: lm_mod.init_lm(key, cfg),
        loss=lambda p, b: lm_mod.lm_loss(p, cfg, b),
        init_cache=lambda p, b, s: lm_mod.init_cache(
            cfg, b["tokens"].shape[0], s),
        decode_step=lambda p, t, c: lm_mod.decode_step(p, cfg, t, c),
    )


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for (cfg, shape). For train/prefill the model
    consumes the full assigned sequence (VLM: patches + text sum to seq_len;
    whisper: encoder frames + decoder tokens). For decode shapes the batch
    is the ONE-token step input; the KV cache spec comes from cache_specs."""
    B, S = shape.global_batch, shape.seq_len
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    f = functools.partial(jax.ShapeDtypeStruct, dtype=cfg.jnp_dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.is_encdec:
            # encoder consumes its fixed frame count; decoder gets the rest
            s_dec = S - cfg.encoder_seq
            assert s_dec > 0, (
                f"enc-dec shape needs seq_len > encoder_seq "
                f"({S} <= {cfg.encoder_seq})")
            return {"frames": f((B, cfg.encoder_seq, cfg.d_model)),
                    "tokens": i32((B, s_dec)), "labels": i32((B, s_dec))}
        if cfg.n_patches:
            s_txt = S - cfg.n_patches
            return {"tokens": i32((B, s_txt)), "labels": i32((B, s_txt)),
                    "patch_embeds": f((B, cfg.n_patches, D_VISION))}
        return {"tokens": i32((B, S)), "labels": i32((B, S))}
    # decode: one new token against a seq_len-deep cache
    return {"tokens": i32((B, 1))}


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract KV/state-cache pytree for a decode shape (eval_shape only)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        model = make_model(cfg, max_dec_seq=S)
        params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params_spec, _ = unzip(params_spec)
        frames = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                      cfg.jnp_dtype)
        return jax.eval_shape(
            lambda p, fr: encdec_mod.init_encdec_cache(p, cfg, fr, S),
            params_spec, frames)
    return jax.eval_shape(lambda: lm_mod.init_cache(cfg, B, S))
