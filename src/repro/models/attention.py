"""Attention: GQA (full / sliding / nystrom) + MLA, train and decode paths.

* full/sliding use a chunked online-softmax (flash-style) scan over key
  blocks — O(S * blk) memory instead of O(S^2), which is what lets the
  32k-prefill shapes fit VMEM/HBM budgets.
* ``nystrom`` is the paper-kindred sub-quadratic variant: the softmax kernel
  matrix is Nystrom-approximated with segment-mean landmarks and the m x m
  inverse is obtained by ITERATIVE Newton-Schulz — the same
  "avoid the explicit pseudo-inverse" insight as the paper's formulation (4).
* MLA (deepseek-v2) caches only the compressed c_kv + shared rope key; the
  decode path uses the absorbed form (q W_uk^T c_kv), never expanding heads.

Decode caches:
  full/nystrom: (k, v) rings of length min(S_max, window or S_max)
  sliding:      fixed ring buffer of ``window`` slots (sub-quadratic decode)
  mla:          (c_kv, k_rope) — rank-compressed
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, leaf, pscan, rms_norm
from repro.models.config import ArchConfig

NEG_INF = -1e30


# ===================================================================== params
def init_attention(key, cfg: ArchConfig):
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 8)
    if cfg.use_mla:
        qk_hd = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "wkv_a": leaf(dense_init(ks[0], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dt),
                          "embed", "kv_lora"),
            "kv_norm": leaf(jnp.ones((cfg.kv_lora_rank,), dt), "kv_lora"),
            "wkv_b": leaf(dense_init(ks[1], (cfg.kv_lora_rank,
                                             H * (cfg.qk_nope_dim + cfg.v_head_dim)), dt),
                          "kv_lora", "heads"),
            "wo": leaf(dense_init(ks[2], (H * cfg.v_head_dim, d), dt), "heads", "embed"),
        }
        if cfg.q_lora_rank:
            p["wq_a"] = leaf(dense_init(ks[3], (d, cfg.q_lora_rank), dt), "embed", "q_lora")
            p["q_norm"] = leaf(jnp.ones((cfg.q_lora_rank,), dt), "q_lora")
            p["wq_b"] = leaf(dense_init(ks[4], (cfg.q_lora_rank, H * qk_hd), dt),
                             "q_lora", "heads")
        else:
            p["wq"] = leaf(dense_init(ks[3], (d, H * qk_hd), dt), "embed", "heads")
        return p
    p = {
        "wq": leaf(dense_init(ks[0], (d, H * hd), dt), "embed", "heads"),
        "wk": leaf(dense_init(ks[1], (d, Kv * hd), dt), "embed", "kv"),
        "wv": leaf(dense_init(ks[2], (d, Kv * hd), dt), "embed", "kv"),
        "wo": leaf(dense_init(ks[3], (H * hd, d), dt), "heads", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = leaf(jnp.ones((hd,), dt), "head_dim")
        p["k_norm"] = leaf(jnp.ones((hd,), dt), "head_dim")
    return p


# ============================================================ chunked softmax
def _flash(q, k, v, q_pos, k_pos0, *, causal: bool, window: int, blk: int):
    """Online-softmax attention.

    q: (B, Sq, Kv, G, hd); k, v: (B, Sk, Kv, hd)
    q_pos: (B, Sq) absolute positions; keys occupy k_pos0 .. k_pos0+Sk-1.
    Returns (B, Sq, Kv, G, hd) in q.dtype; accumulators f32.
    """
    B, Sq, Kv, G, hd = q.shape
    hd_v = v.shape[-1]                                   # may differ (MLA)
    Sk = k.shape[1]
    blk = min(blk, Sk)
    n_blk = (Sk + blk - 1) // blk
    pad = n_blk * blk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blk, blk, Kv, hd)
    vb = v.reshape(B, n_blk, blk, Kv, hd_v)
    scale = hd ** -0.5

    def step(carry, inputs):
        m, l, acc = carry
        kc, vc, bi = inputs                              # (B, blk, Kv, hd)
        s = jnp.einsum("bqcgd,bkcd->bqcgk", q.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale   # (B,Sq,Kv,G,blk)
        kpos = k_pos0 + bi * blk + jnp.arange(blk)       # (blk,)
        qp = q_pos[:, :, None, None, None]               # (B,Sq,1,1,1)
        kp = kpos[None, None, None, None, :]
        valid = kp < (k_pos0 + Sk)
        if causal:
            valid &= kp <= qp
        if window > 0:
            valid &= (qp - kp) < window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqcgk,bkcd->bqcgd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Kv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Kv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Kv, G, hd_v), jnp.float32)
    (m, l, acc), _ = pscan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(n_blk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ========================================================== nystrom attention
def _newton_schulz_pinv(A, iters: int = 6):
    """Iterative pseudo-inverse (Nystromformer eq. 12) — the attention-level
    analogue of the paper's avoidance of eigendecomposition-based W^+."""
    m = A.shape[-1]
    I = jnp.eye(m, dtype=A.dtype)
    a1 = jnp.max(jnp.sum(jnp.abs(A), axis=-2, keepdims=True), axis=-1, keepdims=True)
    ainf = jnp.max(jnp.sum(jnp.abs(A), axis=-1, keepdims=True), axis=-2, keepdims=True)
    Z = jnp.swapaxes(A, -1, -2) / (a1 * ainf)

    def body(Z, _):
        AZ = A @ Z
        Z = 0.25 * Z @ (13.0 * I - AZ @ (15.0 * I - AZ @ (7.0 * I - AZ)))
        return Z, None

    Z, _ = pscan(body, Z, None, length=iters)
    return Z


def _nystrom_attention(q, k, v, q_pos, *, n_landmarks: int, causal: bool):
    """Sub-quadratic attention via landmark (segment-mean) Nystrom approx.

    q: (B,S,Kv,G,hd), k/v: (B,S,Kv,hd). O(S * m) time/memory. The causal
    variant masks the landmark->key kernel at segment granularity
    (approximate causality, documented in DESIGN.md).
    """
    B, S, Kv, G, hd = q.shape
    m = min(n_landmarks, S)
    seg = S // m
    scale = hd ** -0.5
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # landmarks: segment means (B, m, Kv, [G,] hd)
    q_lm = jnp.mean(qf[:, : m * seg].reshape(B, m, seg, Kv, G, hd), axis=2)
    k_lm = jnp.mean(kf[:, : m * seg].reshape(B, m, seg, Kv, hd), axis=2)

    mdim = m
    s1 = jnp.einsum("bqcgd,bmcd->bqcgm", qf, k_lm) * scale    # query -> landmark
    s2 = jnp.einsum("bmcgd,bncd->bcgmn", q_lm, k_lm) * scale  # landmark -> landmark
    s3 = jnp.einsum("bmcgd,bkcd->bcgmk", q_lm, kf) * scale    # landmark -> key

    if causal:
        # segment-granular causal masks
        lm_end = (jnp.arange(m) + 1) * seg - 1                # landmark positions
        kpos = jnp.arange(S)
        mask1 = lm_end[None, None, None, None, :] <= q_pos[:, :, None, None, None]
        s1 = jnp.where(mask1, s1, NEG_INF)
        # ensure each query can reach at least its first landmark
        first = jnp.zeros_like(mask1).at[..., 0].set(True)
        s1 = jnp.where(first & ~mask1.any(-1, keepdims=True), 0.0, s1)
        mask3 = kpos[None, None, None, None, :] <= lm_end[None, None, None, :, None]
        s3 = jnp.where(mask3, s3, NEG_INF)
        mask2 = lm_end[None, :] <= lm_end[:, None]
        s2 = jnp.where(mask2[None, None, None], s2, NEG_INF)

    k1 = jax.nn.softmax(s1, axis=-1)
    k2 = jax.nn.softmax(s2, axis=-1)
    k3 = jax.nn.softmax(s3, axis=-1)
    k3v = jnp.einsum("bcgmk,bkcd->bcgmd", k3, vf)             # (B,Kv,G,m,hd)
    if causal:
        # the segment-causal mask makes k2 LOWER-TRIANGULAR, so the
        # landmark system is solved EXACTLY by a (ridge-regularized)
        # triangular solve — no pseudo-inverse at all (the strongest form
        # of the paper's "avoid W^+" insight) and strictly causal: the
        # inverse of a triangular matrix is triangular, so no future
        # leakage (tests/test_models_smoke.py::test_nystrom_no_future_leakage).
        # The 0.25 ridge bounds the solve against small early-landmark
        # diagonals (ablation in EXPERIMENTS.md: corr .435 -> .611).
        mI = 0.25 * jnp.eye(mdim, dtype=k2.dtype)
        zk3v = jax.scipy.linalg.solve_triangular(k2 + mI, k3v, lower=True)
    else:
        Z = _newton_schulz_pinv(k2)                           # (B,Kv,G,m,m)
        zk3v = Z @ k3v                                        # (B,Kv,G,m,hd)
    out = jnp.einsum("bqcgm,bcgmd->bqcgd", k1, zk3v)
    return out.astype(q.dtype)


def _flash_causal_blocked(q, k, v, *, window: int, blk: int):
    """Causal flash with BLOCK SKIPPING: query block i only visits key
    blocks [lo(i) .. i] (lo>0 under a sliding window), so fully-masked
    blocks cost nothing — ~2x fewer attention FLOPs than masked-dense
    (triangular sum), window/S fewer under sliding. Exact same outputs
    (EXPERIMENTS.md §Perf-A1). Assumes contiguous positions 0..S-1
    (the training/prefill path)."""
    B, S, Kv, G, hd = q.shape
    if S % blk != 0 or S <= blk:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return _flash(q, k, v, pos, 0, causal=True, window=window, blk=blk)
    nq = S // blk
    outs = []
    for qi in range(nq):
        qb = q[:, qi * blk:(qi + 1) * blk]
        lo = 0
        if window > 0:
            lo = max(0, (qi * blk - window) // blk * blk)
        kb = k[:, lo:(qi + 1) * blk]
        vb = v[:, lo:(qi + 1) * blk]
        pos = jnp.broadcast_to(
            (qi * blk + jnp.arange(blk))[None], (B, blk))
        outs.append(_flash(qb, kb, vb, pos, lo, causal=True,
                           window=window, blk=blk))
    return jnp.concatenate(outs, axis=1)


# ================================================================== GQA apply
def _project_qkv(p, cfg: ArchConfig, h, positions):
    B, S, _ = h.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (h @ p["wk"]).reshape(B, S, Kv, hd)
    v = (h @ p["wv"]).reshape(B, S, Kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(p, cfg: ArchConfig, h, positions, *, blk: int = 1024):
    """Full-sequence causal attention (train/prefill). h: (B, S, d)."""
    B, S, _ = h.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // Kv
    q, k, v = _project_qkv(p, cfg, h, positions)
    qg = q.reshape(B, S, Kv, G, hd)
    if cfg.attention_variant == "nystrom":
        out = _nystrom_attention(qg, k, v, positions,
                                 n_landmarks=cfg.n_landmarks, causal=True)
    else:
        window = cfg.window if cfg.attention_variant == "sliding" else 0
        out = _flash_causal_blocked(qg, k, v, window=window, blk=blk)
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"]


class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, S_cache, Kv, hd) — ring buffer when sliding
    v: jnp.ndarray


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, layers: int):
    Kv, hd = cfg.n_kv_heads, cfg.hd
    size = min(max_seq, cfg.window) if cfg.attention_variant == "sliding" else max_seq
    dt = cfg.jnp_dtype
    return KVCache(
        k=jnp.zeros((layers, batch, size, Kv, hd), dt),
        v=jnp.zeros((layers, batch, size, Kv, hd), dt),
    )


def attn_decode(p, cfg: ArchConfig, h, cache: KVCache, pos):
    """One-token decode. h: (B, 1, d); cache holds this LAYER's (k, v);
    pos: scalar int32 — current position. Returns (out, new_cache)."""
    B = h.shape[0]
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // Kv
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, h, positions)
    size = cache.k.shape[1]
    slot = pos % size if cfg.attention_variant == "sliding" else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)

    qg = q.reshape(B, 1, Kv, G, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bqcgd,bkcd->bqcgk", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale          # (B,1,Kv,G,size)
    if cfg.attention_variant == "sliding":
        kpos = (pos - (slot - jnp.arange(size)) % size)     # absolute pos per ring slot
        valid = (kpos >= 0) & (kpos <= pos) & (pos - kpos < size)
    else:
        kpos = jnp.arange(size)
        valid = kpos <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqcgk,bkcd->bqcgd", w, cv.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(h.dtype)
    return out @ p["wo"], KVCache(k=ck, v=cv)


# ================================================================== MLA apply
def _mla_q(p, cfg: ArchConfig, h, positions):
    B, S, _ = h.shape
    H = cfg.n_heads
    qk_hd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = rms_norm(h @ p["wq_a"], p["q_norm"], cfg.norm_eps)
        q = (cq @ p["wq_b"]).reshape(B, S, H, qk_hd)
    else:
        q = (h @ p["wq"]).reshape(B, S, H, qk_hd)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train(p, cfg: ArchConfig, h, positions, *, blk: int = 1024):
    """MLA full-sequence path: expand compressed kv to per-head k/v."""
    B, S, _ = h.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, cfg, h, positions)
    kv = h @ p["wkv_a"]                                  # (B,S,lora+rope)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    kvb = (c_kv @ p["wkv_b"]).reshape(B, S, H, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kvb, [cfg.qk_nope_dim], axis=-1)
    # fold the shared rope key into per-head keys; run as standard MHA (G=1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, cfg.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    qg = q[:, :, :, None, :]                             # (B,S,H,1,hd)
    out = _flash(qg, k, v, positions, 0, causal=True, window=0, blk=blk)
    out = out.reshape(B, S, H * cfg.v_head_dim)
    return out @ p["wo"]


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, S, kv_lora_rank)
    k_rope: jnp.ndarray  # (B, S, qk_rope_dim)


def init_mla_cache(cfg: ArchConfig, batch: int, max_seq: int, layers: int):
    dt = cfg.jnp_dtype
    return MLACache(
        c_kv=jnp.zeros((layers, batch, max_seq, cfg.kv_lora_rank), dt),
        k_rope=jnp.zeros((layers, batch, max_seq, cfg.qk_rope_dim), dt),
    )


def mla_decode(p, cfg: ArchConfig, h, cache: MLACache, pos):
    """Absorbed MLA decode: scores/outputs computed against the COMPRESSED
    cache (deepseek-v2 serving trick) — no per-head k/v expansion."""
    B = h.shape[0]
    H = cfg.n_heads
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, h, positions)        # (B,1,H,*)
    kv = h @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    cc = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_kv, pos, axis=1)
    cr = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, k_rope, pos, axis=1)

    wkv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, H, cfg.qk_nope_dim + cfg.v_head_dim)
    w_k = wkv_b[:, :, : cfg.qk_nope_dim]                 # (lora, H, nope)
    w_v = wkv_b[:, :, cfg.qk_nope_dim:]                  # (lora, H, v)
    # absorb: q_eff = q_nope @ w_k^T  -> score against c_kv directly
    q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))          # (B,1,H,lora)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    s = (jnp.einsum("bqhl,bkl->bqhk", q_eff, cc.astype(jnp.float32)) +
         jnp.einsum("bqhr,bkr->bqhk", q_rope.astype(jnp.float32),
                    cr.astype(jnp.float32))) * scale     # (B,1,H,S)
    kpos = jnp.arange(cc.shape[1])
    s = jnp.where(kpos[None, None, None, :] <= pos, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bqhk,bkl->bqhl", w, cc.astype(jnp.float32))  # (B,1,H,lora)
    out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_v.astype(jnp.float32))
    out = out.reshape(B, 1, H * cfg.v_head_dim).astype(h.dtype)
    return out @ p["wo"], MLACache(c_kv=cc, k_rope=cr)
