"""Seeded, deterministic fault injection (the chaos harness).

The paper's deployment claim — "conjoining with Map-Reduce gives the
fault tolerance necessary for operation on large clusters" (§4) — is a
promise about surviving failures. This package provides the failures:
a :class:`FaultPlan` describes, deterministically and per-seed, which
instrumented call sites throw, which checkpoint commits are torn, which
fleet workers get SIGKILLed or stalled, and when. The recovery machinery
(`repro.util.retry`, `repro.sharding.supervisor`, the serve engine's
circuit breaker) is validated against it in tests/test_faults.py,
tests/test_supervisor.py and tests/test_serve_health.py.

Instrumented in-process sites:

==============  ==========================================================
``chunk.read``    ``repro.data.chunks.ChunkSource.chunk`` (and the
                  partition wrappers) — every stream-plan disk read
``ckpt.commit``   ``repro.checkpoint.ckpt.save_checkpoint`` — before the
                  atomic tmp-write/rename commit
``serve.dispatch``  ``repro.serve.engine.ServeEngine._dispatch`` — before
                  the batched decide call
==============  ==========================================================

Fleet-level events (SIGKILL / SIGSTOP-SIGCONT stalls) ride on the plan's
``schedule`` and are executed from outside the victim by
``tests/multihost/rig.run_fleet(faults=...)``.

Cross-process activation: export ``REPRO_FAULTS`` (the plan's
:meth:`FaultPlan.to_json`) and every python process that imports
``repro.faults`` installs the plan at import time — this is how the
supervisor smoke injects a suicide rule into spawned training workers.
Stdlib-only: importing this package never touches jax or numpy.
"""
from repro.faults.plan import (
    FAULT_ENV,
    FaultPlan,
    FaultRule,
    active,
    fire,
    install,
    uninstall,
)

__all__ = [
    "FAULT_ENV",
    "FaultPlan",
    "FaultRule",
    "active",
    "fire",
    "install",
    "uninstall",
]
