"""FaultPlan: deterministic, seeded fault schedules for chaos tests.

A plan is a list of :class:`FaultRule`\\ s (in-process faults fired at
instrumented call sites) plus a fleet ``schedule`` (kill/stall events a
process supervisor or test rig executes from outside the victim). Rules
are counted, not random, unless an explicit ``probability`` is given —
and even then the coin is seeded per (plan seed, site, rule index), so
two runs of the same plan inject the same faults at the same calls.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import random
import signal
import threading
from typing import Any, Dict, List, Optional, Sequence

log = logging.getLogger("repro.faults")

#: Environment variable holding a JSON-serialised plan; when set, the plan
#: is installed automatically at ``repro.faults`` import time so faults
#: reach subprocesses (training workers, CLI runs) without code changes.
FAULT_ENV = "REPRO_FAULTS"

_EXC_TYPES: Dict[str, type] = {
    "OSError": OSError,
    "IOError": OSError,
    "RuntimeError": RuntimeError,
    "ValueError": ValueError,
    "TimeoutError": TimeoutError,
    "KeyError": KeyError,
}

_ACTIONS = ("raise", "torn", "kill")


@dataclasses.dataclass
class FaultRule:
    """One injection rule bound to an instrumented ``site``.

    Matching is by per-site call count (1-based): the first ``after``
    calls pass clean, then the rule fires on every ``every``-th call
    until it has fired ``times`` times (``times=None`` = persistent
    fault, fires forever). If ``probability`` is set it replaces the
    counting gate with a seeded coin flip per eligible call.

    ``action`` selects the failure mode:
      - ``raise``: raise ``exc`` at the call site (transient I/O error),
      - ``torn``: the site simulates a partial write (checkpoint commits
        leave garbage at the destination) and then raises ``exc``,
      - ``kill``: the process SIGKILLs itself — a crash mid-operation.

    ``flag`` (a file path) makes the rule fire at most once *across
    processes and restarts*: the first process to fire creates the flag
    file atomically and later consults — including in a restarted
    worker — see it and stay clean. This is how "kill the worker once,
    then let the supervisor's restart succeed" is expressed.
    """
    site: str
    times: Optional[int] = 1
    after: int = 0
    every: int = 1
    probability: Optional[float] = None
    exc: str = "OSError"
    action: str = "raise"
    message: str = ""
    flag: Optional[str] = None

    def __post_init__(self):
        if self.action not in _ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"expected one of {_ACTIONS}")
        if self.exc not in _EXC_TYPES:
            raise ValueError(f"unknown exception name {self.exc!r}; "
                             f"expected one of {sorted(_EXC_TYPES)}")
        if self.every < 1:
            raise ValueError("every must be >= 1")
        if self.after < 0:
            raise ValueError("after must be >= 0")
        if self.times is not None and self.times < 1:
            raise ValueError("times must be >= 1 (or None for persistent)")

    def exc_type(self) -> type:
        return _EXC_TYPES[self.exc]


class FaultPlan:
    """A deterministic schedule of in-process faults and fleet events.

    Thread-safe: instrumented sites consult the plan from prefetch and
    writer threads. Usable as a context manager (installs the plan for
    the current process) and JSON round-trippable for the ``REPRO_FAULTS``
    cross-process path.
    """

    def __init__(self, seed: int = 0,
                 rules: Sequence[FaultRule] = (),
                 schedule: Sequence[Dict[str, Any]] = ()):
        self.seed = int(seed)
        self.rules: List[FaultRule] = list(rules)
        #: fleet events executed by an external watchdog (rig/supervisor):
        #: {"kind": "kill"|"stall", "pid": proc index, "at": seconds,
        #:  "duration": seconds (stall only)}
        self.schedule: List[Dict[str, Any]] = [dict(e) for e in schedule]
        self._lock = threading.Lock()
        self._calls: Dict[str, int] = {}
        self._fired: Dict[str, int] = {}
        self._rule_fired: List[int] = [0] * len(self.rules)
        self._rngs: Dict[int, random.Random] = {}

    # -- construction -----------------------------------------------------
    def inject(self, site: str, **kw) -> "FaultPlan":
        """Append an in-process rule (chainable)."""
        self.rules.append(FaultRule(site=site, **kw))
        self._rule_fired.append(0)
        return self

    def kill(self, pid: int, after_s: float) -> "FaultPlan":
        """Schedule SIGKILL of fleet process ``pid`` ``after_s`` seconds in."""
        self.schedule.append({"kind": "kill", "pid": int(pid),
                              "at": float(after_s)})
        return self

    def stall(self, pid: int, after_s: float,
              duration_s: float) -> "FaultPlan":
        """Schedule a SIGSTOP/SIGCONT stall of fleet process ``pid``."""
        self.schedule.append({"kind": "stall", "pid": int(pid),
                              "at": float(after_s),
                              "duration": float(duration_s)})
        return self

    # -- consultation (hot path) ------------------------------------------
    def consult(self, site: str) -> Optional[FaultRule]:
        """Record one call at ``site``; return the rule to fire, if any."""
        with self._lock:
            n = self._calls.get(site, 0) + 1
            self._calls[site] = n
            for idx, rule in enumerate(self.rules):
                if rule.site != site:
                    continue
                if n <= rule.after:
                    continue
                if rule.probability is not None:
                    rng = self._rngs.get(idx)
                    if rng is None:
                        rng = random.Random(
                            f"{self.seed}:{site}:{idx}")
                        self._rngs[idx] = rng
                    if rng.random() >= rule.probability:
                        continue
                else:
                    k = n - rule.after
                    if (k - 1) % rule.every != 0:
                        continue
                    if rule.times is not None and \
                            self._rule_fired[idx] >= rule.times:
                        continue
                if rule.flag is not None and not self._claim_flag(rule.flag):
                    continue
                self._rule_fired[idx] += 1
                self._fired[site] = self._fired.get(site, 0) + 1
                return rule
        return None

    @staticmethod
    def _claim_flag(path: str) -> bool:
        """Atomically claim a once-across-processes flag file."""
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        os.write(fd, str(os.getpid()).encode())
        os.close(fd)
        return True

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"calls": dict(self._calls), "fired": dict(self._fired)}

    # -- (de)serialisation -------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "rules": [dataclasses.asdict(r) for r in self.rules],
            "schedule": list(self.schedule),
        })

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        doc = json.loads(text)
        rules = [FaultRule(**r) for r in doc.get("rules", ())]
        return cls(seed=doc.get("seed", 0), rules=rules,
                   schedule=doc.get("schedule", ()))

    @classmethod
    def from_env(cls, environ=os.environ) -> Optional["FaultPlan"]:
        text = environ.get(FAULT_ENV)
        return cls.from_json(text) if text else None

    # -- context manager ---------------------------------------------------
    def __enter__(self) -> "FaultPlan":
        install(self)
        return self

    def __exit__(self, *exc_info) -> None:
        uninstall()


_active: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> None:
    """Make ``plan`` the process-wide active plan."""
    global _active
    _active = plan


def uninstall() -> None:
    global _active
    _active = None


def active() -> Optional[FaultPlan]:
    return _active


def fire(site: str, detail: str = "") -> Optional[str]:
    """Instrumentation hook: consult the active plan at ``site``.

    Returns ``None`` (no fault — also the fast path when no plan is
    installed), raises the rule's exception (``raise`` action), SIGKILLs
    the process (``kill``), or returns the action name (``torn``) so the
    site can simulate its own partial-failure mode before raising.
    """
    plan = _active
    if plan is None:
        return None
    rule = plan.consult(site)
    if rule is None:
        return None
    msg = rule.message or f"injected fault at {site}" + (
        f" ({detail})" if detail else "")
    log.warning("fault fired: site=%s action=%s detail=%s",
                site, rule.action, detail)
    if rule.action == "kill":
        os.kill(os.getpid(), signal.SIGKILL)
    if rule.action == "raise":
        raise rule.exc_type()(msg)
    return rule.action


# Cross-process activation: workers spawned with REPRO_FAULTS in their
# environment pick the plan up on first import of repro.faults.
_env_plan = FaultPlan.from_env()
if _env_plan is not None:
    install(_env_plan)
del _env_plan
