"""Loss functions for kernel machines (paper §2, §3).

Each loss provides value / derivative / (pseudo-)Hessian-diagonal so that
TRON's Gauss-Newton product ``Hd = lam*W d + C^T D C d`` is generic over the
machine type: squared-hinge -> SVM (the paper's main loss), logistic ->
kernel logistic regression, squared -> kernel ridge regression.

All are elementwise over the margin/output vector ``o = C beta``; reductions
are left to the caller so that the distributed path can psum partial sums.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Loss:
    """Differentiable loss l(o, y) with elementwise value/grad/diag."""

    name: str
    value: Callable  # (o, y) -> per-example loss
    grad: Callable   # (o, y) -> dl/do
    diag: Callable   # (o, y) -> d^2 l/do^2  (Gauss-Newton diagonal D)


def _sqhinge_value(o, y):
    return 0.5 * jnp.square(jnp.maximum(1.0 - y * o, 0.0))


def _sqhinge_grad(o, y):
    active = (1.0 - y * o) > 0.0
    return jnp.where(active, o - y, 0.0)


def _sqhinge_diag(o, y):
    return jnp.where((1.0 - y * o) > 0.0, 1.0, 0.0)


def _logistic_value(o, y):
    # log(1 + exp(-y o)) computed stably
    z = -y * o
    return jnp.logaddexp(0.0, z)


def _logistic_grad(o, y):
    z = -y * o
    s = jnp.where(z > 0, 1.0 / (1.0 + jnp.exp(-z)), jnp.exp(z) / (1.0 + jnp.exp(z)))
    return -y * s


def _logistic_diag(o, y):
    z = -y * o
    s = jnp.where(z > 0, 1.0 / (1.0 + jnp.exp(-z)), jnp.exp(z) / (1.0 + jnp.exp(z)))
    return s * (1.0 - s)


def _squared_value(o, y):
    return 0.5 * jnp.square(o - y)


def _squared_grad(o, y):
    return o - y


def _squared_diag(o, y):
    return jnp.ones_like(o)


SQUARED_HINGE = Loss("squared_hinge", _sqhinge_value, _sqhinge_grad, _sqhinge_diag)
LOGISTIC = Loss("logistic", _logistic_value, _logistic_grad, _logistic_diag)
SQUARED = Loss("squared", _squared_value, _squared_grad, _squared_diag)

LOSSES = {l.name: l for l in (SQUARED_HINGE, LOGISTIC, SQUARED)}


def get_loss(name: str) -> Loss:
    if name not in LOSSES:
        raise KeyError(f"unknown loss {name!r}; available: {sorted(LOSSES)}")
    return LOSSES[name]


def register_loss(loss: Loss) -> str:
    """Add a user-built Loss to the registry so name-keyed configs (and the
    legacy shims taking Loss objects) can refer to it. Returns the name."""
    existing = LOSSES.get(loss.name)
    if existing is not None and existing is not loss:
        raise ValueError(
            f"a different loss is already registered as {loss.name!r}; "
            f"pick a distinct Loss.name")
    LOSSES[loss.name] = loss
    return loss.name
