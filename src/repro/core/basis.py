"""Basis point selection (paper §3.2).

Two strategies, matching the paper's recipe:
  * random subset of the training points — cheap, used when m is large;
  * distributed K-means — each node computes local assignments and partial
    centroid sums, combined with AllReduce(psum); used when m is small
    (Table 2 shows the accuracy edge at small m and the cost blow-up at
    large m). The paper runs only ~3 Lloyd iterations; so do we by default.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.nystrom import sqdist


def random_basis(key: jax.Array, X: jnp.ndarray, m: int) -> jnp.ndarray:
    """m training points chosen uniformly without replacement (paper step 2).

    With X row-sharded this is a gather by global indices — the cross-device
    traffic is exactly the paper's 'broadcast of basis points' (O(m d))."""
    n = X.shape[0]
    idx = jax.random.choice(key, n, shape=(m,), replace=False)
    return jnp.take(X, idx, axis=0)


def _kmeans_step_local(Xl, centers):
    """Local Lloyd step: assignments + partial sums (runs per shard)."""
    d2 = sqdist(Xl, centers)                       # (n_local, m)
    assign = jnp.argmin(d2, axis=1)
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=Xl.dtype)
    psums = onehot.T @ Xl                          # (m, d) partial sums
    pcounts = jnp.sum(onehot, axis=0)              # (m,)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return psums, pcounts, inertia


def kmeans(key: jax.Array, X: jnp.ndarray, m: int, n_iter: int = 3,
           mesh: Optional[Mesh] = None,
           data_axes: Tuple[str, ...] = ("data",)) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(Distributed) K-means. Returns (centers, inertia_trace).

    When ``mesh`` is given, the Lloyd step runs under shard_map with X
    row-sharded and the partial sums AllReduced — the paper's distributed
    K-means. Without a mesh it is the identical math on one device.
    """
    centers0 = random_basis(key, X, m)

    if mesh is None:
        def step(centers, _):
            psums, pcounts, inertia = _kmeans_step_local(X, centers)
            new = psums / jnp.maximum(pcounts, 1.0)[:, None]
            new = jnp.where(pcounts[:, None] > 0, new, centers)
            return new, inertia
        centers, trace = jax.lax.scan(step, centers0, None, length=n_iter)
        return centers, trace

    def wrapped(Xl, centers):
        # local Lloyd partials + AllReduce(psum) — the distributed step
        psums, pcounts, inertia = _kmeans_step_local(Xl, centers)
        psums, pcounts, inertia = jax.lax.psum(
            (psums, pcounts, inertia), data_axes)
        return psums, pcounts, inertia

    body = shard_map(wrapped, mesh=mesh,
                     in_specs=(P(data_axes, None), P()),
                     out_specs=(P(), P(), P()), check_vma=False)

    @jax.jit
    def run(X, centers0):
        def step(centers, _):
            psums, pcounts, inertia = body(X, centers)
            new = psums / jnp.maximum(pcounts, 1.0)[:, None]
            new = jnp.where(pcounts[:, None] > 0, new, centers)
            return new, inertia
        return jax.lax.scan(step, centers0, None, length=n_iter)

    with mesh:
        return run(X, centers0)


def select_basis(key: jax.Array, X: jnp.ndarray, m: int, *,
                 strategy: str = "auto", kmeans_threshold: int = 4096,
                 n_features_threshold: int = 4096, n_iter: int = 3,
                 mesh: Optional[Mesh] = None,
                 data_axes: Tuple[str, ...] = ("data",)) -> jnp.ndarray:
    """Paper §3.2 policy: K-means when m (and d) are small, random otherwise."""
    if strategy == "auto":
        strategy = ("kmeans"
                    if m <= kmeans_threshold and X.shape[1] <= n_features_threshold
                    else "random")
    if strategy == "random":
        return random_basis(key, X, m)
    if strategy == "kmeans":
        centers, _ = kmeans(key, X, m, n_iter=n_iter, mesh=mesh,
                            data_axes=data_axes)
        return centers
    raise ValueError(f"unknown basis strategy {strategy!r}")
