"""Formulations (3) and (4) of the Nystrom-approximated kernel machine.

The paper's central object is formulation (4):

    min_beta  f(beta) = lam/2 * beta^T W beta + sum_i l(c_i beta, y_i)

with gradient      grad = lam * W beta + C^T (dL/do)
and Gauss-Newton   H d  = lam * W d    + C^T D C d .

Everything here is *local* math over explicit (C, W) blocks; the distributed
Algorithm 1 (repro.core.distributed) composes these same functions inside
shard_map with psum AllReduce, exactly mirroring the paper's node-local
compute + AllReduce structure.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.core.losses import Loss


def _colsum(a):
    """Per-column reduction: a scalar for 1-D, (K,) for (n, K) operands."""
    return jnp.sum(a, axis=0)


def _cdot(a, b):
    """Per-column dot; keeps the exact 1-D dot primitive (and rounding) of
    the pre-multi-RHS implementation."""
    if a.ndim == 1:
        return a @ b
    return jnp.sum(a * b, axis=0)


def _ct_v(C, v):
    """C^T v without a transposed copy of C.

    NOTE: for a vector this is written ``v @ C`` — XLA CPU otherwise
    materializes a full transposed copy of C INSIDE the TRON while-loop
    body (not hoisted), costing ~20x per CG step. See EXPERIMENTS.md
    §Perf-K1. The (n, K) block case contracts the leading dim directly,
    which lowers to the same transpose-free dot_general.
    """
    if v.ndim == 1:
        return v @ C
    import jax
    return jax.lax.dot_general(C, v, (((0,), (0,)), ((), ())))


@dataclasses.dataclass(frozen=True)
class Formulation4:
    """f / grad / Hd for formulation (4) given materialized C, W.

    All methods are jit-traceable. ``aux`` returned by fgrad carries the
    Gauss-Newton diagonal D so Hd does not recompute outputs (matching the
    paper's TRON usage: one f/g per outer iteration, several Hd sharing D).

    Rank-generic over a trailing class axis: beta (m, K) with y (n, K)
    evaluates K one-vs-rest objectives through the same two C matmuls —
    f becomes a (K,) vector, D an (n, K) block.
    """

    lam: float
    loss: Loss

    def outputs(self, C, beta):
        return C @ beta

    def value(self, C, W, y, beta):
        o = C @ beta
        reg = 0.5 * self.lam * _cdot(beta, W @ beta)
        return reg + _colsum(self.loss.value(o, y))

    def fgrad(self, C, W, y, beta) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Returns (f, grad, D). Cost O(nm[k]): two mat{vec,mul}s with C."""
        o = C @ beta
        Wb = W @ beta
        f = 0.5 * self.lam * _cdot(beta, Wb) \
            + _colsum(self.loss.value(o, y))
        g = self.lam * Wb + _ct_v(C, self.loss.grad(o, y))
        D = self.loss.diag(o, y)
        return f, g, D

    def hessd(self, C, W, D, d) -> jnp.ndarray:
        """Gauss-Newton product (lam W + C^T D C) d; O(nm[k])."""
        return self.lam * (W @ d) + _ct_v(C, D * (C @ d))


def to_linearized(C, W, jitter: float = 1e-8, rank: int | None = None):
    """Formulation (3) setup: A = C U Lam^{-1/2} via eigendecomposition of W.

    This is the *baseline* path the paper argues against at large m:
    O(m^3) eigendecomposition + O(n m^2) to form A (or O(n m mtil) with a
    rank-mtil truncation). Returns (A, U, lam_vals) so solutions map back:
    beta = U Lam^{-1/2} w.
    """
    m = W.shape[0]
    lam_vals, U = jnp.linalg.eigh(W + jitter * jnp.eye(m, dtype=W.dtype))
    if rank is not None:
        lam_vals = lam_vals[-rank:]
        U = U[:, -rank:]
    good = lam_vals > (jitter * 10.0)
    inv_sqrt = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam_vals, jitter)), 0.0)
    A = C @ (U * inv_sqrt[None, :])
    return A, U, lam_vals


def beta_from_w(U, lam_vals, w, jitter: float = 1e-8):
    """Map linearized solution w back to beta-space: beta = U Lam^{-1/2} w."""
    good = lam_vals > (jitter * 10.0)
    inv_sqrt = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam_vals, jitter)), 0.0)
    return U @ (inv_sqrt * w)
