"""Algorithm 1 — distributed TRON for formulation (4) — mapped to JAX.

Paper (Hadoop/AllReduce-tree)          ->  this module (TPU mesh)
-------------------------------------------------------------------------
step 1  rows of T scattered to p nodes ->  X, y sharded over the data axes
step 2  basis points broadcast         ->  basis replicated (P())
step 3  node-local row block of C      ->  C sharded P(data_axes, model_axis)
step 4  f/g/Hd = local matvec + AllReduce
                                       ->  shard_map body + lax.psum
The paper's proposed hyper-node extension ("row partitioning per hyper-node,
column partitioning within") is exactly the optional ``model_axis``: rows of
C over the data axes, columns over the model axis (2-D partition of C and W).

Three execution modes:
  * ``shard_map``  — the faithful Algorithm 1: collectives are explicit
    psums, one per paper AllReduce call.
  * ``auto``       — same math as plain jnp under jit with sharded operands;
    XLA SPMD chooses the collective schedule (used in §Perf to compare
    against the hand-written schedule).
  * ``materialize=False`` — C is never stored: every f/g/Hd recomputes its
    C tiles on the fly (paper §3.1 "kernel caching / compute on the fly",
    adapted to TPU by fusing gram+matvec; optionally the Pallas kmvp kernel).
  * ``materialize=False, fused=True`` — the ``otf_shard`` plan: even the
    per-shard (n/p, m) block is never allocated; C beta, C^T D r, and W
    contractions all go through the fused kmvp path (Pallas VMEM tiles on
    TPU, row-chunked jnp recomputation elsewhere), and each f/g/Hd call
    AllReduces exactly one m-vector of partials.

A fourth, out-of-core regime streams X from a :class:`ChunkSource`
(:meth:`DistributedNystrom.solve_stream`, the ``stream`` plan): f/g/Hd are
*accumulated* chunk by chunk through the same fused kmvp closures — each
chunk is row-sharded over the mesh, evaluated, AllReduced (one m-vector
psum), and discarded, so n can exceed host RAM. This is the paper's actual
deployment shape: Map-Reduce nodes re-reading their disk partition every
iteration.

beta (and CG direction d) are replicated, matching the paper ("beta is
broadcast to all nodes"); every m-vector reduction is a single psum.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import axis_size, shard_map
# the rank-generic reductions (_colsum, and _ct_v with its XLA-CPU
# transpose-avoidance NOTE) are shared with the local-math module
from repro.core.formulation import _colsum, _ct_v
from repro.core.losses import Loss, get_loss
from repro.core.nystrom import KernelSpec, gram
from repro.core.tron import TronConfig, TronResult, tron, tron_host
from repro.sharding import multihost
from repro.util.retry import RetryPolicy, call_with_retry

#: Transient-read policy for the per-iteration chunk stream. Matches
#: ``repro.data.chunks.READ_RETRY`` (the take_rows/basis path) so the
#: whole stream fit tolerates the same fault budget end to end.
_FEEDER_RETRY = RetryPolicy(max_attempts=3, backoff_s=0.02,
                            max_backoff_s=0.5)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = None   # column partition (hyper-node scheme)
    mode: str = "shard_map"            # shard_map | auto
    materialize: bool = True           # store C, or recompute on the fly
    backend: str = "jnp"               # gram backend: jnp | pallas
    fused: bool = False                # materialize=False only: fuse gram into
                                       # the matvec (kmvp) so not even the
                                       # per-shard C block is ever allocated
    block_rows: Optional[int] = None   # fused jnp fallback row-chunk override
    policy: str = "fp32"               # dtype policy name for every gram/kmvp
                                       # in the closures (kernels.policy);
                                       # accumulation and beta stay f32

    def _gram_policy(self):
        """Policy to hand ``nystrom.gram``: None for fp32 keeps the
        materialized paths on their exact pre-policy expression tree."""
        return None if self.policy == "fp32" else self.policy


class StreamClosures(NamedTuple):
    """Host-callable TRON closures over a chunked source, plus the jitted
    per-chunk evaluations for jaxpr introspection: tests trace
    ``fg_chunk(Xc, yc, wc, basis, beta)`` / ``hd_chunk(Xc, D, basis, d)``
    (chunk-global shapes; the shard_map sub-jaxpr is walked with per-shard
    avals) to prove no intermediate reaches chunk_rows x m elements.
    ``feeder`` is the :class:`_ChunkFeeder` driving chunk I/O — benchmarks
    read its ``h2d_bytes`` counter to measure host->device traffic."""
    fgrad: Callable
    hessd: Callable
    fg_chunk: Callable
    hd_chunk: Callable
    chunk_rows: int
    n_chunks: int
    feeder: Any = None


def _dp_index(data_axes):
    """Linearized index of this device along the (possibly nested) data axes."""
    idx = jax.lax.axis_index(data_axes[0])
    for ax in data_axes[1:]:
        idx = idx * axis_size(ax) + jax.lax.axis_index(ax)
    return idx


def _psum_dp(x, data_axes):
    return jax.lax.psum(x, data_axes)


# Every closure below is generic over a trailing column axis: beta may be
# (m,) or an (m, K) one-vs-rest block, y correspondingly (n,) or (n, K).

def _upd(buf, val, row0):
    """dynamic_update_slice of a row block at any rank."""
    return jax.lax.dynamic_update_slice(buf, val,
                                        (row0,) + (0,) * (val.ndim - 1))


_DEV_CACHE_BYTES = 256 << 20   # default HBM budget for the stream chunk cache


class _ChunkFeeder:
    """Pipelined host->device chunk delivery for the stream closures.

    PR 3's loop paid three per-chunk, per-evaluation costs that this class
    removes — each one matters because CG makes dozens of Hd calls per TRON
    step, and every call walks the whole source:

    * host padding (``np.concatenate`` for the ragged tail chunk, the
      zero-weight mask for every chunk) was rebuilt per call. Now it is
      built once per chunk and cached; only the padded ragged tail keeps
      its X copy, so the host never accumulates the full-size chunks the
      out-of-core plan exists to avoid holding.
    * every chunk was re-transferred host->device per call. Now up to
      ``cache_chunks`` chunks (default: whatever fits ``_DEV_CACHE_BYTES``)
      stay resident on the mesh across evaluations; with the cache warm
      those chunks cost zero transfer.
    * uncached chunks were read+transferred synchronously, serializing disk
      I/O with compute. Now a daemon thread reads, pads, and ``device_put``s
      ``prefetch`` chunks ahead (double buffering by default), so the next
      chunk's transfer overlaps the current chunk's kmvp work.

    ``h2d_bytes`` counts bytes handed to ``jax.device_put`` so benchmarks
    (and the acceptance test) can observe the transfer reduction directly.
    When ``classes`` is given, integer label chunks are expanded on the
    host into (rows, K) one-vs-rest ±1 targets before transfer.

    Multi-controller: when ``source.process_span`` is set, ``source.chunk``
    yields only this host's block of each global chunk. The feeder then
    pads to the per-host slot (``chunk_rows / num_processes`` rows) and
    assembles the global device chunk from per-process blocks
    (:func:`repro.sharding.multihost.put_row_sharded`) — per-host disk
    reads, host RAM, and h2d transfer all drop to 1/P while the device
    arrays (and thus the compiled closures) stay globally identical.
    """

    def __init__(self, source, chunk_rows: int, dtype, x_sh, y_sh, r_sh,
                 classes=None, cache_chunks: Optional[int] = None,
                 prefetch: int = 2, x_dtype=None):
        self.source = source
        self.cr = int(chunk_rows)
        span = getattr(source, "process_span", None)
        # per-host pad target: this host's slot of a global chunk
        self.pad_rows = self.cr // (span[1] if span else 1)
        self.dtype = np.dtype(dtype)
        # X chunks may transfer at a narrower dtype than targets/masks: a
        # bf16 compute policy halves H2D and cache bytes without touching
        # the ±1 targets or the 0/1 mask (exact at any float width).
        self.x_dtype = self.dtype if x_dtype is None else np.dtype(x_dtype)
        self.x_sh, self.y_sh, self.r_sh = x_sh, y_sh, r_sh
        self.classes = None if classes is None else np.asarray(classes)
        self.prefetch = int(prefetch)
        # resident bytes per cached chunk (host-local): X (pad, d) +
        # targets (pad[, K]) + mask (pad,) — the one-vs-rest expansion
        # widens the target block, so the HBM budget must count K columns
        ncols = 1 if self.classes is None else len(self.classes)
        chunk_bytes = (self.pad_rows * source.d * self.x_dtype.itemsize
                       + self.pad_rows * (ncols + 1) * self.dtype.itemsize)
        if cache_chunks is None:
            cache_chunks = _DEV_CACHE_BYTES // max(chunk_bytes, 1)
        self.cache_chunks = max(0, min(int(cache_chunks), source.n_chunks))
        self._host: dict = {}   # i -> (padded X | None, targets, mask)
        self._dev: dict = {}    # i -> (Xd, yd, wd) resident device arrays
        self.h2d_bytes = 0
        self.read_retries = 0
        self._retry = _FEEDER_RETRY
        self._retry_lock = threading.Lock()

    # ------------------------------------------------------------ checkpoint
    def state(self) -> dict:
        """Cursor/identity state for an in-training checkpoint.

        Snapshots land *between* TRON iterations — between complete passes
        over the source — so the cursor proper is always at chunk 0; what
        must survive is the chunk layout identity (to validate the resumed
        source and allow elastic re-rounding) and the transfer accounting.
        """
        return {"n": int(self.source.n), "d": int(self.source.d),
                "chunk_rows": int(self.cr),
                "n_chunks": int(self.source.n_chunks),
                "h2d_bytes": int(self.h2d_bytes),
                "read_retries": int(self.read_retries),
                "classes": None if self.classes is None
                else np.asarray(self.classes).tolist()}

    def restore_state(self, state: dict) -> None:
        """Adopt a checkpointed cursor state (resume).

        The dataset identity (n, d) must match; ``chunk_rows`` may differ —
        elastic restore re-rounds the chunk size to the new mesh's data
        extent, which only re-slices the rows-only partition."""
        n, d = int(state.get("n", -1)), int(state.get("d", -1))
        if (n, d) != (int(self.source.n), int(self.source.d)):
            raise ValueError(
                f"checkpointed stream source was n={n} d={d}; the resumed "
                f"source is n={self.source.n} d={self.source.d} — resume "
                f"must re-read the same dataset")
        self.h2d_bytes = int(state.get("h2d_bytes", 0))
        self.read_retries = int(state.get("read_retries", 0))

    def _targets(self, yc):
        if self.classes is None:
            return np.asarray(yc, self.dtype)
        from repro.data.chunks import ovr_targets
        return ovr_targets(yc, self.classes, dtype=self.dtype)

    def _read_chunk(self, i):
        """One chunk read, retried per ``_FEEDER_RETRY`` — transient disk
        faults below the cap re-read identical bytes, so the training
        trajectory is bit-for-bit unaffected. Retries are counted (they
        run on the prefetch thread too, hence the lock)."""
        def _count(attempt, exc, delay_s):
            with self._retry_lock:
                self.read_retries += 1
        return call_with_retry(self._retry, self.source.chunk, i,
                               label=f"stream-chunk-{i}", on_retry=_count)

    def _host_chunk(self, i):
        hit = self._host.get(i)
        if hit is not None:
            Xc, yc, wc = hit
            if Xc is None:                     # full chunk: re-read, no pad
                Xc = np.asarray(self._read_chunk(i)[0], self.x_dtype)
            return Xc, yc, wc
        Xc, yc = self._read_chunk(i)
        rows = Xc.shape[0]
        pad = self.pad_rows
        Xc = np.asarray(Xc, self.x_dtype).reshape(rows, self.source.d)
        if rows != pad:
            Xc = np.concatenate(
                [Xc, np.zeros((pad - rows, self.source.d), self.x_dtype)])
            yc = np.concatenate(
                [np.asarray(yc), np.zeros((pad - rows,),
                                          np.asarray(yc).dtype)])
        yc = self._targets(yc)
        wc = np.zeros((pad,), self.dtype)
        wc[:rows] = 1.0
        # cache the mask/targets always (O(n) floats total, the same order
        # as y itself) and the padded X only for the ragged tail — caching
        # every X chunk would quietly pull the whole dataset into host RAM
        self._host[i] = (Xc if rows != pad else None, yc, wc)
        return Xc, yc, wc

    def _device_chunk(self, i, need_y: bool):
        hit = self._dev.get(i)
        if hit is not None:
            Xd, yd, wd = hit
            return (Xd, yd, wd) if need_y else Xd
        Xc, yc, wc = self._host_chunk(i)
        # single-process this is a plain device_put; multi-process every
        # host contributes its pad_rows block and receives the global
        # (chunk_rows, ...) array — the compiled closures see identical
        # shapes either way
        Xd = multihost.put_row_sharded(self.x_sh, Xc)
        self.h2d_bytes += Xc.nbytes
        yd = wd = None
        if need_y or i < self.cache_chunks:
            yd = multihost.put_row_sharded(self.y_sh, yc)
            wd = multihost.put_row_sharded(self.r_sh, wc)
            self.h2d_bytes += yc.nbytes + wc.nbytes
        if i < self.cache_chunks:
            self._dev[i] = (Xd, yd, wd)
        return (Xd, yd, wd) if need_y else Xd

    def chunks(self, need_y: bool = True):
        """Yield device chunks in order: (Xd, yd, wd) triples, or bare Xd
        when ``need_y`` is False (the Hd path bakes the example mask into
        the Gauss-Newton diagonal, so y/w transfers would be dead traffic).
        """
        idxs = range(self.source.n_chunks)
        if self.prefetch <= 1:
            for i in idxs:
                yield self._device_chunk(i, need_y)
            return
        yield from self._prefetched(idxs, need_y)

    def _prefetched(self, idxs, need_y: bool):
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()
        end = object()

        def work():
            try:
                for i in idxs:
                    if stop.is_set():
                        return
                    q.put((None, self._device_chunk(i, need_y)))
            except BaseException as e:     # re-raised on the consumer side
                q.put((e, None))
                return
            q.put((None, end))

        t = threading.Thread(target=work, daemon=True,
                             name="stream-chunk-prefetch")
        t.start()
        try:
            while True:
                err, item = q.get()
                if err is not None:
                    raise err
                if item is end:
                    break
                yield item
        finally:
            stop.set()
            while t.is_alive():            # drain so a blocked put can exit
                try:
                    q.get(timeout=0.05)
                except queue.Empty:
                    pass
            t.join()


class DistributedNystrom:
    """Distributed solver for formulation (4) on a device mesh."""

    def __init__(self, mesh: Mesh, lam: float, loss: Loss | str,
                 kernel: KernelSpec, dist: DistConfig = DistConfig()):
        self.mesh = mesh
        self.lam = float(lam)
        self.loss = get_loss(loss) if isinstance(loss, str) else loss
        self.kernel = kernel
        self.dist = dist
        da, ma = dist.data_axes, dist.model_axis
        self.row_spec = P(da)                    # y, o, D
        self.x_spec = P(da, None)                # X rows
        self.c_spec = P(da, ma)                  # C 2-D partition
        self.w_spec = P(da, ma)                  # W 2-D partition (row blocks)
        self.rep_spec = P()                      # beta, d, basis

    # ------------------------------------------------------------------ setup
    def shardings(self):
        ns = lambda spec: NamedSharding(self.mesh, spec)
        return dict(x=ns(self.x_spec), y=ns(self.row_spec), c=ns(self.c_spec),
                    w=ns(self.w_spec), rep=ns(self.rep_spec))

    def precompute(self, X, basis):
        """Steps 2-3: broadcast basis, build sharded C and W."""
        sh = self.shardings()
        kern, backend = self.kernel, self.dist.backend
        pol = self.dist._gram_policy()

        @partial(jax.jit, out_shardings=(sh["c"], sh["w"]))
        def _build(X, basis):
            C = gram(X, basis, kern, backend, policy=pol)
            W = gram(basis, basis, kern, backend, policy=pol)
            return C, W

        return _build(X, basis)

    # -------------------------------------------------------------- closures
    def _local_fgrad(self, Cb, Wb, yb, beta):
        """Node-local body of paper steps 4a+4b; returns psum-reduced f,g,D.

        Rank-generic: beta (m,) with y (n,) is the paper's binary problem;
        beta (m, K) with y (n, K) evaluates K one-vs-rest columns through
        the same matmuls (f becomes a (K,) vector of per-class objectives).
        """
        da, ma = self.dist.data_axes, self.dist.model_axis
        m_dp = Wb.shape[0]          # W row-block size (m / |data axes|)
        m_mp = Cb.shape[1]          # column-block size (m / |model axis|)

        # column slice of beta this device multiplies against
        if ma is not None:
            col0 = jax.lax.axis_index(ma) * m_mp
        else:
            col0 = 0
        beta_cols = jax.lax.dynamic_slice_in_dim(beta, col0, m_mp, 0)

        o_part = Cb @ beta_cols
        o = jax.lax.psum(o_part, ma) if ma else o_part          # AllReduce (4a)

        Wb_part = Wb @ beta_cols if ma else Wb @ beta
        Wbeta_rows = jax.lax.psum(Wb_part, ma) if ma else Wb_part

        row0 = _dp_index(da) * m_dp
        beta_rows = jax.lax.dynamic_slice_in_dim(beta, row0, m_dp, 0)
        reg_part = _colsum(beta_rows * Wbeta_rows)
        loss_part = _colsum(self.loss.value(o, yb))
        # paper step 4a: both sums AllReduced over the data tree in one shot
        reg, lsum = _psum_dp(jnp.stack([reg_part, loss_part]), da)
        f = 0.5 * self.lam * reg + lsum

        r = self.loss.grad(o, yb)
        g_loss_part = _ct_v(Cb, r)                               # (m_mp[, K])
        g_reg_rows = self.lam * Wbeta_rows                       # (m_dp[, K])
        g_local = _upd(jnp.zeros(beta.shape, beta.dtype), g_reg_rows, row0)
        g_loss = _upd(jnp.zeros(beta.shape, beta.dtype),
                      g_loss_part.astype(beta.dtype), col0)
        # NOTE: g_loss contributions overlap across data shards -> psum over
        # all axes gives the complete gradient (AllReduce 4b).
        g = _psum_dp(g_local, da) + jax.lax.psum(
            _psum_dp(g_loss, da), ma) if ma else _psum_dp(g_local + g_loss, da)

        D = self.loss.diag(o, yb)
        return f, g, D

    def _local_hessd(self, Cb, Wb, Db, d):
        """Node-local body of paper step 4c (gradient path with y=0, D fixed).

        Rank-generic like :meth:`_local_fgrad`; Db is (n,) or (n, K)."""
        da, ma = self.dist.data_axes, self.dist.model_axis
        m_dp = Wb.shape[0]
        m_mp = Cb.shape[1]
        col0 = jax.lax.axis_index(ma) * m_mp if ma else 0
        d_cols = jax.lax.dynamic_slice_in_dim(d, col0, m_mp, 0)

        o_part = Cb @ d_cols
        o = jax.lax.psum(o_part, ma) if ma else o_part           # AllReduce
        Wd_part = Wb @ d_cols if ma else Wb @ d
        Wd_rows = jax.lax.psum(Wd_part, ma) if ma else Wd_part

        row0 = _dp_index(da) * m_dp
        h_loss_part = _ct_v(Cb, Db * o)
        h = _upd(jnp.zeros(d.shape, d.dtype), self.lam * Wd_rows, row0)
        h2 = _upd(jnp.zeros(d.shape, d.dtype),
                  h_loss_part.astype(d.dtype), col0)
        if ma:
            return _psum_dp(h, da) + jax.lax.psum(_psum_dp(h2, da), ma)
        return _psum_dp(h + h2, da)                              # AllReduce

    # ------------------------------------------------- on-the-fly (no C in HBM)
    def _slice_basis(self, basis, m):
        """(row-block for W rows, col-block for C/W cols) of the basis set."""
        da, ma = self.dist.data_axes, self.dist.model_axis
        dp_total = 1
        for ax in da:
            dp_total *= axis_size(ax)
        m_dp = m // dp_total
        row0 = _dp_index(da) * m_dp
        basis_rows = jax.lax.dynamic_slice_in_dim(basis, row0, m_dp, 0)
        if ma is not None:
            m_mp = m // axis_size(ma)
            col0 = jax.lax.axis_index(ma) * m_mp
            basis_cols = jax.lax.dynamic_slice_in_dim(basis, col0, m_mp, 0)
        else:
            basis_cols = basis
        return basis_rows, basis_cols

    def _otf_blocks(self, Xl, basis, m):
        """Recompute this device's C and W blocks in-register (paper §3.1:
        'compute kernel elements on the fly'; TPU version = gram fused into
        the matvec, optionally via the Pallas kmvp kernel)."""
        basis_rows, basis_cols = self._slice_basis(basis, m)
        pol = self.dist._gram_policy()
        Cb = gram(Xl, basis_cols, self.kernel, self.dist.backend, policy=pol)
        Wb = gram(basis_rows, basis_cols, self.kernel, self.dist.backend,
                  policy=pol)
        return Cb, Wb

    def _row_spec_like(self, arr):
        """Row-sharded spec at the rank of ``arr``: (n,) targets y/D/o
        vectors, (n, K) their one-vs-rest column blocks (rows sharded,
        classes replicated)."""
        return self.row_spec if jnp.ndim(arr) == 1 else self.x_spec

    def make_otf_closures(self, X, y, basis):
        """(fgrad, hessd) that never materialize C globally."""
        m = basis.shape[0]
        ysp = self._row_spec_like(y)

        def fg_local(Xl, yb, basis, beta):
            Cb, Wb = self._otf_blocks(Xl, basis, m)
            return self._local_fgrad(Cb, Wb, yb, beta)

        def hd_local(Xl, yb, basis, D, d):
            Cb, Wb = self._otf_blocks(Xl, basis, m)
            del yb
            return self._local_hessd(Cb, Wb, D, d)

        smap = partial(shard_map, mesh=self.mesh, check_vma=False)
        fg_body = smap(fg_local,
                       in_specs=(self.x_spec, ysp, self.rep_spec,
                                 self.rep_spec),
                       out_specs=(self.rep_spec, self.rep_spec, ysp))
        hd_body = smap(hd_local,
                       in_specs=(self.x_spec, ysp, self.rep_spec,
                                 ysp, self.rep_spec),
                       out_specs=self.rep_spec)
        fgrad = lambda beta: fg_body(X, y, basis, beta)
        hessd = lambda D, d: hd_body(X, y, basis, D, d)
        return fgrad, hessd

    # ---------------------------------------- fused on-the-fly (otf_shard)
    def make_fused_closures(self, X, y, basis):
        """(fgrad, hessd) where not even the per-shard C block exists.

        The non-fused on-the-fly path (:meth:`make_otf_closures`) rebuilds
        a full (n/p, m) gram block per evaluation; here every C (and W)
        contraction goes through the fused kmvp path — Pallas VMEM tiles
        on TPU, row-chunked recomputation under the jnp fallback — and the
        only cross-device traffic is one m-vector psum per f/g/Hd call
        (plus a 2-scalar psum for the objective pieces): O(m) bytes,
        O(n m d / p) flops recomputed per evaluation.

        Rows-only partition: the fused kernels contract over full basis
        columns, so a ``model_axis`` column split does not apply here.

        Multi-RHS: with y (n, K) and beta (m, K) every kmvp call below
        contracts all K one-vs-rest columns against the SAME recomputed
        gram tiles — a K-class f/g/Hd costs ~one O(n m d / p) recompute
        pass instead of K, which is the whole point of the multi-RHS
        kernels (kernels/kmvp.py).
        """
        if self.dist.model_axis is not None:
            raise ValueError(
                "fused on-the-fly mode shards rows only (the kmvp kernels "
                "contract over all basis columns in VMEM); use "
                "model_axis=None, or the non-fused materialize=False mode "
                "for the 2-D partition")
        from repro.kernels.ops import otf_kmvp_fwd, otf_kmvp_t
        m = basis.shape[0]
        da = self.dist.data_axes
        ysp = self._row_spec_like(y)
        kw = dict(kind=self.kernel.kind, sigma=self.kernel.sigma,
                  backend=self.dist.backend,
                  block_rows=self.dist.block_rows,
                  policy=self.dist.policy)

        def _w_rows_slice(basis):
            """(row0, basis row-block) this device owns for W contractions."""
            dp_total = 1
            for ax in da:
                dp_total *= axis_size(ax)
            m_dp = m // dp_total
            row0 = _dp_index(da) * m_dp
            return row0, m_dp, jax.lax.dynamic_slice_in_dim(
                basis, row0, m_dp, 0)

        def fg_local(Xl, yl, basis, beta):
            row0, m_dp, basis_rows = _w_rows_slice(basis)
            o = otf_kmvp_fwd(Xl, basis, beta, **kw)               # C_l beta
            Wb_rows = otf_kmvp_fwd(basis_rows, basis, beta, **kw)  # (m_dp[,K])
            beta_rows = jax.lax.dynamic_slice_in_dim(beta, row0, m_dp, 0)
            reg_part = _colsum(beta_rows * Wb_rows)
            loss_part = _colsum(self.loss.value(o, yl))
            reg, lsum = _psum_dp(jnp.stack([reg_part, loss_part]), da)
            f = 0.5 * self.lam * reg + lsum

            r = self.loss.grad(o, yl)
            g_loss = otf_kmvp_t(Xl, basis, r, **kw)               # C_l^T r
            g_local = _upd(jnp.zeros(beta.shape, beta.dtype),
                           self.lam * Wb_rows, row0)
            g = _psum_dp(g_local + g_loss.astype(beta.dtype), da)  # 1 psum
            return f, g, self.loss.diag(o, yl)

        def hd_local(Xl, yl, basis, D, d):
            del yl
            row0, m_dp, basis_rows = _w_rows_slice(basis)
            o = otf_kmvp_fwd(Xl, basis, d, **kw)                  # C_l d
            Wd_rows = otf_kmvp_fwd(basis_rows, basis, d, **kw)
            h_loss = otf_kmvp_t(Xl, basis, D * o, **kw)           # C_l^T(D o)
            h_local = _upd(jnp.zeros(d.shape, d.dtype),
                           self.lam * Wd_rows, row0)
            return _psum_dp(h_local + h_loss.astype(d.dtype), da)  # 1 psum

        smap = partial(shard_map, mesh=self.mesh, check_vma=False)
        fg_body = smap(fg_local,
                       in_specs=(self.x_spec, ysp, self.rep_spec,
                                 self.rep_spec),
                       out_specs=(self.rep_spec, self.rep_spec, ysp))
        hd_body = smap(hd_local,
                       in_specs=(self.x_spec, ysp, self.rep_spec,
                                 ysp, self.rep_spec),
                       out_specs=self.rep_spec)
        fgrad = lambda beta: fg_body(X, y, basis, beta)
        hessd = lambda D, d: hd_body(X, y, basis, D, d)
        return fgrad, hessd

    # ------------------------------------------------- streaming (out of core)
    def make_stream_closures(self, source, basis, classes=None,
                             cache_chunks: Optional[int] = None,
                             prefetch: int = 2) -> "StreamClosures":
        """Accumulator-style (fgrad, hessd) over a chunked dataset.

        Every evaluation walks ``source`` chunk by chunk: the chunk is
        row-sharded over the data axes, pushed through the same fused kmvp
        contractions as :meth:`make_fused_closures`, AllReduced (one
        m-vector psum per chunk), and dropped — so the only X ever on
        device is one ``(chunk_rows, d)`` block (plus the HBM-budgeted
        resident cache below) and no intermediate reaches ``chunk_rows x m``
        elements. Ragged last chunks (and any n not divisible by the data
        extent) are handled with a zero example-weight mask, which is exact
        for every registered loss.

        Chunk I/O is a pipeline (:class:`_ChunkFeeder`): host-side padding
        is cached per chunk, up to ``cache_chunks`` chunks stay resident on
        device across evaluations (CG's Hd calls stop re-transferring the
        dataset), and uncached chunks are prefetched+``device_put`` on a
        background thread, ``prefetch`` deep, overlapping I/O with compute.

        ``classes`` switches the solve to one-vs-rest multi-RHS: the source
        keeps its integer labels, each chunk is expanded on the host into a
        (chunk_rows, K) ±1 target block, and beta/g/Hd are (m, K) — every
        streamed gram recomputation then serves all K classes at once.

        The Gauss-Newton diagonal ``aux`` is one row-sharded
        ``(chunk_rows[, K])`` array per chunk — O(n/p) floats per device
        per class, a factor d/K smaller than the X partition the plan
        refuses to hold. The returned closures are host callables for
        :func:`tron_host`; ``fg_chunk``/``hd_chunk`` are exposed so tests
        can introspect the per-chunk jaxpr and *prove* the memory contract.
        """
        if self.dist.model_axis is not None:
            raise ValueError(
                "streaming mode shards rows only (chunks go through the "
                "fused kmvp path, which contracts over all basis columns); "
                "use model_axis=None")
        from repro.kernels.ops import otf_kmvp_fwd, otf_kmvp_t
        da = self.dist.data_axes
        multihost.check_mesh_spans(self.mesh)
        dp = 1
        for ax in da:
            dp *= self.mesh.shape[ax]
        cr = -(-source.chunk_rows // dp) * dp
        if cr != source.chunk_rows:
            source = source.with_chunk_rows(cr)
        # multi-controller: each process streams only its own partition.
        # A pre-partitioned source (per-host shard dirs) must match the
        # live topology; a shared source is split logically per host.
        span = getattr(source, "process_span", None)
        live = (multihost.process_index(), multihost.process_count())
        if span is not None and span != live:
            raise ValueError(
                f"source is the partition for process {span[0]} of "
                f"{span[1]} but this run is process {live[0]} of "
                f"{live[1]} — open the partition dir matching this "
                f"process (or re-export with save_partition_dirs)")
        if span is None and live[1] > 1:
            from repro.data.chunks import HostPartition
            source = HostPartition(source, *live)
        kw = dict(kind=self.kernel.kind, sigma=self.kernel.sigma,
                  backend=self.dist.backend,
                  block_rows=self.dist.block_rows,
                  policy=self.dist.policy)
        basis_dev = jnp.asarray(basis)
        dtype = np.dtype(source.dtype)
        # X chunks transfer at the policy's compute dtype (bf16 halves H2D
        # bytes); targets, masks, and beta stay at the source/param dtype —
        # the optimizer state is deliberately outside the compute policy.
        from repro.kernels.policy import get_policy
        _pol = get_policy(self.dist.policy)
        x_dtype = dtype if _pol.compute == "float32" else \
            _pol.np_compute_dtype()
        multi = classes is not None

        def fg_chunk(Xl, yl, wl, basis, beta):
            o = otf_kmvp_fwd(Xl, basis, beta, **kw)              # C_chunk beta
            w = wl[:, None] if multi else wl
            lsum = _colsum(w * self.loss.value(o, yl))
            r = w * self.loss.grad(o, yl)
            g = otf_kmvp_t(Xl, basis, r, **kw)                   # C_chunk^T r
            lsum, g = jax.lax.psum((lsum, g.astype(beta.dtype)), da)
            return lsum, g, w * self.loss.diag(o, yl)

        def hd_chunk(Xl, Dl, basis, d):
            o = otf_kmvp_fwd(Xl, basis, d, **kw)                 # C_chunk d
            h = otf_kmvp_t(Xl, basis, Dl * o, **kw)              # C^T (D o)
            return jax.lax.psum(h.astype(d.dtype), da)

        ysp = self.x_spec if multi else self.row_spec            # (cr[, K])
        smap = partial(shard_map, mesh=self.mesh, check_vma=False)
        fg_eval = jax.jit(smap(
            fg_chunk,
            in_specs=(self.x_spec, ysp, self.row_spec,
                      self.rep_spec, self.rep_spec),
            out_specs=(self.rep_spec, self.rep_spec, ysp)))
        hd_eval = jax.jit(smap(
            hd_chunk,
            in_specs=(self.x_spec, ysp, self.rep_spec,
                      self.rep_spec),
            out_specs=self.rep_spec))

        # the lam/2 beta^T W beta term has no X dependence: one fused
        # (m[, K]) contraction per evaluation, replicated on every device
        @jax.jit
        def wv_eval(basis, v):
            return otf_kmvp_fwd(basis, basis, v, **kw)

        feeder = _ChunkFeeder(
            source, cr, dtype,
            x_sh=NamedSharding(self.mesh, self.x_spec),
            y_sh=NamedSharding(self.mesh, ysp),
            r_sh=NamedSharding(self.mesh, self.row_spec),
            classes=classes, cache_chunks=cache_chunks, prefetch=prefetch,
            x_dtype=x_dtype)

        # Multi-controller: every process must hit the wire with the SAME
        # collective sequence. XLA-CPU dispatches independent executions
        # concurrently, so two chunks' psums can interleave differently on
        # different hosts and corrupt the gloo streams (observed as
        # preamble-length aborts). Blocking on each chunk's outputs before
        # launching the next pins the order; single-process runs keep the
        # fully-async pipeline.
        if multihost.active():
            _ordered = jax.block_until_ready
        else:
            _ordered = lambda out: out

        def fgrad(beta):
            beta_h = np.asarray(beta, dtype)
            beta_dev = jnp.asarray(beta_h)
            with self.mesh:
                Wbeta = wv_eval(basis_dev, beta_dev)
                parts, aux = [], []
                for Xc, yc, wc in feeder.chunks(need_y=True):
                    lsum, gc, Dc = _ordered(
                        fg_eval(Xc, yc, wc, basis_dev, beta_dev))
                    parts.append((lsum, gc))
                    aux.append(Dc)
                Wbeta = np.asarray(Wbeta, np.float64)
                f = 0.5 * self.lam * np.sum(
                    beta_h.astype(np.float64) * Wbeta, axis=0)
                g = self.lam * Wbeta
                for lsum, gc in parts:          # host f64 accumulation
                    f = f + np.asarray(lsum, np.float64)
                    g = g + np.asarray(gc, np.float64)
            return f, g.astype(dtype), aux

        def hessd(aux, d):
            d_dev = jnp.asarray(np.asarray(d, dtype))
            with self.mesh:
                Wd = wv_eval(basis_dev, d_dev)
                parts = [_ordered(hd_eval(Xc, Dc, basis_dev, d_dev))
                         for Xc, Dc in zip(feeder.chunks(need_y=False), aux)]
                h = self.lam * np.asarray(Wd, np.float64)
                for hc in parts:
                    h = h + np.asarray(hc, np.float64)
            return h.astype(dtype)

        return StreamClosures(fgrad=fgrad, hessd=hessd,
                              fg_chunk=fg_eval, hd_chunk=hd_eval,
                              chunk_rows=cr, n_chunks=source.n_chunks,
                              feeder=feeder)

    def solve_stream(self, source, basis, beta0=None,
                     cfg: TronConfig = TronConfig(), classes=None,
                     cache_chunks: Optional[int] = None,
                     prefetch: int = 2, checkpoint=None,
                     state0=None) -> TronResult:
        """Out-of-core solve: TRON on the host, f/g/Hd streamed from
        ``source`` (see :meth:`make_stream_closures`). ``classes`` runs a
        one-vs-rest multi-RHS solve: beta is (m, K) and every streamed
        pass over the dataset serves all K classes.

        ``checkpoint`` (a ``repro.checkpoint.TrainingCheckpointer``) gets
        the feeder attached (cursor export into every step file, counter
        restore on resume) and receives a snapshot every ``interval``
        outer iterations; ``state0`` (a ``TronSnapshot``) resumes the
        host loop — valid under ANY data-axis extent, since the snapshot
        holds only replicated m-space state and the chunk size was
        re-rounded to this mesh above."""
        sc = self.make_stream_closures(source, basis, classes=classes,
                                       cache_chunks=cache_chunks,
                                       prefetch=prefetch)
        if checkpoint is not None:
            checkpoint.attach_feeder(sc.feeder)
        if beta0 is None:
            shape = (basis.shape[0],) if classes is None \
                else (basis.shape[0], len(classes))
            beta0 = np.zeros(shape, source.dtype)
        return tron_host(
            sc.fgrad, sc.hessd, beta0, cfg, state0=state0,
            snapshot_every=checkpoint.interval if checkpoint else 0,
            on_snapshot=checkpoint.on_snapshot if checkpoint else None)

    def make_closures(self, C, W, y):
        """(fgrad, hessd) closures over sharded C, W, y for TRON.

        Rank-generic over a trailing class axis on y/beta (one-vs-rest)."""
        if self.dist.mode == "auto":
            # plain global math; XLA SPMD inserts the collectives
            def fgrad(beta, C=C, W=W, y=y):
                o = C @ beta
                Wb = W @ beta
                f = 0.5 * self.lam * _colsum(beta * Wb) \
                    + _colsum(self.loss.value(o, y))
                g = self.lam * Wb + _ct_v(C, self.loss.grad(o, y))
                return f, g, self.loss.diag(o, y)

            def hessd(D, d, C=C, W=W):
                return self.lam * (W @ d) + _ct_v(C, D * (C @ d))

            return fgrad, hessd

        ysp = self._row_spec_like(y)
        smap = partial(shard_map, mesh=self.mesh, check_vma=False)
        fg_body = smap(
            self._local_fgrad,
            in_specs=(self.c_spec, self.w_spec, ysp, self.rep_spec),
            out_specs=(self.rep_spec, self.rep_spec, ysp),
        )
        hd_body = smap(
            self._local_hessd,
            in_specs=(self.c_spec, self.w_spec, ysp, self.rep_spec),
            out_specs=self.rep_spec,
        )
        fgrad = lambda beta: fg_body(C, W, y, beta)
        hessd = lambda D, d: hd_body(C, W, D, d)
        return fgrad, hessd

    # ------------------------------------------------------------------ solve
    def _as_global_rows(self, arr):
        """Row-shard a host array over the spanning mesh (each process
        contributes its contiguous block of rows it already holds in
        full); pass through arrays that are already process-spanning."""
        if isinstance(arr, jax.Array) and not arr.is_fully_addressable:
            return arr
        return multihost.shard_rows_from_replicated(
            np.asarray(arr), self.mesh, self.dist.data_axes)

    def _as_replicated(self, arr):
        if isinstance(arr, jax.Array) and not arr.is_fully_addressable:
            return arr
        return multihost.replicate(np.asarray(arr), self.mesh)

    def solve(self, X, y, basis, beta0=None,
              cfg: TronConfig = TronConfig(), checkpoint=None,
              state0=None) -> TronResult:
        if multihost.active():
            # in-memory fit on a process-spanning mesh: X/y become global
            # row-sharded arrays (this process supplies only its block),
            # basis/beta replicas — after which the closures below compile
            # to the exact single-process program, psums included
            multihost.check_mesh_spans(self.mesh)
            X = self._as_global_rows(X)
            y = self._as_global_rows(y)
            basis = self._as_replicated(basis)
            if beta0 is not None:
                beta0 = self._as_replicated(beta0)
        if multihost.active():
            if not self.dist.fused or self.dist.materialize:
                raise ValueError(
                    "multi-controller in-memory fits route through the "
                    "fused rows-only closures (plan 'otf_shard'); other "
                    "in-memory plans are rejected at machine construction")
            if checkpoint is not None or state0 is not None:
                raise ValueError(
                    "checkpointed multi-controller fits use plan 'stream' "
                    "(the paper's deployment shape — tron_host snapshots "
                    "between passes); the in-memory 'otf_shard' traced "
                    "driver cannot hand process-spanning state to the host "
                    "mid-trace")
            if beta0 is None:
                beta0 = self._as_replicated(
                    np.zeros((basis.shape[0],), np.dtype(X.dtype)))

            # non-addressable arrays may not be *closed over* inside jit —
            # build the closures on the traced arguments instead
            @jax.jit
            def _run_global(X, y, basis, beta0):
                fgrad, hessd = self.make_fused_closures(X, y, basis)
                return tron(fgrad, hessd, beta0, cfg)

            with self.mesh:
                return _run_global(X, y, basis, beta0)

        if self.dist.materialize:
            C, W = self.precompute(X, basis)
            fgrad, hessd = self.make_closures(C, W, y)
        elif self.dist.fused:
            fgrad, hessd = self.make_fused_closures(X, y, basis)
        else:
            fgrad, hessd = self.make_otf_closures(X, y, basis)
        if beta0 is None:
            beta0 = jnp.zeros((basis.shape[0],), X.dtype)

        if checkpoint is None and state0 is None:
            @jax.jit
            def _run(beta0):
                return tron(fgrad, hessd, beta0, cfg)

            with self.mesh:
                return _run(beta0)
        # checkpointed/resumed: tron segments its own jitted while_loop so
        # the host can snapshot between segments (no outer jit here)
        with self.mesh:
            return tron(
                fgrad, hessd, beta0, cfg, state0=state0,
                snapshot_every=checkpoint.interval if checkpoint else 0,
                on_snapshot=checkpoint.on_snapshot if checkpoint else None)
