"""DEPRECATED single-machine facade — thin shim over repro.api.

``solve`` predates the unified estimator; it now builds a
``KernelMachine(solver="tron", plan="local")`` and repackages the result,
so legacy scripts keep running while all math lives behind the registry.
Prefer::

    from repro.api import KernelMachine, MachineConfig
    km = KernelMachine(MachineConfig(kernel=..., lam=...)).fit(X, y, basis)
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax.numpy as jnp

from repro.core.losses import Loss, register_loss
from repro.core.nystrom import KernelSpec, predict
from repro.core.tron import TronConfig, TronResult


def loss_name(loss: Loss | str) -> str:
    """Name for a config-carried loss; registers unknown Loss objects so
    shims keep accepting arbitrary Loss instances, as they always did."""
    return loss if isinstance(loss, str) else register_loss(loss)


@dataclasses.dataclass
class NystromMachine:
    """A trained Nystrom kernel machine: basis points + beta. (Legacy result
    type of ``solve``; the estimator equivalent is ``KernelMachine``.)"""

    basis: jnp.ndarray
    beta: jnp.ndarray
    kernel: KernelSpec
    stats: TronResult

    def decision(self, X, backend: str = "jnp"):
        return predict(X, self.basis, self.beta, self.kernel, backend)

    def accuracy(self, X, y, backend: str = "jnp") -> float:
        o = self.decision(X, backend)
        return float(jnp.mean(jnp.sign(o) == y))


def solve(X, y, basis, *, lam: float, loss: Loss | str = "squared_hinge",
          kernel: KernelSpec = KernelSpec(), cfg: TronConfig = TronConfig(),
          beta0: Optional[jnp.ndarray] = None,
          backend: str = "jnp") -> NystromMachine:
    """Deprecated. The exact replacement is::

        from repro.api import KernelMachine, MachineConfig
        km = KernelMachine(MachineConfig(kernel=kernel, loss=loss, lam=lam,
                                         solver="tron", plan="local",
                                         tron=cfg, backend=backend))
        km.fit(X, y, basis, beta0=beta0)   # km.state_["beta"], km.result_
    """
    from repro.api import KernelMachine, MachineConfig  # lazy: avoid cycle
    from repro.api.solvers import ovr_classes
    if ovr_classes(X, y) is not None:
        raise ValueError(
            "repro.core.solve predates multiclass support and its "
            "NystromMachine result is sign-based binary; integer "
            "multiclass labels train one-vs-rest via "
            "KernelMachine(MachineConfig(solver='tron', ...)).fit(X, y)")
    warnings.warn(
        "repro.core.solve is deprecated; use "
        "KernelMachine(MachineConfig(solver='tron', plan='local', ...))"
        ".fit(X, y, basis)", DeprecationWarning, stacklevel=2)
    config = MachineConfig(
        kernel=kernel, loss=loss_name(loss), lam=lam,
        solver="tron", plan="local", tron=cfg, backend=backend)
    km = KernelMachine(config).fit(X, y, basis, beta0=beta0)
    return NystromMachine(basis=basis, beta=km.state_["beta"], kernel=kernel,
                          stats=km.result_.tron)
