"""Single-machine convenience facade over formulation (4) + TRON.

This is the 'one node' row of the paper's tables; the distributed path is
repro.core.distributed.DistributedNystrom with identical math.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formulation import Formulation4
from repro.core.losses import Loss, get_loss
from repro.core.nystrom import KernelSpec, build_C, build_W, predict
from repro.core.tron import TronConfig, TronResult, tron


@dataclasses.dataclass
class NystromMachine:
    """A trained Nystrom kernel machine: basis points + beta."""

    basis: jnp.ndarray
    beta: jnp.ndarray
    kernel: KernelSpec
    stats: TronResult

    def decision(self, X, backend: str = "jnp"):
        return predict(X, self.basis, self.beta, self.kernel, backend)

    def accuracy(self, X, y, backend: str = "jnp") -> float:
        o = self.decision(X, backend)
        return float(jnp.mean(jnp.sign(o) == y))


def solve(X, y, basis, *, lam: float, loss: Loss | str = "squared_hinge",
          kernel: KernelSpec = KernelSpec(), cfg: TronConfig = TronConfig(),
          beta0: Optional[jnp.ndarray] = None,
          backend: str = "jnp") -> NystromMachine:
    loss = get_loss(loss) if isinstance(loss, str) else loss
    C = build_C(X, basis, kernel, backend)
    W = build_W(basis, kernel, backend)
    form = Formulation4(lam=lam, loss=loss)
    if beta0 is None:
        beta0 = jnp.zeros((basis.shape[0],), X.dtype)

    @jax.jit
    def _run(C, W, y, beta0):
        return tron(lambda b: form.fgrad(C, W, y, b),
                    lambda D, d: form.hessd(C, W, D, d), beta0, cfg)

    stats = _run(C, W, y, beta0)
    return NystromMachine(basis=basis, beta=stats.beta, kernel=kernel,
                          stats=stats)
