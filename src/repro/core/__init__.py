"""Core library: the paper's contribution.

Formulation (4) of the Nystrom-approximated kernel machine, the TRON
solver, and the distributed Algorithm 1 (shard_map + psum AllReduce).

The estimator-style surface over all of this is ``repro.api``
(KernelMachine + solver/plan registries); ``solve``, ``stagewise_solve``
and ``solve_rff`` remain as deprecated shims.
"""
from repro.core.losses import LOSSES, get_loss, SQUARED_HINGE, LOGISTIC, SQUARED
from repro.core.nystrom import KernelSpec, gram, build_C, build_W, predict
from repro.core.formulation import Formulation4, to_linearized, beta_from_w
from repro.core.tron import TronConfig, TronResult, tron, tron_host
from repro.core.solver import NystromMachine, solve
from repro.core.distributed import DistConfig, DistributedNystrom
from repro.core.basis import random_basis, kmeans, select_basis
from repro.core.stagewise import stagewise_solve, StageResult

__all__ = [
    "LOSSES", "get_loss", "SQUARED_HINGE", "LOGISTIC", "SQUARED",
    "KernelSpec", "gram", "build_C", "build_W", "predict",
    "Formulation4", "to_linearized", "beta_from_w",
    "TronConfig", "TronResult", "tron", "tron_host",
    "NystromMachine", "solve",
    "DistConfig", "DistributedNystrom",
    "random_basis", "kmeans", "select_basis",
    "stagewise_solve", "StageResult",
]
