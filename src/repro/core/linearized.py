"""Baseline: formulation (3), the 'linearized kernel machine' (Zhang et al).

The path the paper argues against at large m: eigendecompose W (O(m^3)),
form A = C U Lam^{-1/2} (O(n m^2)), then solve a LINEAR machine
    min_w lam/2 ||w||^2 + L(A w, y).
We reuse TRON for the linear solve (W = I, C = A), which keeps the
solver-quality comparison apples-to-apples — the cost difference measured
in benchmarks/table1_formulations.py is therefore purely the
eigendecomposition + A-formation overhead the paper's formulation avoids.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formulation import Formulation4, to_linearized, beta_from_w
from repro.core.losses import Loss, get_loss
from repro.core.nystrom import KernelSpec, build_C, build_W
from repro.core.tron import TronConfig, TronResult, tron


@dataclasses.dataclass
class LinearizedResult:
    w: jnp.ndarray
    beta: jnp.ndarray        # mapped back: beta = U Lam^{-1/2} w
    f: float
    n_iter: int
    time_eig_and_A: float    # the paper's 'Fraction of time for A' numerator
    time_solve: float
    stats: Optional[TronResult] = None   # full solver counters for FitResult


def solve_linearized(X, y, basis, *, lam: float, loss: Loss | str,
                     kernel: KernelSpec, rank: Optional[int] = None,
                     cfg: TronConfig = TronConfig(),
                     backend: str = "jnp") -> LinearizedResult:
    """Solve formulation (3); timings split so Table 1 can be reproduced."""
    loss = get_loss(loss) if isinstance(loss, str) else loss
    C = build_C(X, basis, kernel, backend)
    W = build_W(basis, kernel, backend)

    t0 = time.perf_counter()
    A, U, lam_vals = to_linearized(C, W, rank=rank)
    A.block_until_ready()
    t_a = time.perf_counter() - t0

    form = Formulation4(lam=lam, loss=loss)   # with W=I this IS the linear machine
    eye = jnp.eye(A.shape[1], dtype=A.dtype)

    run = jax.jit(lambda A, y, w0: tron(
        lambda w: form.fgrad(A, eye, y, w),
        lambda D, d: form.hessd(A, eye, D, d),
        w0, cfg))

    t0 = time.perf_counter()
    res = run(A, y, jnp.zeros((A.shape[1],), A.dtype))
    res.beta.block_until_ready()
    t_solve = time.perf_counter() - t0

    beta = beta_from_w(U, lam_vals, res.beta)
    return LinearizedResult(w=res.beta, beta=beta, f=float(res.f),
                            n_iter=int(res.n_iter),
                            time_eig_and_A=t_a, time_solve=t_solve,
                            stats=res)
