"""Version-compat wrappers for the small set of jax APIs that moved.

The repo targets current jax (``jax.shard_map``, ``check_vma``,
``jax.make_mesh(..., axis_types=...)``); the container may ship an older
release where shard_map still lives in ``jax.experimental`` under the
``check_rep`` spelling and ``make_mesh`` has no ``axis_types``. Every
internal call site goes through this module so the difference is absorbed
in exactly one place.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence, Tuple

import jax

try:  # jax >= 0.5: top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SMAP_PARAMS = set(inspect.signature(_shard_map_impl).parameters)
# replication/varying-manual-axes check kwarg was renamed check_rep -> check_vma
_CHECK_KW = "check_vma" if "check_vma" in _SMAP_PARAMS else "check_rep"


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the modern keyword spelling on any jax."""
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs,
              _CHECK_KW: check_vma}
    if f is None:  # support partial-style usage: shard_map(mesh=...)(f)
        return lambda fn: _shard_map_impl(fn, **kwargs)
    return _shard_map_impl(f, **kwargs)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on any jax version
    (older releases return a one-element list of per-program dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def axis_size(name) -> int:
    """``jax.lax.axis_size`` (newer jax) with a psum(1) fallback that is
    constant-folded to the same static extent inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


_MESH_PARAMS = set(inspect.signature(jax.make_mesh).parameters)
_HAS_AXIS_TYPES = "axis_types" in _MESH_PARAMS


def default_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where supported, else None."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return None
    return (at.Auto,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` accepting (and dropping, if unsupported) axis_types."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = default_axis_types(len(tuple(axis_shapes)))
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def default_mesh(data_axes: Tuple[str, ...] = ("data",),
                 model_axis: Optional[str] = None) -> jax.sharding.Mesh:
    """All local devices laid out on the first data axis (trivial otherwise)."""
    names = tuple(data_axes) + ((model_axis,) if model_axis else ())
    shape = (len(jax.devices()),) + (1,) * (len(names) - 1)
    return make_mesh(shape, names)
