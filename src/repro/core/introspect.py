"""Jaxpr shape instrumentation: *prove* what a plan materializes.

The on-the-fly plans claim the (n x m) kernel block C never exists in
device memory. A claim in a docstring rots; this module lets tests (and
benchmarks) assert it mechanically: trace a function to its jaxpr and
report the largest intermediate array any equation produces, recursing
into pjit / scan / while / cond / shard_map sub-jaxprs.

Two deliberate scoping rules:

* Inputs (invars / constvars) are not intermediates — X itself is (n, d)
  and a materialized C passed *into* a closure is the caller's problem.
  Only equation outputs count: arrays the traced computation allocates.
* ``pallas_call`` equations count their HBM outputs but are not entered:
  inside the kernel, refs live in VMEM tiles by construction, which is
  exactly the memory the fused path is allowed to use.  Everything the
  kernel returns to HBM still shows up as the call's outvars.

Shard-mapped bodies are walked with their *per-shard* avals, so the bound
checked for a distributed plan is per-device — the quantity that OOMs.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Iterator

import jax
import numpy as np


def _aval_elems(var) -> int:
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    try:
        return int(math.prod(shape))
    except TypeError:       # symbolic dims: not our use case, don't crash
        return 0


def _subjaxprs(params: dict) -> Iterator[Any]:
    """Yield every (Closed)Jaxpr reachable from an eqn's params."""
    for v in params.values():
        stack = [v]
        while stack:
            item = stack.pop()
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield item.jaxpr            # ClosedJaxpr
            elif hasattr(item, "eqns"):
                yield item                  # raw Jaxpr
            elif isinstance(item, (tuple, list)):
                stack.extend(item)


def max_intermediate_elems_jaxpr(jaxpr) -> int:
    """Largest eqn-output element count anywhere in ``jaxpr`` (recursive)."""
    worst = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            worst = max(worst, _aval_elems(var))
        if "pallas" in eqn.primitive.name:
            continue    # kernel internals are VMEM tiles, not HBM arrays
        for sub in _subjaxprs(eqn.params):
            worst = max(worst, max_intermediate_elems_jaxpr(sub))
    return worst


def max_intermediate_elems(fn: Callable, *args, **kwargs) -> int:
    """Trace ``fn(*args, **kwargs)`` and return the largest intermediate
    array (in elements) the computation materializes. Arguments may be
    arrays or ShapeDtypeStructs; nothing is executed."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return max_intermediate_elems_jaxpr(closed.jaxpr)


def _aval_bytes(var) -> int:
    aval = getattr(var, "aval", None)
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    return _aval_elems(var) * dtype.itemsize


def max_intermediate_bytes_jaxpr(jaxpr) -> int:
    """Largest eqn-output byte size anywhere in ``jaxpr`` (recursive) —
    same walk as :func:`max_intermediate_elems_jaxpr` but dtype-aware, for
    benchmarks that report peak-intermediate memory rather than assert an
    element-count contract."""
    worst = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            worst = max(worst, _aval_bytes(var))
        if "pallas" in eqn.primitive.name:
            continue
        for sub in _subjaxprs(eqn.params):
            worst = max(worst, max_intermediate_bytes_jaxpr(sub))
    return worst


def max_intermediate_bytes(fn: Callable, *args, **kwargs) -> int:
    """Byte-sized counterpart of :func:`max_intermediate_elems`."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return max_intermediate_bytes_jaxpr(closed.jaxpr)


def _resolve_dtype(dtype) -> np.dtype:
    """np.dtype for a dtype object or a jnp name ('bfloat16' is not a
    numpy-native name, so strings resolve through jax.numpy first)."""
    import jax.numpy as jnp
    if isinstance(dtype, str):
        dtype = getattr(jnp, dtype, dtype)
    return np.dtype(dtype)


def max_intermediate_elems_of_dtype_jaxpr(jaxpr, dtype: np.dtype) -> int:
    """Largest eqn-output element count among outputs *of this dtype*.

    The dtype-policy counterpart of :func:`max_intermediate_elems_jaxpr`:
    under a bf16 policy the (rows, m) finished gram chunk is allowed to
    exist — at bf16. What the policy forbids is that chunk at fp32, which
    would silently give back the halved-transient win. Walking only the
    fp32 outputs lets a test pin that contract mechanically."""
    worst = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if getattr(aval, "dtype", None) == dtype:
                worst = max(worst, _aval_elems(var))
        if "pallas" in eqn.primitive.name:
            continue
        for sub in _subjaxprs(eqn.params):
            worst = max(worst,
                        max_intermediate_elems_of_dtype_jaxpr(sub, dtype))
    return worst


def max_intermediate_elems_of_dtype(fn: Callable, dtype,
                                    *args, **kwargs) -> int:
    """Trace ``fn(*args, **kwargs)`` and return the largest intermediate
    of ``dtype`` (an object or a jnp name such as 'bfloat16') that the
    computation materializes. Nothing is executed."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return max_intermediate_elems_of_dtype_jaxpr(closed.jaxpr,
                                                 _resolve_dtype(dtype))


# Primitives whose operands cross device (and, on a process-spanning
# mesh, host) boundaries. Payload accounting uses the *outvar* avals:
# inside a shard_map body those are per-shard, so on a 1-axis data mesh
# the count is exactly the bytes each host contributes to the AllReduce.
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "psum2", "psum_invariant", "all_reduce",
    "all_gather", "all_gather_invariant", "reduce_scatter",
    "all_to_all", "ppermute", "pmax", "pmin",
})


def collective_payload_bytes_jaxpr(jaxpr) -> int:
    """Total bytes of collective-op payloads in ``jaxpr`` (recursive).

    Sums the outvar sizes of every :data:`COLLECTIVE_PRIMITIVES` equation,
    walking pjit / scan / while / cond / shard_map sub-jaxprs the same way
    as :func:`max_intermediate_elems_jaxpr`. For the training closures the
    result is the measured cross-host traffic of one evaluation — the
    quantity the O(m)-per-eval communication contract bounds. Equations
    under ``scan``/``while`` count once; the caller multiplies by trip
    count if a per-run total is wanted (the per-eval contract does not).
    """
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVE_PRIMITIVES:
            for var in eqn.outvars:
                total += _aval_bytes(var)
        if "pallas" in eqn.primitive.name:
            continue
        for sub in _subjaxprs(eqn.params):
            total += collective_payload_bytes_jaxpr(sub)
    return total


def collective_payload_bytes(fn: Callable, *args, **kwargs) -> int:
    """Trace ``fn(*args, **kwargs)`` and return the summed payload bytes
    of every collective primitive — measured from the program, so tests
    assert communication volume instead of trusting a docstring."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return collective_payload_bytes_jaxpr(closed.jaxpr)


def fused_contract_limit(rows: int, m: int, k: int = 1) -> int:
    """Element limit for the fused-kmvp memory contract with ``k`` RHS.

    The forbidden allocation is the (rows, m) gram block. A multi-RHS
    evaluation legitimately materializes (rows, k) outputs and (m, k)
    gradients, so the rows*m bound only *separates* legal from forbidden
    while k < m — guard that loudly instead of letting a wide-k test
    assert nothing.
    """
    if k >= m:
        raise ValueError(
            f"fused memory contract is vacuous at k={k} >= m={m}: the "
            f"legal (rows, k) output block is at least as large as the "
            f"forbidden (rows, m) gram block; test with k < m")
    return rows * m


def assert_max_intermediate_below(fn: Callable, limit_elems: int,
                                  *args, **kwargs) -> int:
    """Raise if any intermediate of ``fn`` reaches ``limit_elems``.

    Returns the measured maximum so callers can report it. This is the
    enforcement behind the ``otf``/``otf_shard`` memory contract: pass
    ``limit_elems = n_shard * m`` to assert the per-device C block is
    never allocated.
    """
    got = max_intermediate_elems(fn, *args, **kwargs)
    if got >= limit_elems:
        raise AssertionError(
            f"intermediate of {got} elements >= limit {limit_elems}: "
            f"the traced computation materializes an array the caller "
            f"declared forbidden (C block?)")
    return got
