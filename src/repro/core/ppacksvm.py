"""Baseline: P-packSVM (Zhu et al., ICDM'09) — packed parallel kernel SGD.

The paper's §4.5 comparison target: primal stochastic gradient descent in
the kernel feature space (Pegasos-style schedule eta_t = 1/(lam t)), with a
PACKING strategy — r examples are processed per communication round: their
outputs are computed against the full alpha in one distributed matvec
(the AllReduce the paper mentions), then the r updates are applied
sequentially using the r x r kernel block (the O(r^2) correction that caps
r at ~100).

We keep the scale-factor trick (alpha stored unnormalized, scalar s carries
the (1 - 1/t) decay products) so a pack costs O(r n) + O(r^2), not O(r n^2).
The number of communication rounds is O(n/r) per epoch — the property that
makes it latency-fragile on the paper's Hadoop AllReduce and motivates the
paper's O(N_tron) ~ 300-round alternative.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.nystrom import KernelSpec, gram


@dataclasses.dataclass
class PPackResult:
    alpha: jnp.ndarray      # de-scaled dual weights over training points
    n_rounds: int           # communication rounds (packs) executed


def ppacksvm(key: jax.Array, X, y, *, lam: float, kernel: KernelSpec,
             epochs: int = 1, pack_size: int = 64,
             backend: str = "jnp") -> PPackResult:
    """Train a hinge-loss kernel SVM with packed Pegasos SGD."""
    n = X.shape[0]
    r = pack_size
    n_packs = (n * epochs) // r
    perm = jax.random.permutation(
        key, jnp.tile(jnp.arange(n), epochs))[: n_packs * r].reshape(n_packs, r)

    def pack_step(carry, idx):
        alpha, s, t = carry
        Xp, yp = X[idx], y[idx]
        # --- distributed part: one matvec against full alpha + AllReduce ---
        o0 = s * (gram(Xp, X, kernel, backend) @ alpha)        # (r,)
        Kpp = gram(Xp, Xp, kernel, backend)                    # (r, r) local

        def inner(c, j):
            alpha, s, t, o = c
            eta = 1.0 / (lam * t)
            decay = 1.0 - eta * lam                            # = 1 - 1/t
            s_new = s * decay
            o = o * decay
            viol = yp[j] * o[j] < 1.0
            delta = jnp.where(viol, eta * yp[j], 0.0)
            alpha = alpha.at[idx[j]].add(delta / jnp.maximum(s_new, 1e-30))
            o = o + delta * Kpp[:, j]
            return (alpha, s_new, t + 1.0, o), None

        (alpha, s, t, _), _ = jax.lax.scan(
            inner, (alpha, s, t, o0), jnp.arange(r))
        # re-normalize the scale factor into alpha when it gets tiny
        renorm = s < 1e-12
        alpha = jnp.where(renorm, alpha * s, alpha)
        s = jnp.where(renorm, 1.0, s)
        return (alpha, s, t), None

    alpha0 = jnp.zeros((n,), X.dtype)
    (alpha, s, _), _ = jax.lax.scan(
        pack_step, (alpha0, jnp.array(1.0, X.dtype), jnp.array(1.0, X.dtype)),
        perm)
    return PPackResult(alpha=alpha * s, n_rounds=int(n_packs))


def predict(alpha, X_train, X_test, kernel: KernelSpec, backend: str = "jnp"):
    return gram(X_test, X_train, kernel, backend) @ alpha
