"""Stage-wise addition of basis points (paper §3, 'Stage-wise addition').

The advantage of formulation (4) the paper highlights: growing m needs no
incremental SVD. We warm-start by zero-padding beta for the new points and
only the new columns of C (and new rows/cols of W) are computed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.core.formulation import Formulation4
from repro.core.losses import Loss
from repro.core.nystrom import KernelSpec, gram
from repro.core.tron import TronConfig, tron


@dataclasses.dataclass
class StageResult:
    m: int
    f: float
    gnorm: float
    n_iter: int
    beta: jnp.ndarray


def stagewise_solve(X, y, basis_stages: List[jnp.ndarray], *, lam: float,
                    loss: Loss, kernel: KernelSpec,
                    cfg: TronConfig = TronConfig(),
                    backend: str = "jnp",
                    callback: Optional[Callable] = None) -> List[StageResult]:
    """Solve (4) with basis sets growing stage by stage.

    ``basis_stages[k]`` holds only the points ADDED at stage k. Returns the
    per-stage results; beta of the final stage is the full solution.
    Incrementality: stage k computes only gram(X, new) and the new W blocks.
    """
    form = Formulation4(lam=lam, loss=loss)
    results: List[StageResult] = []
    C = None
    W = None
    beta = None

    run = jax.jit(lambda C, W, y, b: tron(
        lambda bb: form.fgrad(C, W, y, bb),
        lambda D, d: form.hessd(C, W, D, d),
        b, cfg))

    basis_all = None
    for stage, new_pts in enumerate(basis_stages):
        C_new = gram(X, new_pts, kernel, backend)              # only new cols
        if C is None:
            C, W, basis_all = C_new, gram(new_pts, new_pts, kernel, backend), new_pts
            beta = jnp.zeros((new_pts.shape[0],), X.dtype)
        else:
            W_cross = gram(basis_all, new_pts, kernel, backend)  # old x new
            W_new = gram(new_pts, new_pts, kernel, backend)
            W = jnp.block([[W, W_cross], [W_cross.T, W_new]])
            C = jnp.concatenate([C, C_new], axis=1)
            basis_all = jnp.concatenate([basis_all, new_pts], axis=0)
            # warm start: old beta kept, new coordinates start at zero
            beta = jnp.concatenate(
                [beta, jnp.zeros((new_pts.shape[0],), beta.dtype)])

        res = run(C, W, y, beta)
        beta = res.beta
        out = StageResult(m=int(basis_all.shape[0]), f=float(res.f),
                          gnorm=float(res.gnorm), n_iter=int(res.n_iter),
                          beta=beta)
        results.append(out)
        if callback is not None:
            callback(out)
    return results
