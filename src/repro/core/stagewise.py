"""DEPRECATED stage-wise driver — thin shim over KernelMachine.partial_fit.

Stage-wise basis addition (paper §3) now lives on the estimator. The exact
replacement for ``stagewise_solve(X, y, stages, lam=.., loss=.., kernel=..,
cfg=..)`` is::

    from repro.api import KernelMachine, MachineConfig
    km = KernelMachine(MachineConfig(kernel=kernel, loss=loss, lam=lam,
                                     solver="tron", plan="local", tron=cfg))
    for new_points in stages:
        km.partial_fit(X, y, new_points)      # warm-started, incremental C/W
    # km.history_ holds one FitResult per stage; km.state_["beta"] the solution

Each ``partial_fit`` call zero-pads beta for the new points and recomputes
only the new columns of C (and new blocks of W) under the ``local`` plan
(under ``otf_shard``/``stream`` recomputation makes growth free of any
cache). This module repackages that history as the legacy ``StageResult``
list; ``loss`` accepts a name or a Loss object, matching every other
entrypoint.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, List, Optional

import jax.numpy as jnp

from repro.core.losses import Loss
from repro.core.nystrom import KernelSpec
from repro.core.solver import loss_name
from repro.core.tron import TronConfig


@dataclasses.dataclass
class StageResult:
    m: int
    f: float
    gnorm: float
    n_iter: int
    beta: jnp.ndarray


def stagewise_solve(X, y, basis_stages: List[jnp.ndarray], *, lam: float,
                    loss: Loss | str, kernel: KernelSpec,
                    cfg: TronConfig = TronConfig(),
                    backend: str = "jnp",
                    callback: Optional[Callable] = None) -> List[StageResult]:
    """Deprecated: call ``KernelMachine(MachineConfig(kernel=kernel,
    loss=loss, lam=lam, solver="tron", plan="local",
    tron=cfg)).partial_fit(X, y, new_points)`` once per stage instead (see
    the module docstring for the full replacement snippet).

    ``basis_stages[k]`` holds only the points ADDED at stage k. Returns the
    per-stage results; beta of the final stage is the full solution.
    """
    from repro.api import KernelMachine, MachineConfig  # lazy: avoid cycle
    warnings.warn(
        "repro.core.stagewise_solve is deprecated; use "
        "KernelMachine(MachineConfig(solver='tron', plan='local', ...))"
        ".partial_fit(X, y, new_points) once per stage",
        DeprecationWarning, stacklevel=2)
    config = MachineConfig(
        kernel=kernel, loss=loss_name(loss), lam=lam,
        solver="tron", plan="local", tron=cfg, backend=backend)
    km = KernelMachine(config)
    results: List[StageResult] = []
    for new_pts in basis_stages:
        km.partial_fit(X, y, new_pts)
        r = km.result_
        out = StageResult(m=r.m, f=r.f, gnorm=r.gnorm, n_iter=r.n_iter,
                          beta=km.state_["beta"])
        results.append(out)
        if callback is not None:
            callback(out)
    return results
