"""TRON: Trust-Region Newton method with Steihaug-CG inner solves.

Faithful JAX port of the solver the paper uses (Lin, Weng & Keerthi,
"Trust region Newton methods for large-scale logistic regression", ICML'07
— reference [16]; the liblinear tron.cpp update rules). Fully jittable:
outer iteration and inner CG are ``lax.while_loop``s, so the whole solve —
including the distributed f/g/Hd closures with their psum AllReduces —
lowers to a single XLA program. This is the TPU answer to the paper's §4.4
latency pathology: 5N AllReduce calls become on-device ICI collectives
inside one compiled loop, with zero per-call host latency.

The solver is generic over two closures:
    fgrad(beta)  -> (f, g, aux)   # aux = Gauss-Newton diagonal info
    hessd(aux, d) -> H d
so the same code runs the local, the shard_map-distributed, and the
materialization-free (fused Pallas) problem variants.

Two drivers share the update rules:
  * :func:`tron` — fully traced (``lax.while_loop``); closures must be
    jax-traceable. Every in-memory plan uses this.
  * :func:`tron_host` — the same algorithm as an eager host loop, for
    closures that cannot be traced because each f/g/Hd evaluation is an
    *accumulation over data chunks streamed from disk* (the ``stream``
    execution plan). The m-vector CG algebra runs in numpy on the host;
    all O(n) work stays inside the chunk closures.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TronConfig:
    max_iter: int = 200          # outer Newton iterations (paper: N ~ 300)
    grad_rtol: float = 1e-3      # stop when ||g|| <= grad_rtol * ||g0||
    cg_rtol: float = 0.1         # inner CG: ||r|| <= cg_rtol * ||g||
    cg_max_iter: int = 64        # cap on CG steps per outer iteration
    eta0: float = 1e-4           # step acceptance threshold
    eta1: float = 0.25
    eta2: float = 0.75
    sigma1: float = 0.25         # trust-region shrink/grow factors
    sigma2: float = 0.5
    sigma3: float = 4.0


class TronResult(NamedTuple):
    beta: jnp.ndarray
    f: jnp.ndarray
    gnorm: jnp.ndarray
    n_iter: jnp.ndarray   # outer iterations performed
    n_fg: jnp.ndarray     # function/gradient evaluations (paper step 4a/4b calls)
    n_hd: jnp.ndarray     # Hessian-vector products     (paper step 4c calls)
    converged: jnp.ndarray


class _CGState(NamedTuple):
    s: jnp.ndarray
    r: jnp.ndarray
    d: jnp.ndarray
    rtr: jnp.ndarray
    it: jnp.ndarray
    active: jnp.ndarray


def _steihaug_cg(g, hvp: Callable, delta, tol, max_iter: int):
    """Steihaug-Toint CG: approximately minimize g.s + 0.5 s'Hs, ||s||<=delta.

    Returns (s, r, n_hd) with r = -g - H s maintained through boundary exits
    (liblinear trcg semantics) so the caller can form the predicted
    reduction as -0.5*(g.s - s.r).
    """
    m = g.shape[0]
    zero = jnp.zeros_like(g)
    init = _CGState(
        s=zero, r=-g, d=-g,
        rtr=g @ g,
        it=jnp.array(0, jnp.int32),
        active=jnp.asarray(True),
    )

    def cond(st: _CGState):
        return st.active & (jnp.sqrt(st.rtr) > tol) & (st.it < max_iter)

    def body(st: _CGState):
        Hd = hvp(st.d)
        dHd = st.d @ Hd
        # Negative curvature or step leaving the region -> go to boundary.
        alpha = st.rtr / jnp.where(dHd > 0, dHd, 1.0)
        s_try = st.s + alpha * st.d
        outside = (jnp.linalg.norm(s_try) >= delta) | (dHd <= 0)

        # tau >= 0 solving ||s + tau d|| = delta
        sd = st.s @ st.d
        dd = st.d @ st.d
        ss = st.s @ st.s
        rad = jnp.sqrt(jnp.maximum(sd * sd + dd * (delta * delta - ss), 0.0))
        tau = (rad - sd) / jnp.where(dd > 0, dd, 1.0)

        step = jnp.where(outside, tau, alpha)
        s_new = st.s + step * st.d
        r_new = st.r - step * Hd
        rtr_new = r_new @ r_new
        beta_cg = rtr_new / jnp.where(st.rtr > 0, st.rtr, 1.0)
        d_new = r_new + beta_cg * st.d
        return _CGState(
            s=s_new, r=r_new, d=d_new, rtr=rtr_new,
            it=st.it + 1, active=~outside,
        )

    final = jax.lax.while_loop(cond, body, init)
    return final.s, final.r, final.it


class _TronState(NamedTuple):
    beta: jnp.ndarray
    f: jnp.ndarray
    g: jnp.ndarray
    aux: jnp.ndarray
    delta: jnp.ndarray
    it: jnp.ndarray
    n_fg: jnp.ndarray
    n_hd: jnp.ndarray
    gnorm0: jnp.ndarray
    active: jnp.ndarray


def tron(fgrad: Callable, hessd: Callable, beta0: jnp.ndarray,
         cfg: TronConfig = TronConfig()) -> TronResult:
    """Minimize f via trust-region Newton-CG. See module docstring."""
    f0, g0, aux0 = fgrad(beta0)
    gnorm0 = jnp.linalg.norm(g0)
    init = _TronState(
        beta=beta0, f=f0, g=g0, aux=aux0,
        delta=gnorm0,
        it=jnp.array(0, jnp.int32),
        n_fg=jnp.array(1, jnp.int32),
        n_hd=jnp.array(0, jnp.int32),
        gnorm0=gnorm0,
        active=gnorm0 > 0,
    )

    def cond(st: _TronState):
        gnorm = jnp.linalg.norm(st.g)
        return st.active & (gnorm > cfg.grad_rtol * st.gnorm0) & (st.it < cfg.max_iter)

    def body(st: _TronState):
        gnorm = jnp.linalg.norm(st.g)
        hvp = lambda d: hessd(st.aux, d)
        s, r, cg_steps = _steihaug_cg(
            st.g, hvp, st.delta, cfg.cg_rtol * gnorm, cfg.cg_max_iter)

        snorm = jnp.linalg.norm(s)
        gs = st.g @ s
        prered = -0.5 * (gs - s @ r)

        beta_try = st.beta + s
        f_new, g_new, aux_new = fgrad(beta_try)
        actred = st.f - f_new

        # liblinear delta-update rules
        denom = f_new - st.f - gs
        alpha = jnp.where(denom <= 0, cfg.sigma3,
                          jnp.maximum(cfg.sigma1, -0.5 * (gs / jnp.where(denom == 0, 1.0, denom))))
        # On the very first iteration, recalibrate delta to the step scale.
        delta = jnp.where(st.it == 0, jnp.minimum(st.delta, snorm), st.delta)
        delta = jnp.where(
            actred < cfg.eta0 * prered,
            jnp.minimum(jnp.maximum(alpha, cfg.sigma1) * snorm, cfg.sigma2 * delta),
            jnp.where(
                actred < cfg.eta1 * prered,
                jnp.maximum(cfg.sigma1 * delta, jnp.minimum(alpha * snorm, cfg.sigma2 * delta)),
                jnp.where(
                    actred < cfg.eta2 * prered,
                    jnp.maximum(cfg.sigma1 * delta, jnp.minimum(alpha * snorm, cfg.sigma3 * delta)),
                    jnp.maximum(delta, jnp.minimum(alpha * snorm, cfg.sigma3 * delta)),
                ),
            ),
        )

        accept = actred > cfg.eta0 * prered
        beta = jnp.where(accept, beta_try, st.beta)
        f = jnp.where(accept, f_new, st.f)
        g = jnp.where(accept, g_new, st.g)
        aux = jax.tree.map(lambda a, b: jnp.where(accept, a, b), aux_new, st.aux)

        # Numerical stagnation guards (liblinear): stop on non-positive
        # predicted reduction or vanishing |actred|,|prered| relative to |f|.
        feps = jnp.abs(st.f) * 1e-12
        stagnated = (prered <= 0) | (
            (jnp.abs(actred) <= feps) & (jnp.abs(prered) <= feps))
        return _TronState(
            beta=beta, f=f, g=g, aux=aux, delta=delta,
            it=st.it + 1,
            n_fg=st.n_fg + 1,
            n_hd=st.n_hd + cg_steps,
            gnorm0=st.gnorm0,
            active=st.active & ~stagnated,
        )

    st = jax.lax.while_loop(cond, body, init)
    gnorm = jnp.linalg.norm(st.g)
    return TronResult(
        beta=st.beta, f=st.f, gnorm=gnorm,
        n_iter=st.it, n_fg=st.n_fg, n_hd=st.n_hd,
        converged=gnorm <= cfg.grad_rtol * st.gnorm0,
    )


# --------------------------------------------------------------- host driver
def _steihaug_cg_host(g, hvp: Callable, delta: float, tol: float,
                      max_iter: int):
    """Host mirror of :func:`_steihaug_cg`: same trcg semantics, numpy
    vectors, eager ``hvp`` calls (each one may stream the dataset)."""
    s = np.zeros_like(g)
    r = -g
    d = -g
    rtr = float(g @ g)
    it = 0
    while np.sqrt(rtr) > tol and it < max_iter:
        Hd = np.asarray(hvp(d), g.dtype)
        dHd = float(d @ Hd)
        alpha = rtr / (dHd if dHd > 0 else 1.0)
        s_try = s + alpha * d
        outside = (np.linalg.norm(s_try) >= delta) or (dHd <= 0)
        if outside:
            sd, dd, ss = float(s @ d), float(d @ d), float(s @ s)
            rad = np.sqrt(max(sd * sd + dd * (delta * delta - ss), 0.0))
            step = (rad - sd) / (dd if dd > 0 else 1.0)
        else:
            step = alpha
        s = s + step * d
        r = r - step * Hd
        rtr_new = float(r @ r)
        d = r + (rtr_new / (rtr if rtr > 0 else 1.0)) * d
        rtr = rtr_new
        it += 1
        if outside:
            break
    return s, r, it


def tron_host(fgrad: Callable, hessd: Callable, beta0,
              cfg: TronConfig = TronConfig()) -> TronResult:
    """Eager trust-region Newton-CG with the exact update rules of
    :func:`tron`, for accumulator-style closures.

    ``fgrad``/``hessd`` may be arbitrary Python callables — in the
    ``stream`` plan each call loops over dataset chunks, accumulating the
    m-vector on the host while per-chunk math runs jitted on the mesh.
    ``aux`` is treated as an opaque value (the stream plan keeps the
    Gauss-Newton diagonal as one row-sharded array per chunk).
    """
    beta = np.asarray(beta0)
    dtype = beta.dtype
    f, g, aux = fgrad(beta)
    f = float(f)
    g = np.asarray(g, dtype)
    gnorm0 = float(np.linalg.norm(g))
    delta = gnorm0
    it, n_fg, n_hd = 0, 1, 0
    active = gnorm0 > 0
    while active and np.linalg.norm(g) > cfg.grad_rtol * gnorm0 \
            and it < cfg.max_iter:
        gnorm = float(np.linalg.norm(g))
        s, r, cg_steps = _steihaug_cg_host(
            g, lambda d: hessd(aux, d), delta, cfg.cg_rtol * gnorm,
            cfg.cg_max_iter)
        n_hd += cg_steps

        snorm = float(np.linalg.norm(s))
        gs = float(g @ s)
        prered = -0.5 * (gs - float(s @ r))

        beta_try = (beta + s).astype(dtype)
        f_new, g_new, aux_new = fgrad(beta_try)
        f_new = float(f_new)
        g_new = np.asarray(g_new, dtype)
        n_fg += 1
        actred = f - f_new

        denom = f_new - f - gs
        if denom <= 0:
            alpha = cfg.sigma3
        else:
            alpha = max(cfg.sigma1, -0.5 * (gs / denom))
        if it == 0:
            delta = min(delta, snorm)
        if actred < cfg.eta0 * prered:
            delta = min(max(alpha, cfg.sigma1) * snorm, cfg.sigma2 * delta)
        elif actred < cfg.eta1 * prered:
            delta = max(cfg.sigma1 * delta,
                        min(alpha * snorm, cfg.sigma2 * delta))
        elif actred < cfg.eta2 * prered:
            delta = max(cfg.sigma1 * delta,
                        min(alpha * snorm, cfg.sigma3 * delta))
        else:
            delta = max(delta, min(alpha * snorm, cfg.sigma3 * delta))

        if actred > cfg.eta0 * prered:
            beta, f, g, aux = beta_try, f_new, g_new, aux_new
        it += 1

        feps = abs(f) * 1e-12
        if prered <= 0 or (abs(actred) <= feps and abs(prered) <= feps):
            active = False

    gnorm = float(np.linalg.norm(g))
    return TronResult(
        beta=jnp.asarray(beta, dtype), f=jnp.asarray(f, jnp.float32),
        gnorm=jnp.asarray(gnorm, jnp.float32),
        n_iter=jnp.asarray(it, jnp.int32),
        n_fg=jnp.asarray(n_fg, jnp.int32),
        n_hd=jnp.asarray(n_hd, jnp.int32),
        converged=jnp.asarray(gnorm <= cfg.grad_rtol * gnorm0),
    )
