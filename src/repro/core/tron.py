"""TRON: Trust-Region Newton method with Steihaug-CG inner solves.

Faithful JAX port of the solver the paper uses (Lin, Weng & Keerthi,
"Trust region Newton methods for large-scale logistic regression", ICML'07
— reference [16]; the liblinear tron.cpp update rules). Fully jittable:
outer iteration and inner CG are ``lax.while_loop``s, so the whole solve —
including the distributed f/g/Hd closures with their psum AllReduces —
lowers to a single XLA program. This is the TPU answer to the paper's §4.4
latency pathology: 5N AllReduce calls become on-device ICI collectives
inside one compiled loop, with zero per-call host latency.

The solver is generic over two closures:
    fgrad(beta)  -> (f, g, aux)   # aux = Gauss-Newton diagonal info
    hessd(aux, d) -> H d
so the same code runs the local, the shard_map-distributed, and the
materialization-free (fused Pallas) problem variants.

Both drivers are additionally generic over a trailing *column* axis:
``beta0`` may be the classic (m,) vector or an (m, K) block of K
independent problems (one-vs-rest multiclass — each column has its own y
and therefore its own objective). Every scalar of the update rules (f,
delta, gnorm, the CG dots) becomes a (K,)-vector, every branch a
per-column mask, and the loop runs until all columns converge. The payoff
is that each f/g/Hd closure call evaluates ALL columns at once: with the
fused kmvp closures one gram recomputation pass serves K columns instead
of K separate solves paying K passes. Columns that converge early are
frozen by masks (their CG direction is zeroed), so lockstep iteration
never changes any column's trajectory versus a solo run of that column.

Two drivers share the update rules:
  * :func:`tron` — fully traced (``lax.while_loop``); closures must be
    jax-traceable. Every in-memory plan uses this.
  * :func:`tron_host` — the same algorithm as an eager host loop, for
    closures that cannot be traced because each f/g/Hd evaluation is an
    *accumulation over data chunks streamed from disk* (the ``stream``
    execution plan). The m-vector CG algebra runs in numpy on the host;
    all O(n) work stays inside the chunk closures.

Both drivers are resumable: the complete iterate state of either loop is
the O(m·K) :class:`TronSnapshot` — beta, the per-column trust radii,
``gnorm0`` (the convergence reference), the per-column live masks, and
the three counters. Everything else the loops carry (f, g, aux) is a pure
deterministic function of beta: after a *rejected* step the retained
f/g/aux still correspond to the retained beta, so one ``fgrad(beta)``
call on restore rebuilds them and a resumed solve walks the exact
trajectory of the uninterrupted *checkpointed* run — bit-identically,
because the traced driver re-derives f/g/aux from beta inside the same
jitted segment program at every snapshot boundary (see :func:`tron`) and
the host driver's eager ``fgrad`` is deterministic call-for-call.
``snapshot_every`` / ``on_snapshot`` emit snapshots periodically (the
traced driver runs the ``lax.while_loop`` in jitted segments of that
many iterations so the host can observe the state between them; with
both unset the original single-while_loop program is unchanged), and
``state0`` restores one.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TronConfig:
    max_iter: int = 200          # outer Newton iterations (paper: N ~ 300)
    grad_rtol: float = 1e-3      # stop when ||g|| <= grad_rtol * ||g0||
    cg_rtol: float = 0.1         # inner CG: ||r|| <= cg_rtol * ||g||
    cg_max_iter: int = 64        # cap on CG steps per outer iteration
    eta0: float = 1e-4           # step acceptance threshold
    eta1: float = 0.25
    eta2: float = 0.75
    sigma1: float = 0.25         # trust-region shrink/grow factors
    sigma2: float = 0.5
    sigma3: float = 4.0


class TronResult(NamedTuple):
    beta: jnp.ndarray     # (m,) — or (m, K) for a column-batched solve
    f: jnp.ndarray        # scalar — or (K,) per-column objectives
    gnorm: jnp.ndarray    # scalar — or (K,)
    n_iter: jnp.ndarray   # outer iterations performed (shared loop trips)
    n_fg: jnp.ndarray     # function/gradient evaluations (paper step 4a/4b calls)
    n_hd: jnp.ndarray     # Hessian-vector products     (paper step 4c calls)
    converged: jnp.ndarray  # scalar bool — or (K,) per column


class TronSnapshot(NamedTuple):
    """Resumable iterate state of a TRON solve, as host numpy arrays.

    Deliberately minimal — O(m·K) floats plus four scalars. f, g and aux
    are NOT stored: they are pure deterministic functions of ``beta``
    (even after a rejected step the retained f/g/aux correspond to the
    retained beta), so restore re-evaluates ``fgrad(beta)`` once and gets
    them back bit-identically. That re-evaluation is NOT counted in
    ``n_fg``, so a resumed run's counters match the uninterrupted run's.
    """
    beta: np.ndarray      # (m[, K]) iterate
    delta: np.ndarray     # trust radius — scalar or (K,)
    gnorm0: np.ndarray    # ||g(beta_0)|| convergence reference
    active: np.ndarray    # per-column live mask (stagnation-guard state)
    it: int               # outer iterations completed
    n_fg: int
    n_hd: int

    def to_arrays(self) -> dict:
        """Flat name->array dict, ready for an .npz checkpoint."""
        return {
            "beta": np.asarray(self.beta),
            "delta": np.asarray(self.delta),
            "gnorm0": np.asarray(self.gnorm0),
            "active": np.asarray(self.active),
            "it": np.asarray(int(self.it), np.int64),
            "n_fg": np.asarray(int(self.n_fg), np.int64),
            "n_hd": np.asarray(int(self.n_hd), np.int64),
        }

    @classmethod
    def from_arrays(cls, arrays: dict) -> "TronSnapshot":
        return cls(beta=np.asarray(arrays["beta"]),
                   delta=np.asarray(arrays["delta"]),
                   gnorm0=np.asarray(arrays["gnorm0"]),
                   active=np.asarray(arrays["active"], bool),
                   it=int(arrays["it"]),
                   n_fg=int(arrays["n_fg"]),
                   n_hd=int(arrays["n_hd"]))


def _cdot(a, b):
    """Per-column dot: a scalar for (m,) operands, (K,) for (m, K).

    The 1-D case keeps the exact dot/norm primitives of the single-RHS
    solver so its f32 rounding (and therefore its tested convergence
    trajectories) is unchanged by the column-batched generalization.
    """
    if a.ndim == 1:
        return a @ b
    return jnp.sum(a * b, axis=0)


def _cnorm(a):
    if a.ndim == 1:
        return jnp.linalg.norm(a)
    return jnp.sqrt(jnp.sum(a * a, axis=0))


class _CGState(NamedTuple):
    s: jnp.ndarray
    r: jnp.ndarray
    d: jnp.ndarray
    rtr: jnp.ndarray
    it: jnp.ndarray
    active: jnp.ndarray


def _steihaug_cg(g, hvp: Callable, delta, tol, max_iter: int, active0=None):
    """Steihaug-Toint CG: approximately minimize g.s + 0.5 s'Hs, ||s||<=delta.

    Returns (s, r, n_hd) with r = -g - H s maintained through boundary exits
    (liblinear trcg semantics) so the caller can form the predicted
    reduction as -0.5*(g.s - s.r).

    Column-batched when g is (m, K): delta/tol are (K,), every iteration
    makes ONE hvp call on the whole (m, K) direction block (the fused-kmvp
    amortization), and columns that hit the boundary or their tolerance are
    frozen (their direction zeroed) while the rest keep iterating.
    ``active0`` masks out columns the outer loop already finished.
    """
    multi = g.ndim > 1
    # In the classic 1-D problem every mask below is trace-time True while
    # the loop runs, so the masking selects are elided entirely — the
    # lowered 1-D program (and its f32 rounding) is unchanged from the
    # single-RHS solver.
    sel = (lambda run, new, old: jnp.where(run, new, old)) if multi \
        else (lambda run, new, old: new)
    zero = jnp.zeros_like(g)
    init = _CGState(
        s=zero, r=-g, d=-g,
        rtr=_cdot(g, g),
        it=jnp.array(0, jnp.int32),
        active=(jnp.ones(g.shape[1:], bool) if active0 is None else active0)
        if multi else jnp.asarray(True if active0 is None else active0),
    )

    def cond(st: _CGState):
        live = st.active & (jnp.sqrt(st.rtr) > tol)
        return (jnp.any(live) if multi else live) & (st.it < max_iter)

    def body(st: _CGState):
        run = st.active & (jnp.sqrt(st.rtr) > tol)
        d_run = sel(run, st.d, jnp.zeros_like(st.d))  # frozen cols: no motion
        Hd = hvp(d_run)
        dHd = _cdot(d_run, Hd)
        # Negative curvature or step leaving the region -> go to boundary.
        alpha = st.rtr / jnp.where(dHd > 0, dHd, 1.0)
        s_try = st.s + alpha * d_run
        outside = (_cnorm(s_try) >= delta) | (dHd <= 0)

        # tau >= 0 solving ||s + tau d|| = delta
        sd = _cdot(st.s, d_run)
        dd = _cdot(d_run, d_run)
        ss = _cdot(st.s, st.s)
        rad = jnp.sqrt(jnp.maximum(sd * sd + dd * (delta * delta - ss), 0.0))
        tau = (rad - sd) / jnp.where(dd > 0, dd, 1.0)

        step = jnp.where(outside, tau, alpha)
        s_new = sel(run, st.s + step * d_run, st.s)
        r_new = sel(run, st.r - step * Hd, st.r)
        rtr_new = _cdot(r_new, r_new)
        beta_cg = rtr_new / jnp.where(st.rtr > 0, st.rtr, 1.0)
        d_new = sel(run, r_new + beta_cg * st.d, st.d)
        return _CGState(
            s=s_new, r=r_new, d=d_new, rtr=rtr_new,
            it=st.it + 1,
            active=st.active & ~(run & outside) if multi else ~outside,
        )

    final = jax.lax.while_loop(cond, body, init)
    return final.s, final.r, final.it


class _TronState(NamedTuple):
    beta: jnp.ndarray
    f: jnp.ndarray
    g: jnp.ndarray
    aux: jnp.ndarray
    delta: jnp.ndarray
    it: jnp.ndarray
    n_fg: jnp.ndarray
    n_hd: jnp.ndarray
    gnorm0: jnp.ndarray
    active: jnp.ndarray


def snapshot_of(st) -> TronSnapshot:
    """Host :class:`TronSnapshot` of a live loop state (traced or host)."""
    return TronSnapshot(beta=np.asarray(st.beta), delta=np.asarray(st.delta),
                        gnorm0=np.asarray(st.gnorm0),
                        active=np.asarray(st.active, bool),
                        it=int(st.it), n_fg=int(st.n_fg), n_hd=int(st.n_hd))


def tron(fgrad: Callable, hessd: Callable, beta0: jnp.ndarray,
         cfg: TronConfig = TronConfig(), *,
         state0: TronSnapshot | None = None,
         snapshot_every: int = 0,
         on_snapshot: Callable[[TronSnapshot], None] | None = None
         ) -> TronResult:
    """Minimize f via trust-region Newton-CG. See module docstring.

    ``beta0`` (m,) runs the classic solver; (m, K) runs K independent
    problems in lockstep — one fgrad/hessd call per iteration serves every
    column, each column keeping its own f, trust radius, and convergence.

    ``state0`` resumes from a :class:`TronSnapshot` (beta0 then only fixes
    dtype/shape). ``snapshot_every`` > 0 runs the loop in jitted segments
    of that many outer iterations, calling ``on_snapshot`` with the live
    state between segments — the update rules are identical, only the
    while_loop trip grouping changes. With all three unset the original
    single-``lax.while_loop`` program is emitted unchanged.
    """
    multi = jnp.ndim(beta0) > 1
    sel = (lambda run, new, old: jnp.where(run, new, old)) if multi \
        else (lambda run, new, old: new)

    def cond(st: _TronState):
        live = st.active & (_cnorm(st.g) > cfg.grad_rtol * st.gnorm0)
        return (jnp.any(live) if multi else live) & (st.it < cfg.max_iter)

    def body(st: _TronState):
        gnorm = _cnorm(st.g)
        run = st.active & (gnorm > cfg.grad_rtol * st.gnorm0)
        hvp = lambda d: hessd(st.aux, d)
        s, r, cg_steps = _steihaug_cg(
            st.g, hvp, st.delta, cfg.cg_rtol * gnorm, cfg.cg_max_iter,
            active0=run if multi else None)

        snorm = _cnorm(s)
        gs = _cdot(st.g, s)
        prered = -0.5 * (gs - _cdot(s, r))

        beta_try = st.beta + s          # finished columns have s = 0
        f_new, g_new, aux_new = fgrad(beta_try)
        actred = st.f - f_new

        # liblinear delta-update rules
        denom = f_new - st.f - gs
        alpha = jnp.where(denom <= 0, cfg.sigma3,
                          jnp.maximum(cfg.sigma1, -0.5 * (gs / jnp.where(denom == 0, 1.0, denom))))
        # On the very first iteration, recalibrate delta to the step scale.
        delta = jnp.where(st.it == 0, jnp.minimum(st.delta, snorm), st.delta)
        delta = jnp.where(
            actred < cfg.eta0 * prered,
            jnp.minimum(jnp.maximum(alpha, cfg.sigma1) * snorm, cfg.sigma2 * delta),
            jnp.where(
                actred < cfg.eta1 * prered,
                jnp.maximum(cfg.sigma1 * delta, jnp.minimum(alpha * snorm, cfg.sigma2 * delta)),
                jnp.where(
                    actred < cfg.eta2 * prered,
                    jnp.maximum(cfg.sigma1 * delta, jnp.minimum(alpha * snorm, cfg.sigma3 * delta)),
                    jnp.maximum(delta, jnp.minimum(alpha * snorm, cfg.sigma3 * delta)),
                ),
            ),
        )
        delta = sel(run, delta, st.delta)

        accept = (actred > cfg.eta0 * prered) & run if multi \
            else actred > cfg.eta0 * prered
        beta = jnp.where(accept, beta_try, st.beta)
        f = jnp.where(accept, f_new, st.f)
        g = jnp.where(accept, g_new, st.g)
        aux = jax.tree.map(lambda a, b: jnp.where(accept, a, b), aux_new, st.aux)

        # Numerical stagnation guards (liblinear): stop on non-positive
        # predicted reduction or vanishing |actred|,|prered| relative to |f|.
        feps = jnp.abs(st.f) * 1e-12
        stagnated = (prered <= 0) | (
            (jnp.abs(actred) <= feps) & (jnp.abs(prered) <= feps))
        return _TronState(
            beta=beta, f=f, g=g, aux=aux, delta=delta,
            it=st.it + 1,
            n_fg=st.n_fg + 1,
            n_hd=st.n_hd + cg_steps,
            gnorm0=st.gnorm0,
            active=st.active & ~(run & stagnated) if multi
            else st.active & ~stagnated,
        )

    if state0 is None and snapshot_every <= 0 and on_snapshot is None:
        f0, g0, aux0 = fgrad(beta0)
        gnorm0 = _cnorm(g0)
        init = _TronState(
            beta=beta0, f=f0, g=g0, aux=aux0,
            delta=gnorm0,
            it=jnp.array(0, jnp.int32),
            n_fg=jnp.array(1, jnp.int32),
            n_hd=jnp.array(0, jnp.int32),
            gnorm0=gnorm0,
            active=gnorm0 > 0,
        )
        st = jax.lax.while_loop(cond, body, init)     # the original program
    else:
        # Segmented driver: jit one while_loop whose cond adds a traced
        # iteration cap, run it `snapshot_every` iterations at a time, and
        # hand the host the live state between segments. Crucially the
        # canonical cross-segment state is exactly the TronSnapshot tuple:
        # f/g/aux are re-derived from beta INSIDE the jitted segment (not
        # carried over), so a run resumed from a stored snapshot replays
        # the identical compiled computation the uninterrupted
        # checkpointed run performs at that same boundary — bit-identical
        # trajectories. (A checkpointed run may therefore differ from an
        # un-checkpointed one at float-rounding level: the boundary
        # re-derivation re-rounds f/g/aux every `snapshot_every`
        # iterations. The re-derivations are not counted in n_fg.)
        @jax.jit
        def _segment(beta, delta, gnorm0, active, it, n_fg, n_hd, cap):
            f, g, aux = fgrad(beta)
            st = _TronState(beta=beta, f=f, g=g, aux=aux, delta=delta,
                            it=it, n_fg=n_fg, n_hd=n_hd, gnorm0=gnorm0,
                            active=active)

            def seg_cond(s):
                return cond(s) & (s.it < cap)
            return jax.lax.while_loop(seg_cond, body, st)

        def _run_segment(st, cap: int):
            return _segment(st.beta, st.delta, st.gnorm0, st.active, st.it,
                            st.n_fg, st.n_hd, jnp.asarray(cap, jnp.int32))

        def _host_live(st):
            g = np.asarray(st.g, np.float64)
            gnorm_h = np.sqrt(np.sum(g * g, axis=0)) if multi \
                else np.linalg.norm(g)
            live = np.asarray(st.active) \
                & (gnorm_h > cfg.grad_rtol * np.asarray(st.gnorm0))
            return bool(np.any(live)) and int(st.it) < cfg.max_iter

        if state0 is None:
            f0, g0, aux0 = fgrad(beta0)        # counted: the fresh init eval
            gnorm0 = _cnorm(g0)
            st = _TronState(
                beta=beta0, f=f0, g=g0, aux=aux0,
                delta=gnorm0,
                it=jnp.array(0, jnp.int32),
                n_fg=jnp.array(1, jnp.int32),
                n_hd=jnp.array(0, jnp.int32),
                gnorm0=gnorm0,
                active=gnorm0 > 0,
            )
        else:
            beta_r = jnp.asarray(np.asarray(state0.beta),
                                 jnp.asarray(beta0).dtype)
            rt = beta_r.dtype
            st0 = _TronState(
                beta=beta_r, f=None, g=None, aux=None,  # rebuilt in-segment
                delta=jnp.asarray(np.asarray(state0.delta), rt),
                it=jnp.array(int(state0.it), jnp.int32),
                n_fg=jnp.array(int(state0.n_fg), jnp.int32),
                n_hd=jnp.array(int(state0.n_hd), jnp.int32),
                gnorm0=jnp.asarray(np.asarray(state0.gnorm0), rt),
                active=jnp.asarray(np.asarray(state0.active, bool)) if multi
                else jnp.asarray(bool(state0.active)),
            )
            # Zero-trip segment: rebuild f/g/aux from beta through the SAME
            # jitted program the loop uses, so even the between-segment
            # convergence decision sees the exact bits the uninterrupted
            # run saw at this boundary. Not counted in n_fg.
            st = _run_segment(st0, int(st0.it))

        every = snapshot_every if snapshot_every > 0 else cfg.max_iter
        while _host_live(st):
            cap = min(cfg.max_iter, int(st.it) + every)
            st = _run_segment(st, cap)
            if on_snapshot is not None and snapshot_every > 0:
                on_snapshot(snapshot_of(st))
    gnorm = _cnorm(st.g)
    return TronResult(
        beta=st.beta, f=st.f, gnorm=gnorm,
        n_iter=st.it, n_fg=st.n_fg, n_hd=st.n_hd,
        converged=gnorm <= cfg.grad_rtol * st.gnorm0,
    )


# --------------------------------------------------------------- host driver
def _cdot_np(a, b):
    return np.sum(a * b, axis=0)


def _cnorm_np(a):
    return np.sqrt(np.sum(a * a, axis=0))


def _steihaug_cg_host(g, hvp: Callable, delta, tol, max_iter: int,
                      active0=None):
    """Host mirror of :func:`_steihaug_cg`: same trcg semantics, numpy
    vectors, eager ``hvp`` calls (each one may stream the dataset).

    Column-batched like the traced version: (m, K) g runs K problems per
    hvp call with per-column freeze masks; (m,) reduces to the classic
    scalar loop (masks are 0-d and always true while the loop runs). All
    m-vector state and scalar algebra run in float64 on the host, matching
    the ``float()`` precision of the pre-batched implementation; only the
    hvp argument drops to the problem dtype.
    """
    dtype = g.dtype
    g = g.astype(np.float64)
    s = np.zeros_like(g)
    r = -g
    d = -g
    rtr = _cdot_np(g, g)
    active = np.ones(g.shape[1:], bool) if active0 is None \
        else np.asarray(active0) & np.ones(g.shape[1:], bool)
    it = 0
    while np.any(active & (np.sqrt(rtr) > tol)) and it < max_iter:
        run = active & (np.sqrt(rtr) > tol)
        d_run = np.where(run, d, 0.0)
        Hd = np.asarray(hvp(d_run.astype(dtype)), np.float64)
        dHd = _cdot_np(d_run, Hd)
        alpha = rtr / np.where(dHd > 0, dHd, 1.0)
        s_try = s + alpha * d_run
        outside = (_cnorm_np(s_try) >= delta) | (dHd <= 0)

        sd = _cdot_np(s, d_run)
        dd = _cdot_np(d_run, d_run)
        ss = _cdot_np(s, s)
        rad = np.sqrt(np.maximum(sd * sd + dd * (delta * delta - ss), 0.0))
        tau = (rad - sd) / np.where(dd > 0, dd, 1.0)

        step = np.where(outside, tau, alpha)
        s = np.where(run, s + step * d_run, s)
        r = np.where(run, r - step * Hd, r)
        rtr_new = _cdot_np(r, r)
        beta_cg = rtr_new / np.where(rtr > 0, rtr, 1.0)
        d = np.where(run, r + beta_cg * d, d)
        rtr = rtr_new
        active = active & ~(run & outside)
        it += 1
    return s, r, it


def tron_host(fgrad: Callable, hessd: Callable, beta0,
              cfg: TronConfig = TronConfig(), *,
              state0: TronSnapshot | None = None,
              snapshot_every: int = 0,
              on_snapshot: Callable[[TronSnapshot], None] | None = None
              ) -> TronResult:
    """Eager trust-region Newton-CG with the exact update rules of
    :func:`tron`, for accumulator-style closures.

    ``fgrad``/``hessd`` may be arbitrary Python callables — in the
    ``stream`` plan each call loops over dataset chunks, accumulating the
    m-vector on the host while per-chunk math runs jitted on the mesh.
    ``aux`` is treated as a pytree of per-column arrays (the stream plan
    keeps the Gauss-Newton diagonal as one row-sharded array per chunk).

    Column-batched like :func:`tron` when ``beta0`` is (m, K): every
    streamed fgrad/hessd pass over the dataset then serves all K columns.

    ``state0`` resumes from a :class:`TronSnapshot`; f/g/aux are rebuilt
    by one (uncounted) ``fgrad`` call, so a resumed solve walks the exact
    trajectory of the uninterrupted one. ``snapshot_every`` > 0 calls
    ``on_snapshot`` with the live state every that many outer iterations.
    """
    beta = np.asarray(beta0)
    dtype = beta.dtype
    cols = beta.shape[1:]
    if state0 is not None:
        beta = np.asarray(state0.beta, dtype)
    f, g, aux = fgrad(beta)
    f = np.asarray(f, np.float64)
    g = np.asarray(g, dtype)
    if state0 is None:
        gnorm0 = _cnorm_np(g.astype(np.float64))
        delta = np.asarray(gnorm0).copy()
        it, n_fg, n_hd = 0, 1, 0
        active = np.asarray(gnorm0 > 0) & np.ones(cols, bool)
    else:
        gnorm0 = np.asarray(state0.gnorm0, np.float64)
        delta = np.asarray(state0.delta, np.float64).copy()
        it, n_fg, n_hd = int(state0.it), int(state0.n_fg), int(state0.n_hd)
        active = np.asarray(state0.active, bool) & np.ones(cols, bool)
    while np.any(active & (_cnorm_np(g) > cfg.grad_rtol * gnorm0)) \
            and it < cfg.max_iter:
        gnorm = _cnorm_np(g.astype(np.float64))
        run = active & (gnorm > cfg.grad_rtol * gnorm0)
        s, r, cg_steps = _steihaug_cg_host(
            g, lambda d: hessd(aux, d), delta, cfg.cg_rtol * gnorm,
            cfg.cg_max_iter, active0=run)
        n_hd += cg_steps

        snorm = _cnorm_np(s.astype(np.float64))
        gs = _cdot_np(g.astype(np.float64), s)
        prered = -0.5 * (gs - _cdot_np(s.astype(np.float64), r))

        beta_try = (beta + s).astype(dtype)
        f_new, g_new, aux_new = fgrad(beta_try)
        f_new = np.asarray(f_new, np.float64)
        g_new = np.asarray(g_new, dtype)
        n_fg += 1
        actred = f - f_new

        denom = f_new - f - gs
        alpha = np.where(denom <= 0, cfg.sigma3,
                         np.maximum(cfg.sigma1,
                                    -0.5 * (gs / np.where(denom == 0, 1.0,
                                                          denom))))
        if it == 0:
            delta = np.minimum(delta, snorm)
        delta_new = np.where(
            actred < cfg.eta0 * prered,
            np.minimum(np.maximum(alpha, cfg.sigma1) * snorm,
                       cfg.sigma2 * delta),
            np.where(
                actred < cfg.eta1 * prered,
                np.maximum(cfg.sigma1 * delta,
                           np.minimum(alpha * snorm, cfg.sigma2 * delta)),
                np.where(
                    actred < cfg.eta2 * prered,
                    np.maximum(cfg.sigma1 * delta,
                               np.minimum(alpha * snorm, cfg.sigma3 * delta)),
                    np.maximum(delta,
                               np.minimum(alpha * snorm, cfg.sigma3 * delta)),
                ),
            ),
        )
        delta = np.where(run, delta_new, delta)

        accept = (actred > cfg.eta0 * prered) & run
        beta = np.where(accept, beta_try, beta).astype(dtype)
        f = np.where(accept, f_new, f)
        g = np.where(accept, g_new, g).astype(dtype)
        # jnp.where: stream aux chunks are sharded device arrays — merging
        # on host would drag them off-device and re-transfer every Hd call
        aux = jax.tree.map(lambda a, b: jnp.where(accept, a, b), aux_new, aux)
        it += 1

        feps = np.abs(f) * 1e-12
        stagnated = (prered <= 0) | (
            (np.abs(actred) <= feps) & (np.abs(prered) <= feps))
        active = active & ~(run & stagnated)

        if on_snapshot is not None and snapshot_every > 0 \
                and it % snapshot_every == 0:
            on_snapshot(TronSnapshot(
                beta=beta.copy(), delta=np.asarray(delta).copy(),
                gnorm0=np.asarray(gnorm0).copy(),
                active=np.asarray(active, bool).copy(),
                it=it, n_fg=n_fg, n_hd=n_hd))

    gnorm = _cnorm_np(g.astype(np.float64))
    return TronResult(
        beta=jnp.asarray(beta, dtype),
        f=jnp.asarray(np.asarray(f), jnp.float32),
        gnorm=jnp.asarray(np.asarray(gnorm), jnp.float32),
        n_iter=jnp.asarray(it, jnp.int32),
        n_fg=jnp.asarray(n_fg, jnp.int32),
        n_hd=jnp.asarray(n_hd, jnp.int32),
        converged=jnp.asarray(np.asarray(gnorm <= cfg.grad_rtol * gnorm0)),
    )
