"""Random Fourier Features (Rahimi & Recht 2007) — the paper's §5 Discussion
explicitly flags RFF as the natural alternative to Nystrom basis selection.

For the Gaussian kernel k(x,z) = exp(-||x-z||^2 / 2 sigma^2):
    phi(x) = sqrt(2/m) cos(x Omega / sigma + b),  Omega ~ N(0, I),
    k(x,z) ~ phi(x) . phi(z)   (unbiased)

Training then IS a linear machine on phi(X) — formulation (3)'s form with
A = phi(X) but no eigendecomposition needed (the paper's O(m^3) objection
to (3) does not apply to RFF). The classic empirical trade-off (validated
in benchmarks/rff_vs_nystrom.py): the data-DEPENDENT Nystrom basis reaches
a given accuracy with fewer features than the data-independent RFF draw
(Yang et al., NeurIPS 2012), so formulation (4) keeps its edge whenever m
is the budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.losses import Loss
from repro.core.tron import TronConfig, TronResult


@dataclasses.dataclass(frozen=True)
class RFFBasis:
    omega: jnp.ndarray     # (d, m) frequencies
    phase: jnp.ndarray     # (m,)
    sigma: float

    @property
    def m(self) -> int:
        return self.omega.shape[1]


def sample_rff(key: jax.Array, d: int, m: int, sigma: float) -> RFFBasis:
    k1, k2 = jax.random.split(key)
    omega = jax.random.normal(k1, (d, m))
    phase = jax.random.uniform(k2, (m,), maxval=2.0 * jnp.pi)
    return RFFBasis(omega=omega, phase=phase, sigma=sigma)


def rff_features(X: jnp.ndarray, basis: RFFBasis) -> jnp.ndarray:
    proj = X @ basis.omega / basis.sigma + basis.phase
    return jnp.sqrt(2.0 / basis.m) * jnp.cos(proj)


@dataclasses.dataclass
class RFFMachine:
    basis: RFFBasis
    w: jnp.ndarray
    stats: TronResult

    def decision(self, X):
        return rff_features(X, self.basis) @ self.w

    def accuracy(self, X, y) -> float:
        return float(jnp.mean(jnp.sign(self.decision(X)) == y))


def solve_rff(key: jax.Array, X, y, m: int, *, lam: float, sigma: float,
              loss: Loss | str = "squared_hinge",
              cfg: TronConfig = TronConfig()) -> RFFMachine:
    """Deprecated. The exact replacement is::

        from repro.api import KernelMachine, MachineConfig
        from repro.core import KernelSpec
        km = KernelMachine(MachineConfig(
            kernel=KernelSpec("gaussian", sigma=sigma), loss=loss, lam=lam,
            solver="rff", rff_features=m, tron=cfg))
        km.fit(X, y, key=key)              # km.state_["beta"], km.result_

    Thin shim — samples the basis from ``key`` exactly as before, then runs
    the unified estimator (formulation (4) with C = phi(X), W = I).
    """
    import warnings

    from repro.api import KernelMachine, MachineConfig  # lazy: avoid cycle
    from repro.core.nystrom import KernelSpec
    from repro.core.solver import loss_name

    warnings.warn(
        "repro.core.rff.solve_rff is deprecated; use "
        "KernelMachine(MachineConfig(solver='rff', rff_features=m, ...))"
        ".fit(X, y, key=key)", DeprecationWarning, stacklevel=2)
    config = MachineConfig(
        kernel=KernelSpec("gaussian", sigma=sigma), loss=loss_name(loss),
        lam=lam, solver="rff", plan="local", tron=cfg, rff_features=m)
    basis = sample_rff(key, X.shape[1], m, sigma)
    km = KernelMachine(config).fit(X, y, basis)
    return RFFMachine(basis=basis, w=km.state_["beta"], stats=km.result_.tron)
