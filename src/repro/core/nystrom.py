"""Nystrom kernel-matrix pieces (paper §2.1).

``C[i,k] = k(x_i, xb_k)`` (n x m) and ``W[k,l] = k(xb_k, xb_l)`` (m x m).
The gram computation is pluggable: ``backend='jnp'`` is the reference path;
``backend='pallas'`` routes to the tiled TPU kernel in
``repro.kernels.ops`` (validated against the jnp oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Kernel function spec. Gaussian is the paper's main kernel."""

    kind: str = "gaussian"  # gaussian | linear
    sigma: float = 1.0

    def __post_init__(self):
        if self.kind not in ("gaussian", "linear"):
            raise ValueError(f"unknown kernel kind {self.kind!r}")


def sqdist(x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared distances ||x_i - z_k||^2, (n, m)."""
    xx = jnp.sum(x * x, axis=-1, keepdims=True)          # (n, 1)
    zz = jnp.sum(z * z, axis=-1, keepdims=True).T        # (1, m)
    xz = x @ z.T                                         # (n, m)
    return jnp.maximum(xx + zz - 2.0 * xz, 0.0)


def gram(x: jnp.ndarray, z: jnp.ndarray, kernel: KernelSpec,
         backend: str = "jnp", policy=None) -> jnp.ndarray:
    """Kernel block k(x_i, z_k) with the given backend.

    ``policy`` (name / DtypePolicy / None) selects the compute/accumulate
    dtypes; None is the fp32 default and leaves this function exactly as it
    was before policies existed (including the jnp expression tree)."""
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.gram(x, z, kind=kernel.kind, sigma=kernel.sigma,
                         policy=policy)
    if policy is not None:
        from repro.kernels.policy import get_policy
        pol = get_policy(policy)
        if pol.compute != "float32":
            from repro.kernels.ops import gram_chunk_policy
            return gram_chunk_policy(x, z, kind=kernel.kind,
                                     sigma=kernel.sigma,
                                     pol=pol).astype(pol.accum_dtype)
    if kernel.kind == "linear":
        return x @ z.T
    return jnp.exp(-sqdist(x, z) / (2.0 * kernel.sigma ** 2))


def build_C(x, basis, kernel: KernelSpec, backend: str = "jnp", policy=None):
    return gram(x, basis, kernel, backend, policy)


def build_W(basis, kernel: KernelSpec, backend: str = "jnp", policy=None):
    return gram(basis, basis, kernel, backend, policy)


def nystrom_approx_kernel(x, basis, kernel: KernelSpec,
                          jitter: float = 1e-6) -> jnp.ndarray:
    """K_tilde = C W^+ C^T (paper eq. 2) — reference only, O(n^2) memory.

    Used by tests to check approximation quality; the training path never
    forms this (that is the point of formulation (4)).
    """
    C = build_C(x, basis, kernel)
    W = build_W(basis, kernel)
    m = W.shape[0]
    Winv = jnp.linalg.pinv(W + jitter * jnp.eye(m, dtype=W.dtype))
    return C @ Winv @ C.T


def predict(x, basis, beta, kernel: KernelSpec, backend: str = "jnp"):
    """Classifier output o(x) = sum_k beta_k k(x, xb_k)."""
    return build_C(x, basis, kernel, backend) @ beta
