"""Fused on-the-fly kernel matvec (kmvp) Pallas kernels.

The paper (§3.1) notes that when the C row-block exceeds node memory,
kernel elements must be recomputed on the fly ('kernel caching ideas').
The TPU-native version of that idea is a FUSION: compute each (bn, bm)
gram tile in VMEM and immediately contract it against the vector, so C
never exists in HBM at all:

    kmvp_fwd : o = C(x, z) @ beta        (TRON's  C beta)
    kmvp_t   : g = C(x, z)^T @ v         (TRON's  C^T D r)

HBM traffic drops from O(n m) (read a materialized C per matvec) to
O((n + m) d / bd') per call — arithmetic intensity rises by ~min(bn, bm),
moving the op from memory-bound to compute-bound (see EXPERIMENTS.md §Perf).

Grid layouts (sequential TPU grid => safe output accumulation):
    fwd: (i over n-blocks, j over m-blocks, k over d-blocks), o[i] += E_ij b_j
    t  : (j over m-blocks, i over n-blocks, k over d-blocks), g[j] += E_ij^T v_i
Both keep an (bn, bm) f32 VMEM scratch for the squared-distance accumulation
over k, applying exp once on the last k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tile(x_ref, z_ref, acc_ref, k, nk, kind, sigma):
    """Accumulate the gram tile over d-blocks; return E on the last step."""
    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    xz = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if kind == "linear":
        acc_ref[...] += xz
    else:
        xx = jnp.sum(x * x, axis=1, keepdims=True)
        zz = jnp.sum(z * z, axis=1, keepdims=True).T
        acc_ref[...] += xx + zz - 2.0 * xz


def _finish_tile(acc_ref, kind, sigma):
    acc = acc_ref[...]
    if kind == "linear":
        return acc
    return jnp.exp(-jnp.maximum(acc, 0.0) / (2.0 * sigma ** 2))


def _kmvp_fwd_kernel(x_ref, z_ref, b_ref, o_ref, acc_ref, *, kind, sigma):
    j, k = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when((j == 0) & (k == 0))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _tile(x_ref, z_ref, acc_ref, k, nk, kind, sigma)

    @pl.when(k == nk - 1)
    def _contract():
        E = _finish_tile(acc_ref, kind, sigma)                 # (bn, bm)
        o_ref[...] += E @ b_ref[...].astype(jnp.float32)       # (bn, 1)


def _kmvp_t_kernel(x_ref, z_ref, v_ref, g_ref, acc_ref, *, kind, sigma):
    i, k = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when((i == 0) & (k == 0))
    def _init_out():
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _tile(x_ref, z_ref, acc_ref, k, nk, kind, sigma)

    @pl.when(k == nk - 1)
    def _contract():
        E = _finish_tile(acc_ref, kind, sigma)                 # (bn, bm)
        g_ref[...] += E.T @ v_ref[...].astype(jnp.float32)     # (bm, 1)


def kmvp_fwd_pallas(x, z, beta, *, kind="gaussian", sigma=1.0,
                    bn=256, bm=256, bd=256, interpret=False):
    """o = C(x, z) @ beta, C never materialized. beta: (m, 1); o: (n, 1)."""
    n, d = x.shape
    m, _ = z.shape
    assert n % bn == 0 and m % bm == 0 and d % bd == 0
    grid = (n // bn, m // bm, d // bd)
    kernel = functools.partial(_kmvp_fwd_kernel, kind=kind, sigma=sigma)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(x, z, beta)


def kmvp_t_pallas(x, z, v, *, kind="gaussian", sigma=1.0,
                  bn=256, bm=256, bd=256, interpret=False):
    """g = C(x, z)^T @ v, C never materialized. v: (n, 1); g: (m, 1)."""
    n, d = x.shape
    m, _ = z.shape
    assert n % bn == 0 and m % bm == 0 and d % bd == 0
    grid = (m // bm, n // bn, d // bd)
    kernel = functools.partial(_kmvp_t_kernel, kind=kind, sigma=sigma)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, i, k: (i, k)),
            pl.BlockSpec((bm, bd), lambda j, i, k: (j, k)),
            pl.BlockSpec((bn, 1), lambda j, i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda j, i, k: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(x, z, v)
