"""Fused on-the-fly kernel matvec (kmvp) Pallas kernels.

The paper (§3.1) notes that when the C row-block exceeds node memory,
kernel elements must be recomputed on the fly ('kernel caching ideas').
The TPU-native version of that idea is a FUSION: compute each (bn, bm)
gram tile in VMEM and immediately contract it against the vector, so C
never exists in HBM at all:

    kmvp_fwd : O = C(x, z) @ B           (TRON's  C beta)
    kmvp_t   : G = C(x, z)^T @ V         (TRON's  C^T D r)

HBM traffic drops from O(n m) (read a materialized C per matvec) to
O((n + m) d / bd') per call — arithmetic intensity rises by ~min(bn, bm),
moving the op from memory-bound to compute-bound (see EXPERIMENTS.md §Perf).

Both kernels take a *block* of right-hand sides: B is (m, k), V is (n, k),
k padded to the 128-lane width by the ops.py wrapper. The contraction per
gram tile is then an MXU-shaped (bn, bm) @ (bm, k) matmul instead of a
matvec, and — the point of the multi-RHS generalization — every k column
shares one gram-tile recomputation: a K-class one-vs-rest f/g/Hd costs one
O(n m d) recompute pass, not K. On the MXU any k <= 128 occupies the same
lanes as k = 1, so the extra columns are close to free.

Grid layouts (sequential TPU grid => safe output accumulation):
    fwd: (i over n-blocks, j over m-blocks, l over d-blocks), O[i] += E_ij B_j
    t  : (j over m-blocks, i over n-blocks, l over d-blocks), G[j] += E_ij^T V_i
Both keep an (bn, bm) f32 VMEM scratch for the squared-distance accumulation
over d-blocks, applying exp once on the last step. The k axis is never
blocked: each RHS block rides whole in VMEM (k is small — classes, not
examples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tile(x_ref, z_ref, acc_ref, k, nk, kind, sigma,
          compute=jnp.float32, accum=jnp.float32):
    """Accumulate the gram tile over d-blocks; return E on the last step.

    ``compute`` is what the MXU multiplies (bf16 under the cheap policy),
    ``accum`` is the ``preferred_element_type`` of the cross-term matmul and
    the dtype the squared norms are summed in — the VMEM scratch holding the
    running distance is always ``accum`` (f32), so only the per-tile
    products are low-precision, never the accumulation over d-blocks.
    """
    x = x_ref[...].astype(compute)
    z = z_ref[...].astype(compute)
    xz = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                             preferred_element_type=accum)
    if kind == "linear":
        acc_ref[...] += xz
    else:
        xa = x.astype(accum)
        za = z.astype(accum)
        xx = jnp.sum(xa * xa, axis=1, keepdims=True)
        zz = jnp.sum(za * za, axis=1, keepdims=True).T
        acc_ref[...] += xx + zz - 2.0 * xz


def _finish_tile(acc_ref, kind, sigma):
    acc = acc_ref[...]
    if kind == "linear":
        return acc
    return jnp.exp(-jnp.maximum(acc, 0.0) / (2.0 * sigma ** 2))


def _kmvp_fwd_kernel(x_ref, z_ref, b_ref, o_ref, acc_ref, *, kind, sigma,
                     compute, accum):
    j, k = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when((j == 0) & (k == 0))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _tile(x_ref, z_ref, acc_ref, k, nk, kind, sigma, compute, accum)

    @pl.when(k == nk - 1)
    def _contract():
        E = _finish_tile(acc_ref, kind, sigma)                 # (bn, bm)
        if compute == jnp.float32:
            # fp32 policy keeps the exact pre-policy expression (bitwise).
            o_ref[...] += E @ b_ref[...].astype(jnp.float32)   # (bn, k)
        else:
            # Re-cast the finished tile to compute so the RHS contraction
            # also runs on the cheap MXU path; accumulate at accum.
            o_ref[...] += jax.lax.dot_general(
                E.astype(compute), b_ref[...].astype(compute),
                (((1,), (0,)), ((), ())), preferred_element_type=accum)


def _kmvp_t_kernel(x_ref, z_ref, v_ref, g_ref, acc_ref, *, kind, sigma,
                   compute, accum):
    i, k = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when((i == 0) & (k == 0))
    def _init_out():
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _tile(x_ref, z_ref, acc_ref, k, nk, kind, sigma, compute, accum)

    @pl.when(k == nk - 1)
    def _contract():
        E = _finish_tile(acc_ref, kind, sigma)                 # (bn, bm)
        if compute == jnp.float32:
            g_ref[...] += E.T @ v_ref[...].astype(jnp.float32)  # (bm, k)
        else:
            g_ref[...] += jax.lax.dot_general(
                E.astype(compute), v_ref[...].astype(compute),
                (((0,), (0,)), ((), ())), preferred_element_type=accum)


def _check_blocks(name: str, dims) -> None:
    """Readable divisibility errors instead of bare asserts: every dim the
    grid tiles must be a block multiple (the ops.py wrappers pad for you)."""
    for dim, size, block in dims:
        if block <= 0:
            raise ValueError(f"{name}: block b{dim}={block} must be positive")
        if size % block:
            raise ValueError(
                f"{name}: dim {dim}={size} is not divisible by its block "
                f"b{dim}={block}; pad {dim} to a multiple of {block} (the "
                f"repro.kernels.ops wrappers do this automatically)")


def kmvp_fwd_pallas(x, z, beta, *, kind="gaussian", sigma=1.0,
                    bn=256, bm=256, bd=256, interpret=False,
                    compute=jnp.float32, accum=jnp.float32):
    """O = C(x, z) @ B, C never materialized. B: (m, k); O: (n, k).

    All k right-hand-side columns share each (bn, bm) gram tile — the
    recomputation cost is paid once per tile, not once per column.
    ``compute``/``accum`` select the tile-matmul and accumulation dtypes
    (see ``repro.kernels.policy``); the output is always ``accum`` f32."""
    n, d = x.shape
    m, _ = z.shape
    k = beta.shape[1]
    _check_blocks("kmvp_fwd_pallas", [("n", n, bn), ("m", m, bm),
                                      ("d", d, bd)])
    grid = (n // bn, m // bm, d // bd)
    kernel = functools.partial(_kmvp_fwd_kernel, kind=kind, sigma=sigma,
                               compute=jnp.dtype(compute),
                               accum=jnp.dtype(accum))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, l: (i, l)),
            pl.BlockSpec((bm, bd), lambda i, j, l: (j, l)),
            pl.BlockSpec((bm, k), lambda i, j, l: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i, j, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(x, z, beta)


def kmvp_t_pallas(x, z, v, *, kind="gaussian", sigma=1.0,
                  bn=256, bm=256, bd=256, interpret=False,
                  compute=jnp.float32, accum=jnp.float32):
    """G = C(x, z)^T @ V, C never materialized. V: (n, k); G: (m, k).

    Adjoint of :func:`kmvp_fwd_pallas` over the same implicit C; the k
    columns likewise share every gram-tile recomputation."""
    n, d = x.shape
    m, _ = z.shape
    k = v.shape[1]
    _check_blocks("kmvp_t_pallas", [("n", n, bn), ("m", m, bm),
                                    ("d", d, bd)])
    grid = (m // bm, n // bn, d // bd)
    kernel = functools.partial(_kmvp_t_kernel, kind=kind, sigma=sigma,
                               compute=jnp.dtype(compute),
                               accum=jnp.dtype(accum))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda j, i, l: (i, l)),
            pl.BlockSpec((bm, bd), lambda j, i, l: (j, l)),
            pl.BlockSpec((bn, k), lambda j, i, l: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda j, i, l: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(x, z, v)
