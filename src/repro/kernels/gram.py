"""Tiled Gaussian/linear gram-block Pallas kernel (paper Algorithm 1, step 3).

Kernel computation is the dominant cost for high-dimensional data (paper
Table 4, MNIST8m: step 3 ~ 10x step 4). On TPU the natural formulation is
MXU-friendly: the cross term x z^T is a matmul, so we tile

    grid = (n/bn, m/bm, d/bd)        # d innermost: accumulate sq-distances

with an (bn, bm) f32 VMEM scratch accumulating
``|x|^2 + |z|^2 - 2 x z^T`` over d-blocks, and the transcendental
``exp(-d2 / 2 sigma^2)`` applied once on the last d-step (VPU). Block sizes
keep the working set (bn*bd + bm*bd + bn*bm floats) inside VMEM and the
matmul dims MXU-aligned (multiples of 128 via caller padding).

This is the HBM->VMEM->MXU adaptation of the paper's node-local row-block
computation: one grid row block IS one 'node' share of C.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(x_ref, z_ref, o_ref, acc_ref, *, kind: str, sigma: float,
                 out_dtype, compute=jnp.float32, accum=jnp.float32):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(compute)              # (bn, bd)
    z = z_ref[...].astype(compute)              # (bm, bd)
    xz = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                             preferred_element_type=accum)       # (bn, bm) MXU
    if kind == "linear":
        acc_ref[...] += xz
    else:
        xa = x.astype(accum)
        za = z.astype(accum)
        xx = jnp.sum(xa * xa, axis=1, keepdims=True)             # (bn, 1)
        zz = jnp.sum(za * za, axis=1, keepdims=True).T           # (1, bm)
        acc_ref[...] += xx + zz - 2.0 * xz

    @pl.when(k == nk - 1)
    def _finish():
        acc = acc_ref[...]
        if kind == "linear":
            o_ref[...] = acc.astype(out_dtype)
        else:
            d2 = jnp.maximum(acc, 0.0)
            o_ref[...] = jnp.exp(-d2 / (2.0 * sigma ** 2)).astype(out_dtype)


def gram_pallas(x: jnp.ndarray, z: jnp.ndarray, *, kind: str = "gaussian",
                sigma: float = 1.0, bn: int = 256, bm: int = 256,
                bd: int = 256, out_dtype=jnp.float32,
                interpret: bool = False,
                compute=jnp.float32, accum=jnp.float32) -> jnp.ndarray:
    """C = k(x, z) with explicit VMEM tiling. Shapes must divide the blocks
    (the ops.py wrapper pads/unpads arbitrary shapes). ``compute``/``accum``
    select the cross-term matmul and distance-accumulation dtypes."""
    n, d = x.shape
    m, d2 = z.shape
    assert d == d2, (d, d2)
    assert n % bn == 0 and m % bm == 0 and d % bd == 0, (x.shape, z.shape, (bn, bm, bd))
    grid = (n // bn, m // bm, d // bd)
    kernel = functools.partial(_gram_kernel, kind=kind, sigma=sigma,
                               out_dtype=out_dtype,
                               compute=jnp.dtype(compute),
                               accum=jnp.dtype(accum))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(x, z)
