"""jit'd public wrappers for the Pallas kernels.

Handles arbitrary shapes/dtypes by zero-padding to block multiples (zero
rows/cols are exact no-ops for both the gaussian-distance accumulation and
the matvec contractions), picks VMEM-sane MXU-aligned block sizes, and runs
``interpret=True`` automatically off-TPU so the same call sites work in this
CPU container and on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gram as _gram
from repro.kernels import kmvp as _kmvp
from repro.kernels.policy import DtypePolicy, get_policy


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _sublane(dtype) -> int:
    """Minimum TPU sublane tile for a dtype: 8 rows at 4 bytes, 16 at 2
    (bf16/fp16), 32 at 1 (int8) — the row-padding alignment on hardware."""
    return max(8, 32 // max(jnp.dtype(dtype).itemsize, 1))


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _block(size: int, want: int, align: int, interpret: bool = False) -> int:
    """Largest aligned block <= want that keeps padding small for tiny sizes.

    Off-TPU (``interpret``) there is no tiling constraint, so a tiny input
    uses its exact size as the block: a 1-row input must not round up to a
    full alignment block (8x wasted rows, 128x wasted lanes for the m/d
    dims). On hardware the minimum legal block is one alignment unit, but
    never more than ``want`` even when ``align > want``.
    """
    if size >= want:
        return want
    if interpret:
        return size
    return min(_round_up(size, align), max(want, align))


def _pad_rows(a, to):
    pad = to - a.shape[0]
    return a if pad == 0 else jnp.pad(a, ((0, pad), (0, 0)))


def _pad_cols(a, to):
    pad = to - a.shape[1]
    return a if pad == 0 else jnp.pad(a, ((0, 0), (0, pad)))


def _as_cols(v):
    """RHS as a (p, k) column block plus the flag to undo a 1-D squeeze.

    The kmvp entry points accept a single vector (the historical matvec
    call) or a block of k right-hand sides (multiclass one-vs-rest / CG
    over K columns); everything downstream is uniformly 2-D.
    """
    if v.ndim == 1:
        return v.reshape(-1, 1), True
    return v, False


def _pad_lanes(v, interpret: bool) -> jnp.ndarray:
    """Pad the RHS column count to the 128-lane width on hardware (a k <=
    128 block occupies the same MXU lanes as k = 1, so padded columns are
    free); interpret mode keeps the exact k."""
    k = v.shape[1]
    return v if interpret else _pad_cols(v, _round_up(k, 128))


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret", "policy"))
def gram(x, z, *, kind: str = "gaussian", sigma: float = 1.0,
         bn: int = 256, bm: int = 256, bd: int = 256,
         interpret: bool | None = None, policy=None):
    """C[i,k] = k(x_i, z_k) via the tiled Pallas kernel. Any shapes/dtypes.

    ``policy`` (name or DtypePolicy) selects compute/accum dtypes; the
    default fp32 policy traces exactly the pre-policy jaxpr."""
    if interpret is None:
        interpret = _interpret_default()
    pol = get_policy(policy)
    comp, acc = pol.compute_dtype, pol.accum_dtype
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, _sublane(comp), interpret)
    bm = _block(m, bm, 128, interpret)
    bd = _block(d, bd, 128, interpret)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x.astype(comp), np_), dp_)
    zp = _pad_cols(_pad_rows(z.astype(comp), mp_), dp_)
    out = _gram.gram_pallas(xp, zp, kind=kind, sigma=sigma, bn=bn, bm=bm,
                            bd=bd, interpret=interpret, compute=comp,
                            accum=acc)
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret", "policy"))
def kmvp_fwd(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0,
             bn: int = 256, bm: int = 256, bd: int = 256,
             interpret: bool | None = None, policy=None):
    """o = C(x, z) @ beta with C fused away (never in HBM).

    ``beta`` may be a single (m,) vector or an (m, k) block of right-hand
    sides; the k columns share every gram-tile recomputation, so a K-class
    evaluation costs ~one recompute pass. Returns (n,) or (n, k) to match.
    ``policy`` selects compute/accum dtypes; output is always accum f32.
    """
    if interpret is None:
        interpret = _interpret_default()
    pol = get_policy(policy)
    comp, acc = pol.compute_dtype, pol.accum_dtype
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, _sublane(comp), interpret)
    bm = _block(m, bm, 128, interpret)
    bd = _block(d, bd, 128, interpret)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x.astype(comp), np_), dp_)
    zp = _pad_cols(_pad_rows(z.astype(comp), mp_), dp_)
    b2, squeeze = _as_cols(beta)
    k = b2.shape[1]
    bp = _pad_lanes(_pad_rows(b2, mp_), interpret)  # zero padded basis rows
    out = _kmvp.kmvp_fwd_pallas(xp, zp, bp, kind=kind, sigma=sigma, bn=bn,
                                bm=bm, bd=bd, interpret=interpret,
                                compute=comp, accum=acc)
    return out[:n, 0] if squeeze else out[:n, :k]


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret", "policy"))
def kmvp_t(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0,
           bn: int = 256, bm: int = 256, bd: int = 256,
           interpret: bool | None = None, policy=None):
    """g = C(x, z)^T @ v with C fused away (never in HBM).

    ``v`` may be (n,) or an (n, k) block; returns (m,) or (m, k).
    """
    if interpret is None:
        interpret = _interpret_default()
    pol = get_policy(policy)
    comp, acc = pol.compute_dtype, pol.accum_dtype
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, _sublane(comp), interpret)
    bm = _block(m, bm, 128, interpret)
    bd = _block(d, bd, 128, interpret)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x.astype(comp), np_), dp_)
    zp = _pad_cols(_pad_rows(z.astype(comp), mp_), dp_)
    v2, squeeze = _as_cols(v)
    k = v2.shape[1]
    vp = _pad_lanes(_pad_rows(v2, np_), interpret)  # zero padded example rows
    out = _kmvp.kmvp_t_pallas(xp, zp, vp, kind=kind, sigma=sigma, bn=bn,
                              bm=bm, bd=bd, interpret=interpret,
                              compute=comp, accum=acc)
    return out[:m, 0] if squeeze else out[:m, :k]


# --------------------------------------------------------------------- on-the-
# fly helpers for the sharded plans. These are deliberately *not* jit'd:
# they are called inside shard_map bodies (per-shard shapes are concrete at
# trace time) and inline into the enclosing jit, so the chunk loop stays
# remat-friendly (jax.checkpoint on the chunk body: AD never saves a
# (block_rows x m) gram chunk) and donation of the enclosing buffers works.


def otf_block_rows(n: int, m: int, d: int, budget_bytes: int = 1 << 20,
                   itemsize: int = 4) -> int:
    """Row-chunk size for the jnp on-the-fly fallback, keyed on the
    *per-shard* row count n.

    Two ceilings: the transient (rows, m) gram chunk (``itemsize`` bytes
    per element — 2 under a bf16 policy, doubling the rows per chunk for
    the same budget) stays under ``budget_bytes``, and under ~1/8 of the
    shard's rows (so recomputation never quietly degenerates into
    materializing the full per-shard C block). Floor of 8 rows keeps the
    matmuls sane.
    """
    del d
    by_budget = max(budget_bytes // (itemsize * max(m, 1)), 8)
    by_fraction = _round_up(max(n // 8, 1), 8)
    return int(max(8, min(by_budget, by_fraction, _round_up(n, 8))))


def otf_tiles(n: int, m: int, d: int, k: int = 1,
              vmem_budget: int = 4 << 20) -> tuple[int, int, int]:
    """(bn, bm, bd) Pallas tile sizes keyed on the per-shard n: large shards
    take a taller bn (amortizes re-streaming z across the n-block loop),
    shrunk until the f32 working set (x, z, acc tiles plus the (bm, k) RHS
    and (bn, k) output blocks of the multi-RHS path) fits the budget."""
    interp = _interpret_default()
    kp = k if interp else _round_up(max(k, 1), 128)
    bn = _block(n, 512 if n >= 512 else 256, 8, interp)
    bm = _block(m, 256, 128, interp)
    bd = _block(d, 256, 128, interp)
    while bn > 8 and 4 * (bn * bd + bm * bd + bn * bm
                          + (bn + bm) * kp) > vmem_budget:
        bn = max(8, _round_up(bn // 2, 8))
    return bn, bm, bd


def gram_chunk_policy(c, z, *, kind: str, sigma: float, pol: DtypePolicy):
    """One (rows, m) gram chunk under a dtype policy — the jnp-fallback
    analogue of the Pallas ``_tile``/``_finish_tile`` sequence (satellite:
    the CPU fallback must exercise the *same* cast-compute/accumulate
    order, not silently promote everything to f32).

    The cross-term matmul runs at ``compute`` with ``accum`` accumulation;
    the squared norms and the distance combine at ``accum`` (mirroring the
    f32 VMEM scratch); the *finished* chunk is returned at ``compute`` — so
    the (rows, m) transient the introspect checks see under bf16 really is
    bf16, halving the fallback's peak bytes.
    """
    comp, acc = pol.compute_dtype, pol.accum_dtype
    cc = c.astype(comp)
    zc = z.astype(comp)
    xz = jax.lax.dot_general(cc, zc, (((1,), (1,)), ((), ())),
                             preferred_element_type=acc)
    if kind == "linear":
        return xz.astype(comp)
    ca = cc.astype(acc)
    za = zc.astype(acc)
    xx = jnp.sum(ca * ca, axis=1, keepdims=True)
    zz = jnp.sum(za * za, axis=1, keepdims=True).T
    d2 = jnp.maximum(xx + zz - 2.0 * xz, 0.0)
    return jnp.exp(-d2 / (2.0 * sigma ** 2)).astype(comp)


def kmvp_fwd_chunked(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0,
                     block_rows: int | None = None, policy=None):
    """o = C(x, z) @ beta via row-chunked recomputation (jnp fallback).

    Peak transient is one (block_rows, m) gram chunk — the fallback keeps
    the fused kernels' memory contract on backends without Pallas. ``beta``
    may be (m,) or (m, k); every RHS column contracts against the same
    recomputed gram chunk (one recompute pass per evaluation, not k).
    Under a low-precision ``policy`` the chunk is computed and held at the
    policy's compute dtype with f32 accumulation, exactly like the kernels.
    """
    from repro.kernels import ref
    pol = get_policy(policy)
    n, d = x.shape
    m = z.shape[0]
    b2, squeeze = _as_cols(beta)
    bn = block_rows or otf_block_rows(n, m, d)
    nb = -(-n // bn)
    if pol.compute == "float32":
        xp = _pad_rows(x, nb * bn).reshape(nb, bn, d)

        @jax.checkpoint
        def chunk(c):
            return ref.gram_ref(c, z, kind=kind, sigma=sigma) @ b2.astype(
                jnp.float32)
    else:
        comp, acc = pol.compute_dtype, pol.accum_dtype
        xp = _pad_rows(x.astype(comp), nb * bn).reshape(nb, bn, d)
        bc = b2.astype(comp)

        @jax.checkpoint
        def chunk(c):
            E = gram_chunk_policy(c, z, kind=kind, sigma=sigma, pol=pol)
            return jax.lax.dot_general(E, bc, (((1,), (0,)), ((), ())),
                                       preferred_element_type=acc)

    out = jax.lax.map(chunk, xp).reshape(nb * bn, -1)[:n]
    return out[:, 0] if squeeze else out


def kmvp_t_chunked(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0,
                   block_rows: int | None = None, policy=None):
    """g = C(x, z)^T @ v via row-chunked recomputation (jnp fallback).

    Padded x rows have nonzero gaussian kernel values against z, but their
    v entries are zero-padded, so their contribution to g vanishes exactly.
    ``v`` may be (n,) or (n, k); the accumulator contracts the k columns
    against each gram chunk without ever transposing it. The (k, m)
    accumulator carried across chunks always stays at accum f32.
    """
    from repro.kernels import ref
    pol = get_policy(policy)
    n, d = x.shape
    m = z.shape[0]
    v2, squeeze = _as_cols(v)
    k = v2.shape[1]
    bn = block_rows or otf_block_rows(n, m, d)
    nb = -(-n // bn)
    if pol.compute == "float32":
        xp = _pad_rows(x, nb * bn).reshape(nb, bn, d)
        vp = _pad_rows(v2.astype(jnp.float32), nb * bn).reshape(nb, bn, k)

        @jax.checkpoint
        def contrib(c, vc):
            E = ref.gram_ref(c, z, kind=kind, sigma=sigma)      # (bn, m)
            return jax.lax.dot_general(vc, E, (((0,), (0,)), ((), ())))  # (k, m)
    else:
        comp, acc = pol.compute_dtype, pol.accum_dtype
        xp = _pad_rows(x.astype(comp), nb * bn).reshape(nb, bn, d)
        vp = _pad_rows(v2.astype(comp), nb * bn).reshape(nb, bn, k)

        @jax.checkpoint
        def contrib(c, vc):
            E = gram_chunk_policy(c, z, kind=kind, sigma=sigma, pol=pol)
            return jax.lax.dot_general(vc, E, (((0,), (0,)), ((), ())),
                                       preferred_element_type=acc)

    def body(g, cv):
        return g + contrib(*cv), None

    g, _ = jax.lax.scan(body, jnp.zeros((k, m), jnp.float32), (xp, vp))
    return g[0] if squeeze else g.T


def otf_kmvp_fwd(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0,
                 backend: str = "jnp", block_rows: int | None = None,
                 policy=None):
    """Backend dispatch for o = C(x, z) @ beta with C never in HBM.

    ``pallas`` fuses the gram tile into the matvec in VMEM (tile sizes from
    :func:`otf_tiles`); ``jnp`` recomputes row chunks. Callable inside
    shard_map bodies — x is the per-shard row block there. ``beta`` may be
    (m,) or an (m, k) multi-RHS block on either backend. ``policy`` is
    honored identically by both backends.
    """
    if backend == "pallas":
        k = 1 if beta.ndim == 1 else beta.shape[1]
        bn, bm, bd = otf_tiles(x.shape[0], z.shape[0], x.shape[1], k)
        return kmvp_fwd(x, z, beta, kind=kind, sigma=sigma,
                        bn=bn, bm=bm, bd=bd, policy=policy)
    return kmvp_fwd_chunked(x, z, beta, kind=kind, sigma=sigma,
                            block_rows=block_rows, policy=policy)


def otf_kmvp_t(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0,
               backend: str = "jnp", block_rows: int | None = None,
               policy=None):
    """Backend dispatch for g = C(x, z)^T @ v with C never in HBM.

    ``v`` may be (n,) or an (n, k) multi-RHS block on either backend."""
    if backend == "pallas":
        k = 1 if v.ndim == 1 else v.shape[1]
        bn, bm, bd = otf_tiles(x.shape[0], z.shape[0], x.shape[1], k)
        return kmvp_t(x, z, v, kind=kind, sigma=sigma, bn=bn, bm=bm, bd=bd,
                      policy=policy)
    return kmvp_t_chunked(x, z, v, kind=kind, sigma=sigma,
                          block_rows=block_rows, policy=policy)


@functools.partial(jax.jit, static_argnames=("interpret", "policy"))
def ssd_chunk(Cc, Bc, dA, xdt, *, interpret: bool | None = None, policy=None):
    """Mamba-2 SSD within-chunk term via the Pallas kernel (any shapes with
    Q multiple of 8 recommended; grid = (G, H))."""
    from repro.kernels import ssd as _ssd
    if interpret is None:
        interpret = _interpret_default()
    pol = get_policy(policy)
    return _ssd.ssd_chunk_pallas(Cc, Bc, dA, xdt, interpret=interpret,
                                 compute=pol.compute_dtype,
                                 accum=pol.accum_dtype)
