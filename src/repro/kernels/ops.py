"""jit'd public wrappers for the Pallas kernels.

Handles arbitrary shapes/dtypes by zero-padding to block multiples (zero
rows/cols are exact no-ops for both the gaussian-distance accumulation and
the matvec contractions), picks VMEM-sane MXU-aligned block sizes, and runs
``interpret=True`` automatically off-TPU so the same call sites work in this
CPU container and on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gram as _gram
from repro.kernels import kmvp as _kmvp


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _block(size: int, want: int, align: int) -> int:
    """Largest aligned block <= want that keeps padding small for tiny sizes."""
    if size >= want:
        return want
    return _round_up(size, align)


def _pad_rows(a, to):
    pad = to - a.shape[0]
    return a if pad == 0 else jnp.pad(a, ((0, pad), (0, 0)))


def _pad_cols(a, to):
    pad = to - a.shape[1]
    return a if pad == 0 else jnp.pad(a, ((0, 0), (0, pad)))


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret"))
def gram(x, z, *, kind: str = "gaussian", sigma: float = 1.0,
         bn: int = 256, bm: int = 256, bd: int = 256,
         interpret: bool | None = None):
    """C[i,k] = k(x_i, z_k) via the tiled Pallas kernel. Any shapes/dtypes."""
    if interpret is None:
        interpret = _interpret_default()
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, 8)
    bm = _block(m, bm, 128)
    bd = _block(d, bd, 128)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x, np_), dp_)
    zp = _pad_cols(_pad_rows(z, mp_), dp_)
    out = _gram.gram_pallas(xp, zp, kind=kind, sigma=sigma, bn=bn, bm=bm,
                            bd=bd, interpret=interpret)
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret"))
def kmvp_fwd(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0,
             bn: int = 256, bm: int = 256, bd: int = 256,
             interpret: bool | None = None):
    """o = C(x, z) @ beta with C fused away (never in HBM)."""
    if interpret is None:
        interpret = _interpret_default()
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, 8)
    bm = _block(m, bm, 128)
    bd = _block(d, bd, 128)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x, np_), dp_)
    zp = _pad_cols(_pad_rows(z, mp_), dp_)
    bp = _pad_rows(beta.reshape(-1, 1), mp_)   # zero beta for padded basis rows
    out = _kmvp.kmvp_fwd_pallas(xp, zp, bp, kind=kind, sigma=sigma, bn=bn,
                                bm=bm, bd=bd, interpret=interpret)
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret"))
def kmvp_t(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0,
           bn: int = 256, bm: int = 256, bd: int = 256,
           interpret: bool | None = None):
    """g = C(x, z)^T @ v with C fused away (never in HBM)."""
    if interpret is None:
        interpret = _interpret_default()
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, 8)
    bm = _block(m, bm, 128)
    bd = _block(d, bd, 128)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x, np_), dp_)
    zp = _pad_cols(_pad_rows(z, mp_), dp_)
    vp = _pad_rows(v.reshape(-1, 1), np_)      # zero v for padded example rows
    out = _kmvp.kmvp_t_pallas(xp, zp, vp, kind=kind, sigma=sigma, bn=bn,
                              bm=bm, bd=bd, interpret=interpret)
    return out[:m, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(Cc, Bc, dA, xdt, *, interpret: bool | None = None):
    """Mamba-2 SSD within-chunk term via the Pallas kernel (any shapes with
    Q multiple of 8 recommended; grid = (G, H))."""
    from repro.kernels import ssd as _ssd
    if interpret is None:
        interpret = _interpret_default()
    return _ssd.ssd_chunk_pallas(Cc, Bc, dA, xdt, interpret=interpret)
