"""jit'd public wrappers for the Pallas kernels.

Handles arbitrary shapes/dtypes by zero-padding to block multiples (zero
rows/cols are exact no-ops for both the gaussian-distance accumulation and
the matvec contractions), picks VMEM-sane MXU-aligned block sizes, and runs
``interpret=True`` automatically off-TPU so the same call sites work in this
CPU container and on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gram as _gram
from repro.kernels import kmvp as _kmvp


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _block(size: int, want: int, align: int, interpret: bool = False) -> int:
    """Largest aligned block <= want that keeps padding small for tiny sizes.

    Off-TPU (``interpret``) there is no tiling constraint, so a tiny input
    uses its exact size as the block: a 1-row input must not round up to a
    full alignment block (8x wasted rows, 128x wasted lanes for the m/d
    dims). On hardware the minimum legal block is one alignment unit, but
    never more than ``want`` even when ``align > want``.
    """
    if size >= want:
        return want
    if interpret:
        return size
    return min(_round_up(size, align), max(want, align))


def _pad_rows(a, to):
    pad = to - a.shape[0]
    return a if pad == 0 else jnp.pad(a, ((0, pad), (0, 0)))


def _pad_cols(a, to):
    pad = to - a.shape[1]
    return a if pad == 0 else jnp.pad(a, ((0, 0), (0, pad)))


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret"))
def gram(x, z, *, kind: str = "gaussian", sigma: float = 1.0,
         bn: int = 256, bm: int = 256, bd: int = 256,
         interpret: bool | None = None):
    """C[i,k] = k(x_i, z_k) via the tiled Pallas kernel. Any shapes/dtypes."""
    if interpret is None:
        interpret = _interpret_default()
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, 8, interpret)
    bm = _block(m, bm, 128, interpret)
    bd = _block(d, bd, 128, interpret)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x, np_), dp_)
    zp = _pad_cols(_pad_rows(z, mp_), dp_)
    out = _gram.gram_pallas(xp, zp, kind=kind, sigma=sigma, bn=bn, bm=bm,
                            bd=bd, interpret=interpret)
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret"))
def kmvp_fwd(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0,
             bn: int = 256, bm: int = 256, bd: int = 256,
             interpret: bool | None = None):
    """o = C(x, z) @ beta with C fused away (never in HBM)."""
    if interpret is None:
        interpret = _interpret_default()
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, 8, interpret)
    bm = _block(m, bm, 128, interpret)
    bd = _block(d, bd, 128, interpret)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x, np_), dp_)
    zp = _pad_cols(_pad_rows(z, mp_), dp_)
    bp = _pad_rows(beta.reshape(-1, 1), mp_)   # zero beta for padded basis rows
    out = _kmvp.kmvp_fwd_pallas(xp, zp, bp, kind=kind, sigma=sigma, bn=bn,
                                bm=bm, bd=bd, interpret=interpret)
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("kind", "sigma", "bn", "bm", "bd",
                                             "interpret"))
def kmvp_t(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0,
           bn: int = 256, bm: int = 256, bd: int = 256,
           interpret: bool | None = None):
    """g = C(x, z)^T @ v with C fused away (never in HBM)."""
    if interpret is None:
        interpret = _interpret_default()
    n, d = x.shape
    m = z.shape[0]
    bn = _block(n, bn, 8, interpret)
    bm = _block(m, bm, 128, interpret)
    bd = _block(d, bd, 128, interpret)
    np_, mp_, dp_ = _round_up(n, bn), _round_up(m, bm), _round_up(d, bd)
    xp = _pad_cols(_pad_rows(x, np_), dp_)
    zp = _pad_cols(_pad_rows(z, mp_), dp_)
    vp = _pad_rows(v.reshape(-1, 1), np_)      # zero v for padded example rows
    out = _kmvp.kmvp_t_pallas(xp, zp, vp, kind=kind, sigma=sigma, bn=bn,
                              bm=bm, bd=bd, interpret=interpret)
    return out[:m, 0]


# --------------------------------------------------------------------- on-the-
# fly helpers for the sharded plans. These are deliberately *not* jit'd:
# they are called inside shard_map bodies (per-shard shapes are concrete at
# trace time) and inline into the enclosing jit, so the chunk loop stays
# remat-friendly (jax.checkpoint on the chunk body: AD never saves a
# (block_rows x m) gram chunk) and donation of the enclosing buffers works.


def otf_block_rows(n: int, m: int, d: int, budget_bytes: int = 1 << 20) -> int:
    """Row-chunk size for the jnp on-the-fly fallback, keyed on the
    *per-shard* row count n.

    Two ceilings: the transient (rows, m) f32 gram chunk stays under
    ``budget_bytes``, and under ~1/8 of the shard's rows (so recomputation
    never quietly degenerates into materializing the full per-shard C
    block). Floor of 8 rows keeps the matmuls sane.
    """
    del d
    by_budget = max(budget_bytes // (4 * max(m, 1)), 8)
    by_fraction = _round_up(max(n // 8, 1), 8)
    return int(max(8, min(by_budget, by_fraction, _round_up(n, 8))))


def otf_tiles(n: int, m: int, d: int,
              vmem_budget: int = 4 << 20) -> tuple[int, int, int]:
    """(bn, bm, bd) Pallas tile sizes keyed on the per-shard n: large shards
    take a taller bn (amortizes re-streaming z across the n-block loop),
    shrunk until the f32 working set (x, z, acc tiles) fits the budget."""
    interp = _interpret_default()
    bn = _block(n, 512 if n >= 512 else 256, 8, interp)
    bm = _block(m, 256, 128, interp)
    bd = _block(d, 256, 128, interp)
    while bn > 8 and 4 * (bn * bd + bm * bd + bn * bm) > vmem_budget:
        bn = max(8, _round_up(bn // 2, 8))
    return bn, bm, bd


def kmvp_fwd_chunked(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0,
                     block_rows: int | None = None):
    """o = C(x, z) @ beta via row-chunked recomputation (jnp fallback).

    Peak transient is one (block_rows, m) gram chunk — the fallback keeps
    the fused kernels' memory contract on backends without Pallas.
    """
    from repro.kernels import ref
    n, d = x.shape
    m = z.shape[0]
    bn = block_rows or otf_block_rows(n, m, d)
    nb = -(-n // bn)
    xp = _pad_rows(x, nb * bn).reshape(nb, bn, d)

    @jax.checkpoint
    def chunk(c):
        return ref.gram_ref(c, z, kind=kind, sigma=sigma) @ beta.astype(
            jnp.float32)

    return jax.lax.map(chunk, xp).reshape(-1)[:n]


def kmvp_t_chunked(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0,
                   block_rows: int | None = None):
    """g = C(x, z)^T @ v via row-chunked recomputation (jnp fallback).

    Padded x rows have nonzero gaussian kernel values against z, but their
    v entries are zero-padded, so their contribution to g vanishes exactly.
    """
    from repro.kernels import ref
    n, d = x.shape
    m = z.shape[0]
    bn = block_rows or otf_block_rows(n, m, d)
    nb = -(-n // bn)
    xp = _pad_rows(x, nb * bn).reshape(nb, bn, d)
    vp = jnp.pad(v.astype(jnp.float32), (0, nb * bn - n)).reshape(nb, bn)

    @jax.checkpoint
    def contrib(c, vc):
        return vc @ ref.gram_ref(c, z, kind=kind, sigma=sigma)

    def body(g, cv):
        return g + contrib(*cv), None

    g, _ = jax.lax.scan(body, jnp.zeros((m,), jnp.float32), (xp, vp))
    return g


def otf_kmvp_fwd(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0,
                 backend: str = "jnp", block_rows: int | None = None):
    """Backend dispatch for o = C(x, z) @ beta with C never in HBM.

    ``pallas`` fuses the gram tile into the matvec in VMEM (tile sizes from
    :func:`otf_tiles`); ``jnp`` recomputes row chunks. Callable inside
    shard_map bodies — x is the per-shard row block there.
    """
    if backend == "pallas":
        bn, bm, bd = otf_tiles(x.shape[0], z.shape[0], x.shape[1])
        return kmvp_fwd(x, z, beta, kind=kind, sigma=sigma,
                        bn=bn, bm=bm, bd=bd)
    return kmvp_fwd_chunked(x, z, beta, kind=kind, sigma=sigma,
                            block_rows=block_rows)


def otf_kmvp_t(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0,
               backend: str = "jnp", block_rows: int | None = None):
    """Backend dispatch for g = C(x, z)^T @ v with C never in HBM."""
    if backend == "pallas":
        bn, bm, bd = otf_tiles(x.shape[0], z.shape[0], x.shape[1])
        return kmvp_t(x, z, v, kind=kind, sigma=sigma, bn=bn, bm=bm, bd=bd)
    return kmvp_t_chunked(x, z, v, kind=kind, sigma=sigma,
                          block_rows=block_rows)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(Cc, Bc, dA, xdt, *, interpret: bool | None = None):
    """Mamba-2 SSD within-chunk term via the Pallas kernel (any shapes with
    Q multiple of 8 recommended; grid = (G, H))."""
    from repro.kernels import ssd as _ssd
    if interpret is None:
        interpret = _interpret_default()
    return _ssd.ssd_chunk_pallas(Cc, Bc, dA, xdt, interpret=interpret)
