"""Pallas kernel: Mamba-2 SSD within-chunk (quadratic) term.

One grid cell computes, for a single (sequence-chunk, head) pair:

    scores = C_c B_c^T                      (Q x Q, MXU)
    L      = exp(segsum(dA))  (lower-tri)   (Q x Q, VPU)
    y      = (scores * L) @ (dt * x)        (Q x P, MXU)

The cumulative-sum for the decay matrix is computed as a lower-triangular
ones matmul (MXU-friendly; no serial scan in-kernel). VMEM working set per
cell at (Q=256, N=128, P=64): ~0.8 MB. This is the compute hot spot of the
ssm/hybrid prefill shapes (mamba2 x prefill_32k runs 128 such chunks per
layer per sequence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_chunk_kernel(c_ref, b_ref, da_ref, xdt_ref, o_ref, *,
                      compute=jnp.float32, accum=jnp.float32):
    # blocks: c/b (1, Q, N); da (1, Q); xdt/o (1, Q, P)
    C = c_ref[0].astype(compute)                         # (Q, N)
    B = b_ref[0].astype(compute)                         # (Q, N)
    dA = da_ref[0, 0].astype(accum)                      # (Q,)
    X = xdt_ref[0, 0].astype(compute)                    # (Q, P)
    Q = C.shape[0]

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=accum)  # MXU
    # segsum via triangular-ones matmul: cs[i] = sum_{k<=i} dA[k]
    # The decay matrix stays at accum: exp() of low-precision cumulative
    # sums is where an SSD chunk actually loses accuracy, not the matmuls.
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = (ii >= jj).astype(accum)
    cs = tril @ dA[:, None]                              # (Q, 1) inclusive
    diff = cs - cs.T                                     # cs_i - cs_j
    # segsum semantics: sum_{j<k<=i} dA_k = cs_i - cs_j (both inclusive)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    if compute == jnp.float32:
        y = jax.lax.dot_general(scores * L, X, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    else:
        y = jax.lax.dot_general((scores * L).astype(compute), X,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=accum)
    o_ref[0, 0] = y.astype(o_ref.dtype)


def ssd_chunk_pallas(Cc, Bc, dA, xdt, *, interpret: bool = False,
                     compute=jnp.float32, accum=jnp.float32):
    """Within-chunk SSD term, batched over (G, H) grid.

    Cc, Bc: (G, Q, N); dA: (G, H, Q); xdt: (G, H, Q, P) -> y: (G, H, Q, P).
    """
    G, Q, N = Cc.shape
    H = dA.shape[1]
    P = xdt.shape[-1]
    grid = (G, H)
    return pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, compute=jnp.dtype(compute),
                          accum=jnp.dtype(accum)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda g, h: (g, h, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda g, h: (g, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda g, h: (g, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, H, Q, P), jnp.float32),
        interpret=interpret,
    )(Cc, Bc, dA, xdt)
