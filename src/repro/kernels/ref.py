"""Pure-jnp oracles for the Pallas kernels. Ground truth for all sweeps."""
from __future__ import annotations

import jax.numpy as jnp


def gram_ref(x, z, *, kind: str = "gaussian", sigma: float = 1.0):
    """C[i,k] = k(x_i, z_k); f32 accumulate regardless of input dtype."""
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    if kind == "linear":
        return x @ z.T
    xx = jnp.sum(x * x, axis=-1, keepdims=True)
    zz = jnp.sum(z * z, axis=-1, keepdims=True).T
    d2 = jnp.maximum(xx + zz - 2.0 * (x @ z.T), 0.0)
    return jnp.exp(-d2 / (2.0 * sigma ** 2))


def kmvp_ref(x, z, beta, *, kind: str = "gaussian", sigma: float = 1.0):
    """o = C(x, z) @ beta without the caller holding C."""
    return gram_ref(x, z, kind=kind, sigma=sigma) @ beta.astype(jnp.float32)


def kmvp_t_ref(x, z, v, *, kind: str = "gaussian", sigma: float = 1.0):
    """g = C(x, z)^T @ v without the caller holding C."""
    return gram_ref(x, z, kind=kind, sigma=sigma).T @ v.astype(jnp.float32)


def ssd_chunk_ref(Cc, Bc, dA, xdt):
    """Within-chunk SSD oracle. Cc/Bc: (G,Q,N); dA: (G,H,Q); xdt: (G,H,Q,P)."""
    import jax
    Cc = Cc.astype(jnp.float32)
    Bc = Bc.astype(jnp.float32)
    dA = dA.astype(jnp.float32)
    xdt = xdt.astype(jnp.float32)
    Q = Cc.shape[1]
    cs = jnp.cumsum(dA, axis=-1)                         # (G,H,Q) inclusive
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    L = jnp.where(mask, jnp.exp(diff), 0.0)              # (G,H,Q,Q)
    scores = jnp.einsum("gqn,gkn->gqk", Cc, Bc)
    return jnp.einsum("ghqk,ghkp->ghqp", scores[:, None] * L, xdt)
