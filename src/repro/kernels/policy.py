"""Dtype policy for the kernel compute path (ROADMAP item 3).

One frozen, hashable object answers every "which dtype?" question the hot
path asks, so the answer is threaded as *data* from ``MachineConfig`` down
to the Pallas tiles instead of being hardcoded per call site:

    compute — dtype operands are cast to before the tile matmuls (what the
              MXU multiplies: bf16 doubles effective throughput vs fp32 on
              the same math; fp16 is the CPU-fallback analogue).
    accum   — ``preferred_element_type`` of every tile contraction and the
              dtype of the Pallas VMEM distance accumulator. fp32 always:
              low-precision *accumulation* is where kernel machines actually
              lose margins, and the MXU gives fp32 accumulation for free.
    param   — dtype of the optimizer state (beta, g, delta, Hd). Kept fp32
              so TRON's trust-region logic is numerically untouched by the
              compute policy.
    store   — dtype checkpointed arrays are written in (``int8`` means the
              symmetric per-column quantization in ``repro.checkpoint.quant``).

The default policy is all-fp32 and every policied code path is written so
that the fp32 policy traces the *identical* jaxpr as the pre-policy code —
bitwise-unchanged behavior, asserted by tests, not just promised.

Policies are named (``"fp32"``, ``"bf16"``, ``"fp16"``) so they JSON
round-trip through ``MachineConfig`` and checkpoints as plain strings.
Fields are dtype *names* (strings), keeping the dataclass hashable — it
rides through ``jax.jit`` static arguments unchanged.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """What the kernel layer computes, accumulates, optimizes, and stores in.

    All fields are numpy/jax dtype names. ``store`` additionally accepts
    ``"int8"``, which selects quantized checkpointing (see
    ``repro.checkpoint.quant``) rather than a plain array cast.
    """

    compute: str = "float32"
    accum: str = "float32"
    param: str = "float32"
    store: str = "float32"

    def __post_init__(self):
        for field in ("compute", "accum", "param"):
            jnp.dtype(getattr(self, field))       # fail fast on typos
        if self.store != "int8":
            jnp.dtype(self.store)

    # jnp dtypes on demand (the string fields keep the dataclass hashable)
    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute)

    @property
    def accum_dtype(self):
        return jnp.dtype(self.accum)

    @property
    def param_dtype(self):
        return jnp.dtype(self.param)

    @property
    def is_default(self) -> bool:
        """True when every dtype is fp32 — the bitwise-unchanged fast path."""
        return (self.compute == self.accum == self.param == "float32"
                and self.store == "float32")

    def np_compute_dtype(self) -> np.dtype:
        """The compute dtype as a numpy dtype — what request payloads and
        host-side chunk transfers are cast to. bf16 resolves through
        ml_dtypes (shipped with jax), so plain numpy arrays can hold it."""
        return np.dtype(jnp.dtype(self.compute).name)


FP32 = DtypePolicy()
BF16 = DtypePolicy(compute="bfloat16")
FP16 = DtypePolicy(compute="float16")

#: Named policies — the values ``MachineConfig.dtype_policy`` accepts.
POLICIES = {"fp32": FP32, "bf16": BF16, "fp16": FP16}


def get_policy(policy) -> DtypePolicy:
    """Resolve a policy name / DtypePolicy / None (-> fp32 default)."""
    if policy is None:
        return FP32
    if isinstance(policy, DtypePolicy):
        return policy
    if isinstance(policy, str):
        try:
            return POLICIES[policy]
        except KeyError:
            raise ValueError(
                f"unknown dtype policy {policy!r}; registered: "
                f"{sorted(POLICIES)}") from None
    raise TypeError(f"dtype policy must be a name, DtypePolicy, or None; "
                    f"got {type(policy).__name__}")
