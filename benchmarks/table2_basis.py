"""Paper Table 2: K-means vs random basis selection on Covtype-like data.

Claims validated: (a) K-means beats random at small m; (b) the K-means cost
becomes a significant fraction of total time at large m while its accuracy
edge shrinks — the paper's rationale for switching to random at large m.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import Row
from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, kmeans, random_basis
from repro.data import make_dataset


def run(scale: float = 0.01, ms=(16, 512)):
    X, y, Xt, yt, spec = make_dataset("covtype", jax.random.PRNGKey(0),
                                      scale=scale, d_cap=54)
    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=1.2), lam=1.0,
                           tron=TronConfig(max_iter=80))
    rows = []
    edge = {}
    for m in ms:
        # --- random
        t0 = time.perf_counter()
        basis_r = random_basis(jax.random.PRNGKey(1), X, m)
        acc_r = KernelMachine(config).fit(X, y, basis_r).score(Xt, yt)
        t_r = time.perf_counter() - t0
        # --- kmeans (3 Lloyd iterations, like the paper)
        t0 = time.perf_counter()
        centers, _ = kmeans(jax.random.PRNGKey(1), X, m, n_iter=3)
        centers.block_until_ready()
        t_km = time.perf_counter() - t0
        acc_k = KernelMachine(config).fit(X, y, centers).score(Xt, yt)
        t_k = time.perf_counter() - t0
        edge[m] = acc_k - acc_r
        rows.append(Row(f"table2/random_m{m}", t_r * 1e6,
                        f"test_acc={acc_r:.4f};total_s={t_r:.2f}"))
        rows.append(Row(f"table2/kmeans_m{m}", t_k * 1e6,
                        f"test_acc={acc_k:.4f};kmeans_s={t_km:.2f};"
                        f"total_s={t_k:.2f};kmeans_frac={t_km / t_k:.3f}"))
    rows.append(Row("table2/claim_kmeans_helps_small_m", 0.0,
                    f"edge_small={edge[ms[0]]:.4f};edge_large={edge[ms[-1]]:.4f};"
                    f"ok={edge[ms[0]] >= edge[ms[-1]] - 0.02}"))
    return rows
