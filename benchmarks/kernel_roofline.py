"""Roofline for the PAPER's own workload: distributed TRON (Algorithm 1) at
full published scale — MNIST8m (n=8M, d=784) with m up to 51200 basis
points — lowered on the production 16x16 mesh with ShapeDtypeStructs.

Run standalone (sets the 512-device flag before jax import):
  PYTHONPATH=src python -m benchmarks.kernel_roofline

Compares three execution plans per (n, m):
  * shard_map  (faithful Algorithm 1, explicit psums)
  * auto       (XLA SPMD chooses the schedule)
  * otf        (materialize=False — C recomputed per matvec, the paper's
                kernel-caching idea; trades FLOPs for HBM capacity/traffic)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import DistConfig, DistributedNystrom, KernelSpec, TronConfig
from repro.core import compat
from repro.core.compat import make_mesh
from repro.core.tron import tron

RESULTS = Path(__file__).resolve().parent / "results" / "kernel_machine"
PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(")
_DT = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "pred": 1, "f64": 8, "u32": 4}


def _coll_bytes(txt):
    out = {}
    for m in _COLL_RE.finditer(txt):
        b = _DT.get(m.group(1), 4)
        for d in m.group(2).split(","):
            if d.strip():
                b *= int(d)
        out[m.group(3)] = out.get(m.group(3), 0) + b
    return out


def lower_kernel_machine(n, m, d, mode, materialize, mesh, c_dtype=jnp.float32):
    kern = KernelSpec("gaussian", sigma=7.0)
    dc = DistConfig(data_axes=("data",), model_axis="model", mode=mode,
                    materialize=materialize)
    solver = DistributedNystrom(mesh, 8.0, "squared_hinge", kern, dc)
    sh = solver.shardings()
    X = jax.ShapeDtypeStruct((n, d), jnp.float32)
    y = jax.ShapeDtypeStruct((n,), jnp.float32)
    basis = jax.ShapeDtypeStruct((m, d), jnp.float32)
    cfg = TronConfig(max_iter=300)

    if materialize:
        C = jax.ShapeDtypeStruct((n, m), c_dtype)
        W = jax.ShapeDtypeStruct((m, m), c_dtype)

        def step(C, W, y, b0):
            # one TRON iteration's work: f/g + 3 Hd (paper's per-iter mix)
            fgrad, hessd = solver.make_closures(C, W, y)
            f, g, D = fgrad(b0)
            h = hessd(D, g)
            h = hessd(D, h)
            h = hessd(D, h)
            return f, g + h

        with mesh:
            lowered = jax.jit(step, in_shardings=(
                sh["c"], sh["w"], sh["y"], sh["rep"])).lower(
                C, W, y, jax.ShapeDtypeStruct((m,), jnp.float32))
    else:
        def step(X, y, basis, b0):
            fg, hd = solver.make_otf_closures(X, y, basis)
            f, g, D = fg(b0)
            h = hd(D, g)
            h = hd(D, h)
            h = hd(D, h)
            return f, g + h

        with mesh:
            lowered = jax.jit(step, in_shardings=(
                sh["x"], sh["y"], sh["rep"], sh["rep"])).lower(
                X, y, basis, jax.ShapeDtypeStruct((m,), jnp.float32))
    return lowered


def main():
    RESULTS.mkdir(parents=True, exist_ok=True)
    mesh = make_mesh((16, 16), ("data", "model"),
                     devices=jax.devices()[:256])
    n, d = 8_000_000, 784
    print("| n | m | plan | compute_s | memory_s (HLO ub) | stream_s (analytic) | "
          "collective_s | dominant | C bytes/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for m in (10_240, 51_200):
        for plan, mode, mat in (("shard_map", "shard_map", True),
                                ("auto", "auto", True),
                                ("otf", "shard_map", False),
                                ("bf16C", "auto", True)):
            t0 = time.time()
            lowered = lower_kernel_machine(
                n, m, d, mode, mat, mesh,
                c_dtype=jnp.bfloat16 if plan == "bf16C" else jnp.float32)
            compiled = lowered.compile()
            cost = compat.cost_analysis(compiled)
            colls = _coll_bytes(compiled.as_text())
            flops = float(cost.get("flops", 0))
            byts = float(cost.get("bytes accessed", 0))
            cb = float(sum(colls.values()))
            terms = dict(compute_s=flops / PEAK_FLOPS, memory_s=byts / HBM_BW,
                         collective_s=cb / ICI_BW)
            dom = max(terms, key=terms.get)
            c_bytes = n * m * (2 if plan == "bf16C" else 4) / 256 if mat else 0
            # analytic streaming floor for the 8-matvec TRON iteration mix:
            # materialized plans stream C per matvec; OTF streams X + basis
            # (the capacity-free regime of the fused Pallas kmvp)
            if mat:
                stream = 8 * c_bytes / HBM_BW
            else:
                per_dev = (n // 16) * d * 4 + m * d * 4
                stream = 8 * per_dev / HBM_BW
            terms["stream_s"] = stream
            print(f"| {n} | {m} | {plan} | {terms['compute_s']:.3e} | "
                  f"{terms['memory_s']:.3e} | {stream:.3e} | "
                  f"{terms['collective_s']:.3e} | "
                  f"{dom} | {c_bytes / 2**30:.2f} GiB |", flush=True)
            (RESULTS / f"n{n}_m{m}_{plan}.json").write_text(json.dumps(
                {"n": n, "m": m, "plan": plan, "roofline": terms,
                 "dominant": dom, "collectives": colls,
                 "compile_s": round(time.time() - t0, 1)}, indent=2))


if __name__ == "__main__":
    main()
