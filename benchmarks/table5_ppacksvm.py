"""Paper Table 5: our method vs P-packSVM on MNIST8m-like data.

Claim validated: formulation (4)+TRON reaches >= P-packSVM(1 epoch) accuracy
in less wall time (time-to-accuracy), at reduced scale. Communication-round
counts are also compared: O(N_tron) ~ hundreds vs O(n/r) ~ thousands.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.core import ppacksvm as pps
from repro.data import make_dataset


def run(n: int = 32768, m: int = 256):
    # paper regime: n >> m (their MNIST8m run has n/m = 800). P-packSVM's
    # per-epoch kernel work is O(n^2 d); ours is O(n m d) + O(n m N_tron).
    from repro.data import make_classification
    Xa, ya = make_classification(jax.random.PRNGKey(0), n + 2048, 64,
                                 clusters_per_class=20, margin=0.55)
    X, y, Xt, yt = Xa[:n], ya[:n], Xa[n:], ya[n:]
    kern = KernelSpec("gaussian", sigma=4.0)

    config = MachineConfig(kernel=kern, lam=1e-3,
                           tron=TronConfig(max_iter=100),
                           ppack_epochs=1, ppack_size=64, seed=2)

    t0 = time.perf_counter()
    ours = KernelMachine(config).fit(
        X, y, random_basis(jax.random.PRNGKey(1), X, m))
    acc_ours = ours.score(Xt, yt)
    t_ours = time.perf_counter() - t0
    rounds_ours = 5 * ours.result_.n_iter

    t0 = time.perf_counter()
    pp = KernelMachine(config.replace(solver="ppacksvm")).fit(X, y)
    acc_pp = pp.score(Xt, yt)
    t_pp = time.perf_counter() - t0
    res = pp.result_.extras

    return [
        Row("table5/ours", t_ours * 1e6,
            f"test_acc={acc_ours:.4f};total_s={t_ours:.2f};"
            f"comm_rounds={rounds_ours}"),
        Row("table5/ppacksvm_1epoch", t_pp * 1e6,
            f"test_acc={acc_pp:.4f};total_s={t_pp:.2f};"
            f"comm_rounds={res['n_rounds']}"),
        Row("table5/claim_faster_and_better", 0.0,
            f"ok={t_ours < t_pp and acc_ours >= acc_pp - 0.01};"
            f"speedup={t_pp / t_ours:.2f}x"),
    ]
