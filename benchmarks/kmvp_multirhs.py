"""Multi-RHS kmvp amortization + stream chunk-cache transfer benchmark.

Three measurements, one per claim of the multi-RHS/pipelined-I/O PR:

  * kmvp_step — wall-clock of the fused otf kmvp fwd/t pair at growing RHS
    count k on one (n, m, d) problem. The gram recomputation dominates, so
    per-RHS cost should fall ~1/k (each extra column rides the same tiles).
  * multiclass_fit — a K-class one-vs-rest train: K sequential single-RHS
    fits (the pre-multi-RHS recipe) vs ONE column-batched multi-RHS fit on
    the same plan/config. Acceptance: multirhs >= 2x faster at K=8 (jnp
    fallback numbers on CPU; the Pallas path amortizes at least as well
    since k <= 128 columns share MXU lanes).
  * stream_h2d — host->device bytes for one TRON evaluation mix (f/g +
    3xHd) over a shard-dir stream, chunk cache off (PR-3 behavior: every
    call re-transfers the dataset) vs warm (resident chunks: zero bytes).

Appends the repo-root ``BENCH_kmvp.json`` trajectory with --emit-json.

Run:  PYTHONPATH=src python -m benchmarks.kmvp_multirhs [--smoke] [--emit-json]
"""
import argparse
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent

parser = argparse.ArgumentParser()
parser.add_argument("--n", type=int, default=4096)
parser.add_argument("--d", type=int, default=32)
parser.add_argument("--m", type=int, default=256)
parser.add_argument("--ks", type=int, nargs="*", default=[1, 2, 4, 8])
parser.add_argument("--classes", type=int, default=8)
parser.add_argument("--fit-n", type=int, default=2048)
parser.add_argument("--fit-m", type=int, default=128)
parser.add_argument("--max-iter", type=int, default=30)
parser.add_argument("--chunk-rows", type=int, default=512)
parser.add_argument("--smoke", action="store_true",
                    help="smallest sizes (the verify.sh --bench-smoke step)")
parser.add_argument("--emit-json", action="store_true",
                    help="append results to repo-root BENCH_kmvp.json")
parser.add_argument("--out", default=None)
args = parser.parse_args()
if args.smoke:
    args.n, args.d, args.m = 512, 16, 64
    args.ks = [1, 4]
    args.classes, args.fit_n, args.fit_m = 3, 384, 32
    args.max_iter, args.chunk_rows = 5, 128


def _timed(fn, *a, repeats=3):
    fn(*a)                                     # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*a))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_kmvp_step():
    from repro.kernels.ops import otf_kmvp_fwd, otf_kmvp_t
    n, m, d = args.n, args.m, args.d
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    z = jax.random.normal(jax.random.PRNGKey(1), (m, d))
    kw = dict(kind="gaussian", sigma=float(np.sqrt(d)))
    rows = []
    print(f"kmvp step: n={n} m={m} d={d}")
    print("| k | fwd_s | t_s | per-RHS vs k=1 |")
    print("|---|-------|-----|----------------|")
    fwd1 = t1 = None
    for k in args.ks:
        B = jax.random.normal(jax.random.PRNGKey(2), (m, k))
        V = jax.random.normal(jax.random.PRNGKey(3), (n, k))
        fwd = _timed(jax.jit(lambda x, z, B: otf_kmvp_fwd(x, z, B, **kw)),
                     x, z, B)
        t = _timed(jax.jit(lambda x, z, V: otf_kmvp_t(x, z, V, **kw)),
                   x, z, V)
        if fwd1 is None:
            fwd1, t1 = fwd, t
        per_rhs = (fwd + t) / k / (fwd1 + t1)
        rows.append(dict(k=k, fwd_s=round(fwd, 6), t_s=round(t, 6),
                         per_rhs_vs_k1=round(per_rhs, 4)))
        print(f"| {k} | {fwd:.5f} | {t:.5f} | {per_rhs:.3f} |", flush=True)
    return rows


def bench_dtype_sweep():
    """Accuracy-vs-speed per dtype policy on the fused kmvp pair.

    On CPU (interpret-mode Pallas / jnp fallback) the bf16 step time is a
    correctness trajectory, not a speed claim — the MXU throughput win
    needs TPU hardware; max_rel_err vs the fp32 run is meaningful anywhere
    and is what the verify gate bounds."""
    from repro.kernels.ops import otf_kmvp_fwd, otf_kmvp_t
    n, m, d = args.n, args.m, args.d
    k = max(args.ks)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    z = jax.random.normal(jax.random.PRNGKey(1), (m, d))
    B = jax.random.normal(jax.random.PRNGKey(2), (m, k))
    V = jax.random.normal(jax.random.PRNGKey(3), (n, k))
    kw = dict(kind="gaussian", sigma=float(np.sqrt(d)))
    rows = []
    ref_fwd = ref_t = None
    base = None
    print(f"dtype sweep: n={n} m={m} d={d} k={k}")
    print("| policy | fwd_s | t_s | vs fp32 | max_rel_err |")
    print("|--------|-------|-----|---------|-------------|")
    for policy in ("fp32", "bf16", "fp16"):
        fwd_fn = jax.jit(
            lambda x, z, B, p=policy: otf_kmvp_fwd(x, z, B, policy=p, **kw))
        t_fn = jax.jit(
            lambda x, z, V, p=policy: otf_kmvp_t(x, z, V, policy=p, **kw))
        O, G = np.asarray(fwd_fn(x, z, B)), np.asarray(t_fn(x, z, V))
        if ref_fwd is None:
            ref_fwd, ref_t = O, G
        err = max(
            float(np.max(np.abs(O - ref_fwd)) / np.max(np.abs(ref_fwd))),
            float(np.max(np.abs(G - ref_t)) / np.max(np.abs(ref_t))))
        fwd = _timed(fwd_fn, x, z, B)
        t = _timed(t_fn, x, z, V)
        if base is None:
            base = fwd + t
        rows.append(dict(policy=policy, k=k, fwd_s=round(fwd, 6),
                         t_s=round(t, 6),
                         step_vs_fp32=round((fwd + t) / base, 4),
                         max_rel_err=float(err)))
        print(f"| {policy} | {fwd:.5f} | {t:.5f} | "
              f"{(fwd + t) / base:.3f} | {err:.2e} |", flush=True)
    return rows


def bench_multiclass_fit():
    from repro.api import KernelMachine, MachineConfig
    from repro.core import KernelSpec, TronConfig, random_basis
    from repro.data import make_multiclass
    from repro.data.chunks import ovr_targets
    n, d, m, K = args.fit_n, args.d, args.fit_m, args.classes
    X, yi = make_multiclass(jax.random.PRNGKey(0), n, d, K,
                            clusters_per_class=2)
    basis = random_basis(jax.random.PRNGKey(1), X, m)
    cfg = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=2.0,
                        plan="otf_shard",
                        tron=TronConfig(max_iter=args.max_iter,
                                        grad_rtol=1e-5))
    Y = ovr_targets(np.asarray(yi), np.arange(K))

    def fit_sequential():
        for k in range(K):
            KernelMachine(cfg).fit(X, jnp.asarray(Y[:, k]), basis)

    def fit_multirhs():
        KernelMachine(cfg).fit(X, yi, basis)

    # warm both compile caches (all K sequential fits share one executable)
    KernelMachine(cfg.replace(tron=TronConfig(max_iter=1))).fit(
        X, jnp.asarray(Y[:, 0]), basis)
    KernelMachine(cfg.replace(tron=TronConfig(max_iter=1))).fit(X, yi, basis)
    t0 = time.perf_counter()
    fit_sequential()
    seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    fit_multirhs()
    multi = time.perf_counter() - t0
    out = dict(K=K, n=n, m=m, plan="otf_shard",
               sequential_s=round(seq, 4), multirhs_s=round(multi, 4),
               speedup=round(seq / multi, 2))
    print(f"multiclass fit K={K}: sequential {seq:.2f}s vs multi-RHS "
          f"{multi:.2f}s -> {seq / multi:.2f}x", flush=True)
    return out


def bench_stream_h2d():
    from repro.core import KernelSpec
    from repro.core.compat import make_mesh
    from repro.core.distributed import DistConfig, DistributedNystrom
    from repro.data.chunks import MmapChunkSource, save_chunks
    n, d, m, cr = args.n, args.d, args.m, args.chunk_rows
    X = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (n, d)))
    y = np.sign(np.asarray(jax.random.normal(jax.random.PRNGKey(1), (n,))))
    basis = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (m, d)))
    mesh = make_mesh((1,), ("data",))
    solver = DistributedNystrom(
        mesh, 0.5, "squared_hinge", KernelSpec("gaussian", sigma=4.0),
        DistConfig(materialize=False, fused=True))
    out = {}
    with tempfile.TemporaryDirectory() as td:
        save_chunks(td, X, y, rows_per_shard=cr)
        for label, cache in (("cache_off", 0), ("cache_warm", None)):
            src = MmapChunkSource(td, chunk_rows=cr)
            sc = solver.make_stream_closures(src, basis, cache_chunks=cache)
            b0 = np.zeros((m,), np.float32)

            def step():
                f, g, aux = sc.fgrad(b0)
                h = sc.hessd(aux, g)
                h = sc.hessd(aux, h)
                sc.hessd(aux, h)

            step()                                  # compile + fill cache
            before = sc.feeder.h2d_bytes
            t0 = time.perf_counter()
            step()
            dt = time.perf_counter() - t0
            out[label] = dict(
                h2d_bytes_per_step=sc.feeder.h2d_bytes - before,
                step_s=round(dt, 5),
                cache_chunks=sc.feeder.cache_chunks)
            print(f"stream step {label}: "
                  f"{out[label]['h2d_bytes_per_step'] / 2**20:.2f} MiB "
                  f"h2d, {dt:.4f}s", flush=True)
    return out


def main():
    results = dict(kmvp_step=bench_kmvp_step(),
                   dtype_sweep=bench_dtype_sweep(),
                   multiclass_fit=bench_multiclass_fit(),
                   stream_h2d=bench_stream_h2d())
    if args.emit_json:
        from benchmarks.run import append_trajectory
        out = Path(args.out) if args.out else REPO_ROOT / "BENCH_kmvp.json"
        append_trajectory(out, {
            "benchmark": "kmvp_multirhs",
            "run_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "config": {"n": args.n, "d": args.d, "m": args.m,
                       "ks": args.ks, "classes": args.classes,
                       "fit_n": args.fit_n, "fit_m": args.fit_m,
                       "max_iter": args.max_iter,
                       "chunk_rows": args.chunk_rows,
                       "smoke": args.smoke,
                       "backend": jax.default_backend()},
            "results": results})
        print(f"appended {out}")
    ok = results["multiclass_fit"]["speedup"] >= (1.0 if args.smoke else 2.0)
    h2d = results["stream_h2d"]
    ok &= (h2d["cache_warm"]["h2d_bytes_per_step"]
           < h2d["cache_off"]["h2d_bytes_per_step"])
    # dtype policy accuracy bounds (documented in docs/paper_map.md):
    # fp32 is the reference, bf16 input rounding stays well under 5e-2,
    # fp16 under 1e-2 on these unit-scale problems
    errs = {r["policy"]: r["max_rel_err"] for r in results["dtype_sweep"]}
    ok &= errs["fp32"] == 0.0 and errs["bf16"] < 5e-2 and errs["fp16"] < 1e-2
    print(f"acceptance {'OK' if ok else 'FAILED'}: "
          f"speedup={results['multiclass_fit']['speedup']}x, warm h2d "
          f"{h2d['cache_warm']['h2d_bytes_per_step']} < cold "
          f"{h2d['cache_off']['h2d_bytes_per_step']}, dtype errs "
          f"{ {p: f'{e:.1e}' for p, e in errs.items()} }")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
