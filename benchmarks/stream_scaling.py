"""Out-of-core scaling: shard_map vs otf_shard vs stream at growing n.

For each plan at each n this measures, per device:
  * step_s — wall-clock for one TRON-iteration evaluation mix (f/g + 3xHd)
    at this container's reduced CPU scale (relative numbers; absolute
    speed needs TPU). The stream plan is timed over real .npy shards
    written to a temp directory and re-read memory-mapped every
    evaluation — the paper's disk-resident deployment shape.
  * peak_intermediate_bytes — largest array the evaluation materializes
    (jaxpr shape instrumentation, per-shard avals; the quantity that
    OOMs). For stream this is the per-chunk body: bounded by
    chunk_rows x m no matter how large n grows.
  * resident_x_bytes / resident_cw_bytes — what must sit in device memory
    for the whole solve: the X shard (+ C, W shards when materialized)
    for the in-memory plans, a single chunk for stream.

Emits the repo-root ``BENCH_stream.json`` perf-trajectory record (append
semantics: one entry per run, so regressions are visible across PRs).

Run:  PYTHONPATH=src python -m benchmarks.stream_scaling [--devices 4]
"""
import argparse
import os

parser = argparse.ArgumentParser()
parser.add_argument("--devices", type=int, default=4)
parser.add_argument("--d", type=int, default=32)
parser.add_argument("--m", type=int, default=256)
parser.add_argument("--ns", type=int, nargs="*", default=[4096, 16384, 65536])
parser.add_argument("--chunk-rows", type=int, default=4096)
parser.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_stream.json)")
args = parser.parse_args()
# append (not setdefault): a user-set XLA_FLAGS must not silently disable
# the forced device count --devices asked for
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") +
    f" --xla_force_host_platform_device_count={args.devices}").strip()

import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import DistConfig, DistributedNystrom, KernelSpec
from repro.core.compat import make_mesh
from repro.core.introspect import max_intermediate_bytes
from repro.data.chunks import MmapChunkSource, save_chunks

REPO_ROOT = Path(__file__).resolve().parent.parent


def inmem_step(solver, Xs, ys, basis, materialize):
    """f/g + 3 Hd — the paper's per-TRON-iteration evaluation mix."""
    if materialize:
        C, W = solver.precompute(Xs, basis)
        fgrad, hessd = solver.make_closures(C, W, ys)
    else:
        fgrad, hessd = solver.make_fused_closures(Xs, ys, basis)

    def step(b):
        f, g, D = fgrad(b)
        h = hessd(D, g)
        h = hessd(D, h)
        h = hessd(D, h)
        return f, g + h

    return step


def bench_inmem(mesh, kern, X, y, basis, materialize):
    n, d = X.shape
    m = basis.shape[0]
    p = args.devices
    Xs = jax.device_put(X, NamedSharding(mesh, P(("data",), None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(("data",))))
    dc = DistConfig(data_axes=("data",), materialize=materialize,
                    fused=not materialize)
    solver = DistributedNystrom(mesh, 0.5, "squared_hinge", kern, dc)
    step = inmem_step(solver, Xs, ys, basis, materialize)
    b0 = jnp.zeros((m,), jnp.float32)
    with mesh:
        peak = max_intermediate_bytes(step, b0)
        run = jax.jit(step)
        jax.block_until_ready(run(b0))           # compile
        t0 = time.perf_counter()
        jax.block_until_ready(run(b0))
        dt = time.perf_counter() - t0
    resident_cw = ((n // p) * m + (m // p) * m) * 4 if materialize else 0
    return dict(step_s=round(dt, 5), peak_intermediate_bytes=peak,
                resident_x_bytes=(n // p) * d * 4,
                resident_cw_bytes=resident_cw)


def bench_stream(mesh, kern, shard_dir, basis, chunk_rows):
    m = basis.shape[0]
    d = basis.shape[1]
    src = MmapChunkSource(shard_dir, chunk_rows=chunk_rows)
    dc = DistConfig(data_axes=("data",), materialize=False, fused=True)
    solver = DistributedNystrom(mesh, 0.5, "squared_hinge", kern, dc)
    sc = solver.make_stream_closures(src, np.asarray(basis))
    cr = sc.chunk_rows
    b0 = np.zeros((m,), np.float32)

    def step(b):
        f, g, D = sc.fgrad(b)
        h = sc.hessd(D, g)
        h = sc.hessd(D, h)
        h = sc.hessd(D, h)
        return f, g + h

    step(b0)                                     # compile chunk bodies
    t0 = time.perf_counter()
    step(b0)
    dt = time.perf_counter() - t0
    shapes = dict(
        Xc=jax.ShapeDtypeStruct((cr, d), jnp.float32),
        v=jax.ShapeDtypeStruct((cr,), jnp.float32),
        basis=jax.ShapeDtypeStruct((m, d), jnp.float32),
        beta=jax.ShapeDtypeStruct((m,), jnp.float32))
    with mesh:
        peak = max(
            max_intermediate_bytes(sc.fg_chunk, shapes["Xc"], shapes["v"],
                                   shapes["v"], shapes["basis"],
                                   shapes["beta"]),
            max_intermediate_bytes(sc.hd_chunk, shapes["Xc"], shapes["v"],
                                   shapes["basis"], shapes["beta"]))
    return dict(step_s=round(dt, 5), peak_intermediate_bytes=peak,
                resident_x_bytes=(cr // args.devices) * d * 4,
                resident_cw_bytes=0)


def main():
    p, d, m = args.devices, args.d, args.m
    mesh = make_mesh((p,), ("data",))
    kern = KernelSpec("gaussian", sigma=4.0)
    basis = jax.random.normal(jax.random.PRNGKey(2), (m, d))
    results = []
    print(f"d={d} m={m} p={p} chunk_rows={args.chunk_rows}")
    print("| n | plan | step_s | peak intermediate | resident X / dev |")
    print("|---|------|--------|-------------------|------------------|")
    for n in args.ns:
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (n, d))
        y = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (n,)))
        with tempfile.TemporaryDirectory() as td:
            save_chunks(td, np.asarray(X), np.asarray(y),
                        rows_per_shard=args.chunk_rows)
            for plan in ("shard_map", "otf_shard", "stream"):
                if plan == "stream":
                    row = bench_stream(mesh, kern, td, basis, args.chunk_rows)
                else:
                    row = bench_inmem(mesh, kern, X, y, basis,
                                      materialize=plan == "shard_map")
                row.update(n=n, plan=plan)
                results.append(row)
                print(f"| {n} | {plan} | {row['step_s']:.4f} "
                      f"| {row['peak_intermediate_bytes'] / 2**20:.2f} MiB "
                      f"| {row['resident_x_bytes'] / 2**20:.2f} MiB |",
                      flush=True)

    from benchmarks.run import append_trajectory   # one trajectory format
    out = Path(args.out) if args.out else REPO_ROOT / "BENCH_stream.json"
    append_trajectory(out, {
        "benchmark": "stream_scaling", "run_at": time.strftime(
            "%Y-%m-%dT%H:%M:%S"), "config": {
                "devices": p, "d": d, "m": m, "chunk_rows": args.chunk_rows,
                "backend": jax.default_backend()}, "results": results})
    print(f"appended {out}")


if __name__ == "__main__":
    main()
