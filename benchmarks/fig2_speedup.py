"""Paper Fig. 2: parallel speed-up vs node count — measured compute,
modeled communication (this container has one physical core, so wall-clock
multi-node speedup cannot be measured; the paper's own analysis 4.4 is a
latency model, which we reproduce quantitatively).

time(p) = T_load/p + T_kernel/p + T_tron_compute/p + 5N * (C_lat + D * B)

with N TRON outer iterations (5N AllReduce rounds, paper §4.4). Two latency
scenarios: 'hadoop' (C=50 ms, the paper's crude AllReduce) and 'ici'
(C=1 us, TPU psum — the paper's "with effort a lot better implementation").

Claims validated: (a) covtype-like (large N, small local compute) saturates
badly on the hadoop latency; (b) mnist8m-like (kernel-compute dominated) is
near-linear either way; (c) the ICI mapping removes the pathology.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import (Formulation4, KernelSpec, TronConfig, build_C,
                        build_W, get_loss, random_basis, tron)
from repro.data import make_dataset

LAT = {"hadoop": 50e-3, "ici": 1e-6}
BW_PER_BYTE = {"hadoop": 1 / 100e6, "ici": 1 / 50e9}


FULL_N = {"covtype": 522_910, "mnist8m": 8_000_000}


def _measure(ds, sigma, iters, scale, m):
    X, y, _, _, spec = make_dataset(ds, jax.random.PRNGKey(0), scale=scale,
                                    d_cap=784)
    basis = random_basis(jax.random.PRNGKey(1), X, m)
    kern = KernelSpec("gaussian", sigma=sigma)
    t0 = time.perf_counter()
    C = build_C(X, basis, kern); W = build_W(basis, kern)
    jax.block_until_ready((C, W))
    t_kernel = time.perf_counter() - t0
    form = Formulation4(lam=0.01, loss=get_loss("squared_hinge"))
    run_tron = jax.jit(lambda C, W, y, b: tron(
        lambda bb: form.fgrad(C, W, y, bb),
        lambda D, d: form.hessd(C, W, D, d), b,
        TronConfig(max_iter=iters, grad_rtol=1e-7)))
    t0 = time.perf_counter()
    res = run_tron(C, W, y, jnp.zeros((m,), X.dtype))
    res.beta.block_until_ready()
    t_tron = time.perf_counter() - t0
    n_rounds = 5 * int(res.n_iter)          # paper: ~5N AllReduce calls
    payload = m * 4                          # bytes per reduction
    # extrapolate local compute to the FULL dataset size (O(nm) both steps):
    # the paper's regime is full-n compute vs fixed per-round latency.
    factor = FULL_N[ds] / X.shape[0]
    return t_kernel * factor, t_tron * factor, n_rounds, payload


def run(scale: float = 0.003, m: int = 384):
    rows = []
    for ds, sigma, iters in (("covtype", 1.2, 150), ("mnist8m", 12.0, 10)):
        t_kernel, t_tron, n_rounds, payload = _measure(ds, sigma, iters,
                                                       scale, m)
        for scen in ("hadoop", "ici"):
            comm = n_rounds * (LAT[scen] + payload * BW_PER_BYTE[scen])
            t1 = t_kernel + t_tron + comm
            speedups = {}
            for p in (25, 50, 100, 200):
                tp = (t_kernel + t_tron) / p + comm
                speedups[p] = t1 / tp * (1 if p else 1)
            rel = {p: speedups[p] / speedups[25] * 25 for p in speedups}
            rows.append(Row(
                f"fig2/{ds}_{scen}", comm * 1e6,
                f"speedup_vs25@200={speedups[200] / speedups[25]:.2f}x;"
                f"comm_s={comm:.3f};compute_s={t_kernel + t_tron:.3f};"
                f"rounds={n_rounds}"))
        # claims
    rows.append(Row("fig2/claim", 0.0,
                    "covtype saturates under hadoop latency; ici restores "
                    "near-linear scaling (see rows above)"))
    return rows
