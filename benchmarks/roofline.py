"""Roofline report: reads the dry-run JSONs and emits the per-(arch x shape)
three-term table, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS utility ratio,
and the suggested hillclimb targets. Single-pod (16x16) per the brief.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Row

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

# active params per token (MoE: routed top-k + shared only), precomputed from
# the configs; used for MODEL_FLOPS = 6 * N_active * tokens.
def _active_params(arch_cfg, n_params_total):
    c = arch_cfg
    if c.n_experts:
        # subtract the inactive routed expert weights
        per_expert = 3 * c.d_model * c.moe_d_ff
        n_moe_layers = sum(1 for j in range(c.n_layers) if c.is_moe_layer(j))
        inactive = n_moe_layers * per_expert * (c.n_experts - c.top_k)
        return n_params_total - inactive
    return n_params_total


def run(mesh: str = "16x16"):
    from repro.configs import ARCHS
    rows = []
    table = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        cfg = ARCHS[r["arch"]]
        shape_tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                        "decode_32k": 128, "long_500k": 1}[r["shape"]]
        n_active = _active_params(cfg, r["n_params"])
        mult = 6 if r["kind"] == "train" else 2
        model_flops = mult * n_active * shape_tokens / r["n_chips"]
        hlo = r["cost"]["flops_per_device"]
        util = model_flops / hlo if hlo else 0.0
        rl = r["roofline"]
        dom = rl["dominant"]
        total = rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
        frac = rl[dom] / total if total else 0.0
        table.append((r["arch"], r["shape"], rl, dom, util,
                      r["memory"]["peak_estimate_gib"]))
        rows.append(Row(
            f"roofline/{r['arch']}__{r['shape']}", rl[dom] * 1e6,
            f"compute_s={rl['compute_s']:.3e};memory_s={rl['memory_s']:.3e};"
            f"collective_s={rl['collective_s']:.3e};dominant={dom};"
            f"model/hlo_flops={util:.3f};peak_gib={r['memory']['peak_estimate_gib']}"))
    return rows


def print_markdown(mesh: str = "16x16"):
    """Full markdown table for EXPERIMENTS.md §Roofline."""
    from repro.configs import ARCHS
    print(f"| arch | shape | compute_s | memory_s (lb) | collective_s | "
          f"dominant | MODEL/HLO flops | peak GiB/dev (TPU model) | "
          f"(XLA-CPU ub) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        cfg = ARCHS[r["arch"]]
        shape_tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                        "decode_32k": 128, "long_500k": 1}[r["shape"]]
        n_active = _active_params(cfg, r["n_params"])
        mult = 6 if r["kind"] == "train" else 2
        model_flops = mult * n_active * shape_tokens / r["n_chips"]
        hlo = r["cost"]["flops_per_device"]
        util = model_flops / hlo if hlo else 0.0
        rl = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
              f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
              f"{rl['dominant'].replace('_s', '')} | {util:.3f} | "
              f"{r['memory'].get('modeled_peak_gib_tpu', '-')} | "
              f"{r['memory']['peak_estimate_gib']} |")


if __name__ == "__main__":
    import sys
    print_markdown(sys.argv[1] if len(sys.argv) > 1 else "16x16")
