"""Inference scaling: local vs fused vs stream scoring at growing n_test.

The prediction map o(x) = k(x, basis)·β is the same row-partitioned
contraction training evaluates, so each execution plan's decide arm keeps
its training-side memory contract at serving time. For each plan at each
n_test this measures:

  * score_s / rows_per_s — wall-clock for one full margin pass over the
    test set (this container's reduced CPU scale; relative numbers).
    The stream plan is timed over real .npy shards written to a temp
    directory and read back memory-mapped — the scoring shape for test
    sets larger than RAM.
  * peak_intermediate_bytes — largest array the margin evaluation
    materializes (jaxpr shape instrumentation): the dense local arm pays
    the full (n_test, m) test gram; the fused arm stays under the
    per-shard block heuristic; the stream arm is bounded by its per-chunk
    body no matter how large n_test grows.

Emits the repo-root ``BENCH_infer.json`` perf-trajectory record (append
semantics: one entry per run, regressions visible across PRs). ``--smoke``
runs the smallest size only and asserts the memory contracts — the
``scripts/verify.sh --bench-smoke`` step.

Run:  PYTHONPATH=src python -m benchmarks.infer_scaling [--devices 4]
"""
import argparse
import os

parser = argparse.ArgumentParser()
parser.add_argument("--devices", type=int, default=4)
parser.add_argument("--d", type=int, default=32)
parser.add_argument("--m", type=int, default=256)
parser.add_argument("--ns", type=int, nargs="*", default=[4096, 16384, 65536])
parser.add_argument("--chunk-rows", type=int, default=4096)
parser.add_argument("--classes", type=int, default=3,
                    help="K one-vs-rest margin columns (one multi-RHS pass)")
parser.add_argument("--smoke", action="store_true",
                    help="smallest size only + contract asserts "
                         "(the verify.sh --bench-smoke step)")
parser.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_infer.json)")
args = parser.parse_args()
if args.smoke:
    args.ns = [2048]
    args.chunk_rows = 512
# append (not setdefault): a user-set XLA_FLAGS must not silently disable
# the forced device count --devices asked for
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") +
    f" --xla_force_host_platform_device_count={args.devices}").strip()

import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import MachineConfig, StreamConfig
from repro.api.infer import (DecisionSpec, decide_fused, decide_local,
                             make_margin_body, make_stream_decider)
from repro.core import KernelSpec
from repro.core.compat import make_mesh
from repro.core.introspect import max_intermediate_bytes
from repro.core.nystrom import gram
from repro.data.chunks import MmapChunkSource, save_chunks

REPO_ROOT = Path(__file__).resolve().parent.parent


# Each arm is timed the way serving runs it: one jit-compiled decide
# callable (what ServingEndpoint caches per bucket) warmed once, timed on
# its second call — compile time never leaks into the trajectory.

def bench_local(config, spec, X):
    def margins(X):
        return gram(X, spec.basis, spec.kernel, spec.backend) @ spec.beta

    peak = max_intermediate_bytes(margins, X)
    run = jax.jit(lambda X: decide_local(config, None, spec, X))
    jax.block_until_ready(run(X))                # compile
    t0 = time.perf_counter()
    jax.block_until_ready(run(X))
    return time.perf_counter() - t0, peak


def bench_fused(config, mesh, spec, X):
    body = make_margin_body(config, mesh, spec)
    with mesh:
        peak = max_intermediate_bytes(body, X, spec.basis, spec.beta)
    run = jax.jit(lambda X: decide_fused(config, mesh, spec, X))
    jax.block_until_ready(run(X))                # compile
    t0 = time.perf_counter()
    jax.block_until_ready(run(X))
    return time.perf_counter() - t0, peak


def bench_stream(config, mesh, spec, shard_dir):
    src = MmapChunkSource(shard_dir, chunk_rows=args.chunk_rows)
    sd = make_stream_decider(config, mesh, spec, src)
    cr = sd.chunk_rows
    shapes = (jax.ShapeDtypeStruct((cr, args.d), jnp.float32),
              jax.ShapeDtypeStruct(np.shape(spec.basis), jnp.float32),
              jax.ShapeDtypeStruct(np.shape(spec.beta), jnp.float32))
    with mesh:
        peak = max_intermediate_bytes(sd.o_chunk, *shapes)
    for _ in sd.margins():                       # compile + warm page cache
        pass
    t0 = time.perf_counter()                     # second pass: same jitted
    rows = sum(oc.shape[0] for oc in sd.margins())   # o_chunk body, reused
    assert rows == src.n
    return time.perf_counter() - t0, peak


def main():
    p, d, m, k = args.devices, args.d, args.m, args.classes
    mesh = make_mesh((p,), ("data",))
    kern = KernelSpec("gaussian", sigma=4.0)
    config = MachineConfig(kernel=kern, stream=StreamConfig(
        chunk_rows=args.chunk_rows))
    basis = jax.random.normal(jax.random.PRNGKey(2), (m, d))
    beta_shape = (m,) if k <= 1 else (m, k)
    beta = jax.random.normal(jax.random.PRNGKey(3), beta_shape)
    spec = DecisionSpec(map_x=lambda x: x, basis=basis, beta=beta,
                        kernel=kern, backend="jnp")
    results = []
    print(f"d={d} m={m} K={max(k, 1)} p={p} chunk_rows={args.chunk_rows}")
    print("| n_test | plan | score_s | rows/s | peak intermediate |")
    print("|--------|------|---------|--------|-------------------|")
    for n in args.ns:
        X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        y = np.zeros((n,), np.float32)
        with tempfile.TemporaryDirectory() as td:
            save_chunks(td, np.asarray(X), y, rows_per_shard=args.chunk_rows)
            for plan in ("local", "fused", "stream"):
                if plan == "local":
                    dt, peak = bench_local(config, spec, X)
                elif plan == "fused":
                    dt, peak = bench_fused(config, mesh, spec, X)
                else:
                    dt, peak = bench_stream(config, mesh, spec, td)
                row = dict(n_test=n, plan=plan, score_s=round(dt, 5),
                           rows_per_s=round(n / max(dt, 1e-9), 1),
                           peak_intermediate_bytes=peak)
                results.append(row)
                print(f"| {n} | {plan} | {dt:.4f} | {row['rows_per_s']:.0f} "
                      f"| {peak / 2**20:.2f} MiB |", flush=True)

    # ------------------------------------------------- dtype policy sweep
    # Accuracy-vs-speed rows: the local decide arm under each policy on the
    # smallest n, plus checkpoint bytes fp32 vs int8-quantized. CPU step
    # times are correctness trajectory; max_rel_err holds anywhere.
    n0 = args.ns[0]
    Xp = jax.random.normal(jax.random.PRNGKey(0), (n0, d))
    print("| n_test | plan | score_s | rows/s | max_rel_err |")
    print("|--------|------|---------|--------|-------------|")
    ref_pol = None
    for policy in ("fp32", "bf16", "fp16"):
        pspec = spec._replace(policy=policy)
        run = jax.jit(lambda X, s=pspec: decide_local(config, None, s, X))
        out = np.asarray(run(Xp))
        if ref_pol is None:
            ref_pol = out
        rel = float(np.max(np.abs(out - ref_pol)) / np.max(np.abs(ref_pol)))
        jax.block_until_ready(run(Xp))           # warm
        t0 = time.perf_counter()
        jax.block_until_ready(run(Xp))
        dt = time.perf_counter() - t0
        row = dict(n_test=n0, plan=f"local[{policy}]", policy=policy,
                   score_s=round(dt, 5),
                   rows_per_s=round(n0 / max(dt, 1e-9), 1),
                   max_rel_err=rel)
        results.append(row)
        print(f"| {n0} | local[{policy}] | {dt:.4f} | "
              f"{row['rows_per_s']:.0f} | {rel:.2e} |", flush=True)

    from repro.api.machine import KernelMachine
    km = KernelMachine(MachineConfig(m=m))
    km.state_ = {"basis": jnp.asarray(basis, jnp.float32),
                 "beta": jnp.asarray(beta, jnp.float32)}
    with tempfile.TemporaryDirectory() as td:
        full = km.save(os.path.join(td, "full.npz"))
        q8 = km.save(os.path.join(td, "q8.npz"), quantize="int8")
        ck = dict(plan="ckpt[int8]", m=m, d=d,
                  checkpoint_bytes_fp32=os.path.getsize(full),
                  checkpoint_bytes_int8=os.path.getsize(q8))
    ck["ratio"] = round(ck["checkpoint_bytes_int8"]
                        / ck["checkpoint_bytes_fp32"], 3)
    results.append(ck)
    print(f"checkpoint m={m}: fp32 {ck['checkpoint_bytes_fp32']} B, "
          f"int8 {ck['checkpoint_bytes_int8']} B "
          f"(ratio {ck['ratio']})", flush=True)

    if args.smoke:
        by = {r["plan"]: r for r in results}
        dense = args.ns[0] * m * 4          # the (n, m) f32 test-gram bytes
        assert by["local"]["peak_intermediate_bytes"] >= dense, \
            "instrumentation lost the dense test gram (positive control)"
        assert by["fused"]["peak_intermediate_bytes"] < args.ns[0] * m * 4, \
            "fused decide materialized an (n, m)-scale block"
        assert by["stream"]["peak_intermediate_bytes"] < \
            args.chunk_rows * m * 4, \
            "stream decide materialized a (chunk_rows, m)-scale block"
        assert by["local[fp32]"]["max_rel_err"] == 0.0
        assert by["local[bf16]"]["max_rel_err"] < 5e-2
        assert by["local[fp16]"]["max_rel_err"] < 1e-2
        assert by["ckpt[int8]"]["checkpoint_bytes_int8"] < \
            by["ckpt[int8]"]["checkpoint_bytes_fp32"]
        print("[smoke] inference memory contracts hold "
              "(dense gram seen locally; fused < n*m; stream < chunk*m); "
              "dtype policy margins bounded; int8 checkpoint smaller")

    from benchmarks.run import append_trajectory   # one trajectory format
    out = Path(args.out) if args.out else REPO_ROOT / "BENCH_infer.json"
    append_trajectory(out, {
        "benchmark": "infer_scaling", "run_at": time.strftime(
            "%Y-%m-%dT%H:%M:%S"), "config": {
                "devices": p, "d": d, "m": m, "classes": max(k, 1),
                "chunk_rows": args.chunk_rows, "smoke": args.smoke,
                "backend": jax.default_backend()}, "results": results})
    print(f"appended {out}")


if __name__ == "__main__":
    main()
