"""Beyond-paper: Nystrom (formulation 4) vs Random Fourier Features at equal
feature budget m — the comparison the paper's §5 Discussion proposes.

Expected (Yang et al. 2012): the data-dependent Nystrom basis dominates at
small m on clustered data; the gap closes as m grows.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timeit
from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_dataset


def run(scale: float = 0.01, ms=(32, 128, 512)):
    X, y, Xt, yt, spec = make_dataset("covtype", jax.random.PRNGKey(0),
                                      scale=scale, d_cap=54)
    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=1.2),
                           lam=0.01, tron=TronConfig(max_iter=80), seed=2)
    rows = []
    wins = 0
    for m in ms:
        nys = KernelMachine(config).fit(
            X, y, random_basis(jax.random.PRNGKey(1), X, m))
        acc_nys = nys.score(Xt, yt)
        rff = KernelMachine(config.replace(solver="rff",
                                           rff_features=m)).fit(X, y)
        acc_rff = rff.score(Xt, yt)
        wins += acc_nys >= acc_rff
        rows.append(Row(f"rff_vs_nystrom/m{m}", 0.0,
                        f"nystrom_acc={acc_nys:.4f};rff_acc={acc_rff:.4f}"))
    rows.append(Row("rff_vs_nystrom/claim_nystrom_dominates", 0.0,
                    f"nystrom_wins={wins}/{len(ms)};ok={wins >= len(ms) - 1}"))
    return rows
