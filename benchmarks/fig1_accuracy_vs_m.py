"""Paper Fig. 1: test accuracy vs m for Covtype- and CCAT-like data.

Claim validated: accuracy rises quickly at small m, keeps improving at
large m on the hard (covtype-like) dataset — the regime that motivates the
paper ('need for large m', §4.2).
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timeit
from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_dataset


def run(scale: float = 0.01, ms=(16, 64, 256, 1024)):
    rows = []
    for ds, sigma in (("covtype", 1.2), ("ccat", 2.0)):
        X, y, Xt, yt, spec = make_dataset(ds, jax.random.PRNGKey(0),
                                          scale=scale, d_cap=64)
        config = MachineConfig(kernel=KernelSpec("gaussian", sigma=sigma),
                               lam=1.0, tron=TronConfig(max_iter=80))
        accs = []
        for m in ms:
            basis = random_basis(jax.random.PRNGKey(1), X, m)
            t = timeit(lambda: KernelMachine(config)
                       .fit(X, y, basis).state_["beta"])
            acc = KernelMachine(config).fit(X, y, basis).score(Xt, yt)
            accs.append(acc)
            rows.append(Row(f"fig1/{ds}_m{m}", t * 1e6, f"test_acc={acc:.4f}"))
        monotone = all(accs[i] <= accs[i + 1] + 0.01 for i in range(len(accs) - 1))
        rows.append(Row(f"fig1/{ds}_claim_acc_rises_with_m", 0.0,
                        f"accs={['%.3f' % a for a in accs]};ok={monotone}"))
    return rows
