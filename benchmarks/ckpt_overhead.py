"""In-training checkpoint overhead: step time with commits off / async / sync.

A preemption-safe run is only worth having if the insurance is cheap.
This measures the steady-state step time of the SAME tron solve three
ways (``--repeat`` interleaved passes, median reported, so machine drift
hits every mode equally):

  off     plain driver, no snapshots (the baseline trajectory)
  async   segmented driver + background writer (the ``--ckpt-interval``
          default): commits overlap the next training segment, so the
          training thread pays only the snapshot device->host pull
  sync    segmented driver committing on the training thread
          (``--ckpt-sync``): the upper bound, every fsync is on the
          critical path

Per-step time is STEADY-STATE, with compile excluded on both sides:

  * off — the plain driver behind a stable ``jax.jit`` wrapper, compiled
    once, then timed warm at two iteration caps; the time difference over
    the iteration-count difference is the pure step cost for the window
    ``[interval, N)``.
  * async / sync — ONE fit through the segmented driver with the real
    :class:`TrainingCheckpointer` committing every ``--interval`` outer
    iterations. The snapshot callbacks themselves timestamp each segment
    boundary; the slope of (iteration, time) across boundaries after the
    first is the steady per-step cost — segment compile happens before
    the first boundary and never enters the window, and every commit
    (enqueue for async, write+fsync for sync) inside the window is
    charged.

Both windows cover the same iterations, so per-iteration CG-count drift
cancels. Reported per mode: step seconds, overhead vs off in percent,
and the writer's own accounting (bytes, write seconds, drops). The
boundary cost is dominated by one canonicalizing f/g re-derivation per
interval (the price of bitwise resume) and is independent of where the
commit happens, so the overhead FRACTION falls as n grows while the
step itself scales with n x m. The acceptance bar this benchmark exists
to enforce: async overhead under 5% at the default interval at the
largest default size (32768) — smaller problems amortize less and
should lengthen ``--ckpt-interval`` to taste.

Emits the repo-root ``BENCH_ckpt.json`` perf-trajectory record (append
semantics: one entry per run, so regressions are visible across PRs).

Run:  PYTHONPATH=src python -m benchmarks.ckpt_overhead [--smoke]
"""
import argparse
import os

parser = argparse.ArgumentParser()
parser.add_argument("--ns", type=int, nargs="*",
                    default=[4096, 16384, 32768])
parser.add_argument("--d", type=int, default=32)
parser.add_argument("--m", type=int, default=256)
parser.add_argument("--max-iter", type=int, default=60,
                    help="outer-iteration cap (stagnation may stop earlier; "
                         "the measured window adapts)")
parser.add_argument("--interval", type=int, default=10,
                    help="outer iterations between commits")
parser.add_argument("--repeat", type=int, default=5,
                    help="timed passes per point, interleaved across modes "
                         "so machine drift hits all of them equally; the "
                         "median is reported")
parser.add_argument("--smoke", action="store_true",
                    help="single small size, short fit (CI-sized)")
parser.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_ckpt.json)")
args = parser.parse_args()
if args.smoke:
    args.ns, args.m, args.repeat = [2048], 64, 2
    args.max_iter, args.interval = 16, 4
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

import numpy as np

from repro.checkpoint import CheckpointConfig, TrainingCheckpointer
from repro.core import KernelSpec, TronConfig, select_basis
from repro.core.formulation import Formulation4
from repro.core.losses import get_loss
from repro.core.nystrom import build_C, build_W
from repro.core.tron import tron

REPO_ROOT = Path(__file__).resolve().parent.parent
MODES = ("off", "async", "sync")


KERNEL = KernelSpec("gaussian", sigma=4.0)
LAM = 1e-3


def _closures(X, y, basis):
    """Materialized (C, W) closures — the plan 'local' evaluation shape."""
    C = build_C(X, basis, KERNEL, None)
    W = build_W(basis, KERNEL, None)
    form = Formulation4(lam=LAM, loss=get_loss("squared_hinge"))
    return (lambda b: form.fgrad(C, W, y, b),
            lambda D, d: form.hessd(C, W, D, d), C.dtype)


def _setup_off(fgrad, hessd, b0, lo, hi):
    """Stable jitted wrappers for the plain driver at two caps (compiled
    once, reused warm every repeat). Plain-driver trajectories share
    their prefix across caps, so the hi-lo difference is exactly the
    [lo, hi) iteration window."""
    runs, iters = {}, {}
    for cap in (lo, hi):
        cfg = TronConfig(max_iter=cap, grad_rtol=0.0)
        run = jax.jit(lambda cfg=cfg: tron(fgrad, hessd, b0, cfg))
        iters[cap] = int(jax.block_until_ready(run().n_iter))   # compile
        runs[cap] = run
    span = iters[hi] - iters[lo]
    if span <= 0:
        raise SystemExit(
            f"solve stagnated at {iters[hi]} iterations <= interval "
            f"{lo}; lower --interval to leave a measurement window")
    return runs, span, iters[hi]


def _time_off(runs, span, lo, hi):
    ts = {}
    for cap, run in runs.items():
        t0 = time.perf_counter()
        jax.block_until_ready(run().beta)
        ts[cap] = time.perf_counter() - t0
    return (ts[hi] - ts[lo]) / span


def _time_ckpt(fgrad, hessd, b0, mode, n_iter_cap):
    """One segmented fit through the real commit path; returns the slope
    of (iteration, wall time) across snapshot boundaries after the first
    — compile lands before the first boundary, outside the window; every
    commit inside the window (enqueue for async, write+fsync for sync)
    is charged."""
    with tempfile.TemporaryDirectory() as tmp:
        ck = TrainingCheckpointer(
            CheckpointConfig(dir=tmp, interval=args.interval, keep=2,
                             background=mode == "async"),
            meta={"solver": "tron", "plan": "local", "bench": True})
        marks = []

        def hook(snap, _ck=ck, _marks=marks):
            _marks.append((int(np.asarray(snap.it)), time.perf_counter()))
            _ck.on_snapshot(snap)

        try:
            tron(fgrad, hessd, b0, TronConfig(max_iter=n_iter_cap,
                                              grad_rtol=0.0),
                 snapshot_every=args.interval, on_snapshot=hook)
        finally:
            ck.close()
        stats = ck.stats()
    if len(marks) < 2:
        raise SystemExit(
            f"{mode}: only {len(marks)} snapshot boundaries inside "
            f"{n_iter_cap} iterations; lower --interval")
    (i0, t0), (i1, t1) = marks[0], marks[-1]
    return (t1 - t0) / (i1 - i0), stats


def bench_size(n):
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n, args.d))
    y = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (n,)))
    basis = select_basis(jax.random.PRNGKey(2), X, args.m)
    fgrad, hessd, dt = _closures(X, y, basis)
    b0 = jnp.zeros((args.m,), dt)
    # off window starts at the first boundary the ckpt modes measure from
    runs, span, n_iter = _setup_off(fgrad, hessd, b0, args.interval,
                                    args.max_iter)
    samples = {m: [] for m in MODES}
    stats = {}
    for _ in range(args.repeat):          # round-robin: drift hits all modes
        samples["off"].append(_time_off(runs, span, args.interval,
                                        args.max_iter))
        for mode in ("async", "sync"):
            step, stats[mode] = _time_ckpt(fgrad, hessd, b0, mode, n_iter)
            samples[mode].append(step)
    med = {m: float(np.median(samples[m])) for m in MODES}
    rows = {"off": dict(n=n, mode="off", n_iter=n_iter,
                        step_s=round(med["off"], 6))}
    for mode in ("async", "sync"):
        s = stats[mode]
        rows[mode] = dict(
            n=n, mode=mode, n_iter=n_iter, step_s=round(med[mode], 6),
            overhead_pct=round(
                100.0 * (med[mode] - med["off"]) / med["off"], 2),
            snapshots=s["snapshots_written"],
            ckpt_bytes=s["bytes_written"],
            write_s=round(s["write_seconds"], 5),
            dropped=s["snapshots_dropped"])
    return [rows[m] for m in MODES]


def main():
    print(f"d={args.d} m={args.m} max_iter={args.max_iter} "
          f"interval={args.interval} backend={jax.default_backend()}")
    print("| n | mode | step_s | overhead | snapshots | write_s |")
    print("|---|------|--------|----------|-----------|---------|")
    results = []
    for n in args.ns:
        for row in bench_size(n):
            results.append(row)
            ov = (f"{row['overhead_pct']:+.2f}%"
                  if "overhead_pct" in row else "—")
            print(f"| {n} | {row['mode']} | {row['step_s']:.5f} | {ov} "
                  f"| {row.get('snapshots', 0)} "
                  f"| {row.get('write_s', 0.0):.4f} |", flush=True)

    from benchmarks.run import append_trajectory   # one trajectory format
    out = Path(args.out) if args.out else REPO_ROOT / "BENCH_ckpt.json"
    append_trajectory(out, {
        "benchmark": "ckpt_overhead", "run_at": time.strftime(
            "%Y-%m-%dT%H:%M:%S"), "config": {
                "d": args.d, "m": args.m, "max_iter": args.max_iter,
                "interval": args.interval, "repeat": args.repeat,
                "smoke": args.smoke, "backend": jax.default_backend()},
        "results": results})
    print(f"appended {out}")


if __name__ == "__main__":
    main()
