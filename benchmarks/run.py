"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Scales are CPU-container defaults;
full-scale shape coverage lives in the dry-run/roofline path.

``--emit-json`` appends each benchmark's rows to a repo-root
``BENCH_<name>.json`` trajectory file (one record per run, oldest first),
so perf history accumulates across PRs next to ``BENCH_stream.json`` from
``benchmarks.stream_scaling``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def append_trajectory(path: Path, record: dict) -> None:
    """Append one run record to a JSON trajectory file (list of records)."""
    trajectory = []
    if path.exists():
        trajectory = json.loads(path.read_text())
        if not isinstance(trajectory, list):
            trajectory = [trajectory]
    trajectory.append(record)
    path.write_text(json.dumps(trajectory, indent=2) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names (e.g. table1,fig1)")
    ap.add_argument("--emit-json", action="store_true",
                    help="append results to repo-root BENCH_<name>.json "
                         "trajectory files")
    args = ap.parse_args()

    from benchmarks import (fig1_accuracy_vs_m, fig2_speedup, rff_vs_nystrom,
                            roofline, table1_formulations, table2_basis,
                            table4_cost_slicing, table5_ppacksvm)
    benches = {
        "table1": table1_formulations.run,
        "fig1": fig1_accuracy_vs_m.run,
        "table2": table2_basis.run,
        "table4": table4_cost_slicing.run,
        "fig2": fig2_speedup.run,
        "table5": table5_ppacksvm.run,
        "rff": rff_vs_nystrom.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if name not in only:
            continue
        t0 = time.time()
        rows = []
        try:
            for row in fn():
                rows.append(row)
                print(row.csv(), flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(name)
        elapsed = time.time() - t0
        print(f"# {name} finished in {elapsed:.1f}s", flush=True)
        # never emit a partial row set from a crashed run: it would be
        # indistinguishable from a fast successful run in the trajectory
        if args.emit_json and rows and name not in failed:
            out = REPO_ROOT / f"BENCH_{name}.json"
            append_trajectory(out, {
                "benchmark": name,
                "run_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "elapsed_s": round(elapsed, 1),
                "rows": [{"name": r.name, "us_per_call": r.us_per_call,
                          "derived": r.derived} for r in rows]})
            print(f"# appended {out.name}", flush=True)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
