"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Scales are CPU-container defaults;
full-scale shape coverage lives in the dry-run/roofline path.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names (e.g. table1,fig1)")
    args = ap.parse_args()

    from benchmarks import (fig1_accuracy_vs_m, fig2_speedup, rff_vs_nystrom,
                            roofline, table1_formulations, table2_basis,
                            table4_cost_slicing, table5_ppacksvm)
    benches = {
        "table1": table1_formulations.run,
        "fig1": fig1_accuracy_vs_m.run,
        "table2": table2_basis.run,
        "table4": table4_cost_slicing.run,
        "fig2": fig2_speedup.run,
        "table5": table5_ppacksvm.run,
        "rff": rff_vs_nystrom.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row.csv(), flush=True)
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} finished in {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
