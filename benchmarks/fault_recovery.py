"""Fault recovery cost: MTTR and steps lost per kill under supervision.

The paper's §4 deployment argument is qualitative — Hadoop re-runs a lost
worker's task, so the job survives. This benchmark makes the repo's
version of that claim quantitative. A supervised streaming
``kernel_train`` fit is killed ``--kills`` times mid-run (a SIGKILL
inside a checkpoint commit, injected by flag-guarded ``ckpt.commit``
rules so each kill fires exactly once across restarts); the supervisor's
per-attempt forensics then price the recovery:

  mttr_s               mean time from death detection to relaunch
                       (teardown of survivors + backoff), per kill
  death_detect_s       attempt launch -> death noticed (mostly the
                       training time before the kill; detection itself
                       is bounded by the supervisor's poll interval)
  steps_lost_per_kill  outer iterations recomputed after resume: the
                       step being committed when killed minus the step
                       actually resumed from (bounded by the interval)
  recovered_bitwise    final beta identical to the unkilled run's — the
                       recovery was free in result terms, only in time

Emits the repo-root ``BENCH_faults.json`` trajectory record.

Run:  PYTHONPATH=src python -m benchmarks.fault_recovery [--smoke]
"""
import argparse
import os
import sys
import tempfile
import time
from pathlib import Path

parser = argparse.ArgumentParser()
parser.add_argument("--n", type=int, default=8192)
parser.add_argument("--d", type=int, default=16)
parser.add_argument("--m", type=int, default=64)
parser.add_argument("--max-iter", type=int, default=60)
parser.add_argument("--interval", type=int, default=5,
                    help="outer iterations between checkpoint commits")
parser.add_argument("--kills", type=int, default=2,
                    help="how many times a worker is SIGKILLed mid-run")
parser.add_argument("--smoke", action="store_true",
                    help="small, CI-sized run (one kill)")
parser.add_argument("--out", default=None,
                    help="output JSON (default: <repo>/BENCH_faults.json)")
args = parser.parse_args()
if args.smoke:
    args.n, args.m, args.max_iter = 2048, 32, 40
    args.interval, args.kills = 2, 1
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

from benchmarks.run import REPO_ROOT, append_trajectory

sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.data.chunks import save_chunks          # noqa: E402
from repro.faults import FAULT_ENV, FaultPlan      # noqa: E402
from repro.sharding.supervisor import (Supervisor,  # noqa: E402
                                       SupervisorConfig)


def child_cmd(data_dir, save, ckpt_dir):
    def build(pid, nproc, port, resume):
        cmd = [sys.executable, "-m", "repro.launch.kernel_train",
               "--plan", "stream", "--data-dir", str(data_dir),
               "--m", str(args.m), "--max-iter", str(args.max_iter),
               "--lam", "1e-3", "--sigma", "2.0", "--chunk-rows", "512",
               "--ckpt-interval", str(args.interval), "--ckpt-keep", "0",
               "--ckpt-dir", str(ckpt_dir), "--save", str(save)]
        if resume:
            cmd += ["--resume", str(ckpt_dir)]
        return cmd
    return build


def supervised_fit(data_dir, save, ckpt_dir, *, plan=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    env.pop(FAULT_ENV, None)
    if plan is not None:
        env[FAULT_ENV] = plan.to_json()
    sup = Supervisor(
        child_cmd(data_dir, save, ckpt_dir), ckpt_dir=str(ckpt_dir),
        config=SupervisorConfig(max_restarts=args.kills + 1,
                                backoff_s=0.25, max_backoff_s=2.0),
        env=env, say=lambda s: print(s, flush=True))
    t0 = time.monotonic()
    res = sup.run()
    return res, time.monotonic() - t0


def beta(path):
    with np.load(path, allow_pickle=True) as z:
        return np.asarray(z["beta"], dtype=np.float64)


def main():
    root = Path(tempfile.mkdtemp(prefix="fault-recovery-"))
    rng = np.random.default_rng(7)
    X = rng.standard_normal((args.n, args.d)).astype(np.float32)
    w = rng.standard_normal(args.d)
    y = np.where(X @ w + 0.3 * rng.standard_normal(args.n) > 0, 1, -1)
    save_chunks(root / "shards", X, y.astype(np.int64), rows_per_shard=1024)

    print(f"# fault_recovery: n={args.n} m={args.m} "
          f"interval={args.interval} kills={args.kills}")
    ref_res, ref_s = supervised_fit(root / "shards", root / "ref.npz",
                                    root / "ref-steps")
    assert ref_res.ok and ref_res.restarts == 0

    # each flag-guarded rule fires once across ALL processes/restarts, so
    # k rules = exactly k kill cycles, then the last relaunch runs clean
    plan = FaultPlan()
    for i in range(args.kills):
        plan.inject("ckpt.commit", action="kill", after=1, times=1,
                    flag=str(root / f"kill-{i}"))
    got_res, got_s = supervised_fit(root / "shards", root / "got.npz",
                                    root / "got-steps", plan=plan)
    assert got_res.ok, "supervised run failed to recover"
    assert got_res.restarts == args.kills, \
        f"expected {args.kills} restarts, got {got_res.restarts}"

    failed = [a for a in got_res.attempts if not a["ok"]]
    mttr = [a["teardown_s"] + a["backoff_s"] for a in failed]
    detect = [a["death_detect_s"] for a in failed]
    # the kill fires inside the commit AFTER the one resumed from: the
    # in-flight step is one interval past each attempt's resume point
    lost = []
    for prev, nxt in zip(got_res.attempts, got_res.attempts[1:]):
        killed_at = (prev["resumed_from"] or 0) + 2 * args.interval
        lost.append(killed_at - (nxt["resumed_from"] or 0))

    bitwise = bool(np.array_equal(beta(root / "ref.npz"),
                                  beta(root / "got.npz")))
    rows = {
        "kills": args.kills,
        "restarts": got_res.restarts,
        "mttr_s": float(np.mean(mttr)),
        "death_detect_s": float(np.mean(detect)),
        "steps_lost_per_kill": float(np.mean(lost)),
        "recovered_bitwise": bitwise,
        "clean_fit_s": round(ref_s, 3),
        "faulted_fit_s": round(got_s, 3),
        "recovery_overhead_s": round(got_s - ref_s, 3),
    }
    print("\n| metric | value |\n|---|---|")
    for k, v in rows.items():
        print(f"| {k} | {v} |")
    if not bitwise:
        print("WARNING: recovered beta is NOT bitwise identical")

    out = Path(args.out) if args.out else REPO_ROOT / "BENCH_faults.json"
    append_trajectory(out, {
        "bench": "fault_recovery", "smoke": bool(args.smoke),
        "n": args.n, "d": args.d, "m": args.m,
        "max_iter": args.max_iter, "interval": args.interval,
        "timestamp": time.time(), **rows,
    })
    print(f"\nwrote {out}")
    return 0 if bitwise else 1


if __name__ == "__main__":
    raise SystemExit(main())
