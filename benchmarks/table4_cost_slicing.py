"""Paper Table 4: per-step cost slicing of Algorithm 1.

Steps: 1 data loading, 2 basis selection/broadcast, 3 kernel (C) computation,
4 TRON optimization. Claim validated: high-d data (mnist8m-like) is kernel-
computation dominated (step 3 >> step 4); low-d/hard data (covtype-like,
many TRON iterations) is optimization dominated (step 4 >> step 3).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import Row
from repro.core import (Formulation4, KernelSpec, TronConfig, build_C,
                        build_W, get_loss, random_basis, tron)
from repro.data import make_dataset

import jax.numpy as jnp


def run(scale: float = 0.004, m: int = 512):
    rows = []
    dominance = {}
    for ds, sigma, iters in (("covtype", 1.2, 200), ("mnist8m", 12.0, 12)):
        t0 = time.perf_counter()
        X, y, Xt, yt, spec = make_dataset(ds, jax.random.PRNGKey(0),
                                          scale=scale, d_cap=784)
        X.block_until_ready()
        t1_load = time.perf_counter() - t0

        t0 = time.perf_counter()
        basis = random_basis(jax.random.PRNGKey(1), X, m)
        basis.block_until_ready()
        t2_basis = time.perf_counter() - t0

        kern = KernelSpec("gaussian", sigma=sigma)
        t0 = time.perf_counter()
        C = build_C(X, basis, kern)
        W = build_W(basis, kern)
        jax.block_until_ready((C, W))
        t3_kernel = time.perf_counter() - t0

        form = Formulation4(lam=0.01, loss=get_loss("squared_hinge"))
        run_tron = jax.jit(lambda C, W, y, b: tron(
            lambda bb: form.fgrad(C, W, y, bb),
            lambda D, d: form.hessd(C, W, D, d),
            b, TronConfig(max_iter=iters, grad_rtol=1e-6)))
        t0 = time.perf_counter()
        res = run_tron(C, W, y, jnp.zeros((m,), X.dtype))
        res.beta.block_until_ready()
        t4_tron = time.perf_counter() - t0

        dominance[ds] = t3_kernel / max(t4_tron, 1e-9)
        rows.append(Row(f"table4/{ds}_step1_load", t1_load * 1e6, f"s={t1_load:.3f}"))
        rows.append(Row(f"table4/{ds}_step2_basis", t2_basis * 1e6, f"s={t2_basis:.3f}"))
        rows.append(Row(f"table4/{ds}_step3_kernel", t3_kernel * 1e6,
                        f"s={t3_kernel:.3f};d={X.shape[1]}"))
        rows.append(Row(f"table4/{ds}_step4_tron", t4_tron * 1e6,
                        f"s={t4_tron:.3f};n_iter={int(res.n_iter)};"
                        f"n_hd={int(res.n_hd)}"))
    ok = dominance["mnist8m"] > dominance["covtype"]
    rows.append(Row("table4/claim_step3_dominates_high_d", 0.0,
                    f"kernel/tron_ratio_mnist8m={dominance['mnist8m']:.2f};"
                    f"covtype={dominance['covtype']:.2f};ok={ok}"))
    return rows
