"""Peak-memory + step-time comparison: shard_map vs otf vs otf_shard at
growing basis size m — the scale axis the fused plan exists to unlock.

For each plan at each m this measures, per device:
  * peak_intermediate_bytes — largest array the f/g + 3xHd TRON-iteration
    mix materializes (jaxpr shape instrumentation, per-shard avals; the
    quantity that OOMs). For materialized plans the resident (C, W) shards
    are added on top — they live for the whole solve.
  * step_s — wall-clock for one jitted iteration mix at the reduced CPU
    scale of this container (relative numbers; absolute speed needs TPU).

BENCH json (benchmarks/results/kernel_machine/otf_shard_mem_m{m}_{plan}
.json) gains the memory axis: {"m", "plan", "peak_intermediate_bytes",
"resident_cw_bytes", "step_s", "n", "d", "p"}.

Run:  PYTHONPATH=src python -m benchmarks.otf_shard_memory [--devices 8]
"""
import argparse
import os

parser = argparse.ArgumentParser()
parser.add_argument("--devices", type=int, default=8)
parser.add_argument("--n", type=int, default=4096)
parser.add_argument("--d", type=int, default=32)
parser.add_argument("--ms", type=int, nargs="*", default=[128, 256, 512, 1024])
args = parser.parse_args()
# append (not setdefault): a user-set XLA_FLAGS must not silently disable
# the forced device count --devices asked for
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") +
    f" --xla_force_host_platform_device_count={args.devices}").strip()

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import DistConfig, DistributedNystrom, KernelSpec
from repro.core.compat import make_mesh
from repro.core.introspect import max_intermediate_elems

RESULTS = Path(__file__).resolve().parent / "results" / "kernel_machine"

PLANS = {
    "shard_map": dict(materialize=True),
    "otf": dict(materialize=False),
    "otf_shard": dict(materialize=False, fused=True),
}


def iteration_mix(solver, X, y, basis, materialize):
    """f/g + 3 Hd — the paper's per-TRON-iteration evaluation mix."""
    if materialize:
        C, W = solver.precompute(X, basis)
        fgrad, hessd = solver.make_closures(C, W, y)
    elif solver.dist.fused:
        fgrad, hessd = solver.make_fused_closures(X, y, basis)
    else:
        fgrad, hessd = solver.make_otf_closures(X, y, basis)

    def step(b):
        f, g, D = fgrad(b)
        h = hessd(D, g)
        h = hessd(D, h)
        h = hessd(D, h)
        return f, g + h

    return step


def main():
    p = args.devices
    n, d = args.n, args.d
    mesh = make_mesh((p,), ("data",))
    kern = KernelSpec("gaussian", sigma=4.0)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n, d))
    y = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (n,)))
    Xs = jax.device_put(X, NamedSharding(mesh, P(("data",), None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(("data",))))

    RESULTS.mkdir(parents=True, exist_ok=True)
    print(f"n={n} d={d} p={p}  (per-shard rows: {n // p})")
    print("| m | plan | peak intermediate / dev | resident C,W / dev | step_s |")
    print("|---|------|-------------------------|--------------------|--------|")
    for m in args.ms:
        basis = jax.random.normal(jax.random.PRNGKey(2), (m, d))
        for plan, kw in PLANS.items():
            dc = DistConfig(data_axes=("data",), **kw)
            solver = DistributedNystrom(mesh, 0.5, "squared_hinge", kern, dc)
            step = iteration_mix(solver, Xs, ys, basis, kw.get("materialize"))
            b0 = jnp.zeros((m,), jnp.float32)
            with mesh:
                peak = max_intermediate_elems(step, b0) * 4
                run = jax.jit(step)
                jax.block_until_ready(run(b0))          # compile
                t0 = time.perf_counter()
                jax.block_until_ready(run(b0))
                dt = time.perf_counter() - t0
            # precompute shards C as (n/p, m) and W as (m/p, m) per device
            resident = ((n // p) * m + (m // p) * m) * 4 if kw.get(
                "materialize") else 0
            print(f"| {m} | {plan} | {peak / 2**20:.2f} MiB "
                  f"| {resident / 2**20:.2f} MiB | {dt:.4f} |", flush=True)
            (RESULTS / f"otf_shard_mem_m{m}_{plan}.json").write_text(
                json.dumps({"n": n, "d": d, "p": p, "m": m, "plan": plan,
                            "peak_intermediate_bytes": peak,
                            "resident_cw_bytes": resident,
                            "step_s": round(dt, 5)}, indent=2))


if __name__ == "__main__":
    main()
