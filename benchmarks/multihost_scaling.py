"""Multi-controller scaling: step time + measured cross-host bytes/eval.

The paper's distribution claim is that each TRON iteration moves O(m)
bytes between nodes regardless of n (the AllReduce of f/g/Hd partials),
so adding hosts buys data capacity at constant coordination cost. This
benchmark runs the SAME fused stream evaluation over 1, 2 and 4
controller processes on one machine (fake local devices keep the global
mesh at 4 devices throughout, so the math — and the flop count — is
identical; only the process partition changes) and reports:

  * eval_s          wall seconds of one f/g + Hd pass (the TRON step body)
  * xhost bytes     the per-chunk collective payload counted from the
                    traced jaxpr (instrumented, not claimed), and the
                    per-eval total = n_chunks x per-chunk

The per-eval bytes must be identical across process counts and a tiny
fraction of the partition size; step time may pick up the gloo hop cost
(cross-process TCP AllReduce vs XLA's shared-memory reduction) — that
gap IS the deployment price the paper's Table 4 slices, measured here.

Emits the repo-root ``BENCH_multihost.json`` trajectory record.

Run:  PYTHONPATH=src python -m benchmarks.multihost_scaling [--smoke]

(The module re-invokes itself with ``--worker`` for each fleet process;
XLA_FLAGS is set by the parent before each spawn.)
"""
import argparse
import json
import os
import socket
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

parser = argparse.ArgumentParser()
parser.add_argument("--n", type=int, default=16384)
parser.add_argument("--d", type=int, default=32)
parser.add_argument("--m", type=int, default=256)
parser.add_argument("--chunk-rows", type=int, default=2048)
parser.add_argument("--evals", type=int, default=8,
                    help="timed f/g + Hd passes (min reported)")
parser.add_argument("--procs", type=int, nargs="*", default=[1, 2, 4],
                    help="process counts; each uses 4/P fake local devices")
parser.add_argument("--smoke", action="store_true",
                    help="small sizes for the verify.sh gate")
parser.add_argument("--out", default=None,
                    help="output JSON (default: <repo>/BENCH_multihost.json)")
parser.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
parser.add_argument("--fleet", type=int, default=0, help=argparse.SUPPRESS)
parser.add_argument("--pid", type=int, default=0, help=argparse.SUPPRESS)
parser.add_argument("--port", type=int, default=0, help=argparse.SUPPRESS)
args = parser.parse_args()
if args.smoke:
    args.n, args.m, args.chunk_rows, args.evals = 2048, 64, 512, 3


# ------------------------------------------------------------ worker process
def worker():
    import numpy as np
    from repro.sharding import multihost

    multihost.init(f"127.0.0.1:{args.port}", args.fleet, args.pid)

    import jax
    from repro.core import KernelSpec
    from repro.core.distributed import DistConfig, DistributedNystrom
    from repro.core.introspect import collective_payload_bytes_jaxpr
    from repro.data.chunks import ArrayChunkSource

    rng = np.random.default_rng(0)
    X = rng.standard_normal((args.n, args.d)).astype(np.float32)
    y = np.where(X @ rng.standard_normal(args.d) > 0, 1, -1).astype(np.int64)
    basis = X[: args.m].copy()
    mesh = multihost.spanning_mesh()
    kern = KernelSpec("gaussian", sigma=2.0)
    solver = DistributedNystrom(mesh, 0.1, "squared_hinge", kern,
                                DistConfig(fused=True, materialize=False))
    sc = solver.make_stream_closures(
        ArrayChunkSource(X, y, chunk_rows=args.chunk_rows), basis)
    beta = np.zeros((args.m,), np.float32)

    f, g, aux = sc.fgrad(beta)           # warm: compile + first stream pass
    sc.hessd(aux, g)
    best = float("inf")
    for _ in range(args.evals):
        t0 = time.perf_counter()
        f, g, aux = sc.fgrad(beta)
        sc.hessd(aux, g)
        best = min(best, time.perf_counter() - t0)

    cr, d, m = sc.chunk_rows, args.d, args.m
    f32 = np.float32

    def count(fn, *shapes):
        with mesh:
            closed = jax.make_jaxpr(fn)(
                *[jax.ShapeDtypeStruct(s, f32) for s in shapes])
        return collective_payload_bytes_jaxpr(closed.jaxpr)

    fg_b = count(sc.fg_chunk, (cr, d), (cr,), (cr,), (m, d), (m,))
    hd_b = count(sc.hd_chunk, (cr, d), (cr,), (m, d), (m,))
    multihost.sync("bench-done")
    if multihost.is_primary():
        print(json.dumps({
            "num_processes": args.fleet, "n_devices": jax.device_count(),
            "eval_s": best, "n_chunks": sc.n_chunks, "chunk_rows": cr,
            "fg_chunk_bytes": int(fg_b), "hd_chunk_bytes": int(hd_b),
            "bytes_per_eval": int(sc.n_chunks * (fg_b + hd_b)),
            "partition_bytes": int(X.nbytes // args.fleet)}))


# ------------------------------------------------------------- fleet driver
def free_port():
    s = socket.socket()
    try:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]
    finally:
        s.close()


def run_fleet(nproc):
    devs = 4 // nproc
    port = free_port()
    procs = []
    for p in range(nproc):
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devs}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = str(REPO_ROOT / "src") + os.pathsep \
            + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "benchmarks.multihost_scaling",
               "--worker", "--fleet", str(nproc), "--pid", str(p),
               "--port", str(port),
               "--n", str(args.n), "--d", str(args.d), "--m", str(args.m),
               "--chunk-rows", str(args.chunk_rows),
               "--evals", str(args.evals)]
        procs.append(subprocess.Popen(
            cmd, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, env=env, cwd=str(REPO_ROOT)))
    outs = [pr.communicate()[0].decode(errors="replace") for pr in procs]
    for p, pr in enumerate(procs):
        if pr.returncode != 0:
            raise SystemExit(f"worker {p}/{nproc} failed rc={pr.returncode}:"
                             f"\n{outs[p][-2000:]}")
    return json.loads(outs[0].strip().splitlines()[-1])


def main():
    print(f"n={args.n} d={args.d} m={args.m} chunk_rows={args.chunk_rows} "
          f"evals={args.evals} (4 global devices throughout)")
    print("| procs | eval_s | bytes/eval | bytes/chunk (fg+hd) | "
          "partition MB |")
    print("|-------|--------|------------|---------------------|"
          "--------------|")
    results = []
    for nproc in args.procs:
        if 4 % nproc:
            raise SystemExit(f"--procs must divide 4, got {nproc}")
        row = run_fleet(nproc)
        results.append(row)
        print(f"| {nproc} | {row['eval_s']:.4f} | {row['bytes_per_eval']} "
              f"| {row['fg_chunk_bytes'] + row['hd_chunk_bytes']} "
              f"| {row['partition_bytes'] / 1e6:.1f} |", flush=True)

    # the instrumented O(m) claim, enforced at benchmark time too
    per_eval = {r["bytes_per_eval"] for r in results}
    assert len(per_eval) == 1, \
        f"cross-host bytes/eval changed with process count: {per_eval}"
    chunk_bytes = results[0]["fg_chunk_bytes"] + results[0]["hd_chunk_bytes"]
    assert chunk_bytes <= 8 * args.m * 4, \
        f"per-chunk payload {chunk_bytes}B is not O(m) (m={args.m})"

    from benchmarks.run import append_trajectory
    out = Path(args.out) if args.out else REPO_ROOT / "BENCH_multihost.json"
    append_trajectory(out, {
        "benchmark": "multihost_scaling",
        "run_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "config": {"n": args.n, "d": args.d, "m": args.m,
                   "chunk_rows": args.chunk_rows, "evals": args.evals,
                   "smoke": args.smoke},
        "results": results})
    print(f"appended {out}")


if __name__ == "__main__":
    worker() if args.worker else main()
