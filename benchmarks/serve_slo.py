"""Serve SLO: continuous batching vs request-at-a-time under client load.

One fleet of concurrent client threads fires identical mixed-size request
streams at both serving architectures, with ``--window`` requests
outstanding per client (the offered load is the same; only the server
changes):

  * baseline — the pre-engine ``ServingEndpoint`` semantics: a global
    lock serializes dispatches, one request's rows per dispatch, so a
    queued (2, d) request pays a whole (bucket, m) contraction alone;
  * engine — :class:`repro.serve.ServeEngine` continuous batching: queued
    rows from many clients coalesce into ONE power-of-two-bucketed
    dispatch and the multi-RHS margins are scattered back per caller.

Per-request latency is timed submit-to-result; responses are verified
AFTER the timed region against references computed synchronously through
the same bucketed jit family (``--atol`` bounds the comparison: at large
m XLA may split the m-reduction differently per batch shape, so exact
bitwise equality is only contractual at small m — the engine's own
tier-1 tests pin that). The report per target: rows/s, p50/p95/p99 (the
shared ``repro.serve.metrics.percentiles`` helper), completion/rejection
counts, and for the engine batch occupancy + requests per dispatch.

Emits the repo-root ``BENCH_serve.json`` perf-trajectory record (append
semantics: one entry per run, regressions visible across PRs). ``--smoke``
shrinks everything and asserts the serving contracts — the
``scripts/verify.sh --bench-smoke`` step.

Run:  PYTHONPATH=src python -m benchmarks.serve_slo [--clients 8]
"""
import argparse

parser = argparse.ArgumentParser()
parser.add_argument("--clients", type=int, default=8,
                    help="concurrent client threads (acceptance: >= 8)")
parser.add_argument("--requests", type=int, default=100,
                    help="requests per client")
parser.add_argument("--window", type=int, default=16,
                    help="submissions outstanding per client (1 = fully "
                         "synchronous callers)")
parser.add_argument("--max-rows", type=int, default=4,
                    help="request sizes drawn uniformly from [1, max-rows] "
                         "— small requests are where coalescing pays")
parser.add_argument("--m", type=int, default=4096,
                    help="basis size (large m = expensive per-dispatch "
                         "contraction, the serving-relevant regime)")
parser.add_argument("--d", type=int, default=128)
parser.add_argument("--max-batch", type=int, default=256,
                    help="rows per engine dispatch: the top batch bucket")
parser.add_argument("--atol", type=float, default=1e-6,
                    help="verification tolerance vs the synchronous "
                         "reference (0 = bitwise)")
parser.add_argument("--seed", type=int, default=0)
parser.add_argument("--smoke", action="store_true",
                    help="tiny sizes + contract asserts "
                         "(the verify.sh --bench-smoke step)")
parser.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_serve.json)")
args = parser.parse_args()
if args.smoke:
    args.clients, args.requests, args.window = 4, 40, 8
    args.m, args.d, args.max_batch = 512, 32, 64

import time
from pathlib import Path

import jax
import numpy as np

from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec
from repro.serve import (EngineConfig, ModelRegistry, ServeEngine,
                         baseline_target, engine_target, make_workload,
                         run_load)

REPO_ROOT = Path(__file__).resolve().parent.parent


def make_machine(m: int, d: int, seed: int = 0) -> KernelMachine:
    """A served-shape machine with synthetic weights — serving cost depends
    only on (m, d), not on how beta was fit, so no slow training here."""
    km = KernelMachine(MachineConfig(kernel=KernelSpec("gaussian",
                                                       sigma=4.0)))
    km.state_ = {
        "basis": jax.random.normal(jax.random.PRNGKey(seed), (m, d)),
        "beta": jax.random.normal(jax.random.PRNGKey(seed + 1), (m,)),
    }
    return km


def main():
    print(f"clients={args.clients} requests/client={args.requests} "
          f"window={args.window} sizes=1-{args.max_rows} m={args.m} "
          f"d={args.d} max_batch={args.max_batch}")
    registry = ModelRegistry(max_batch=args.max_batch)
    registry.add("bin", make_machine(args.m, args.d, seed=args.seed))
    t0 = time.perf_counter()
    n_exec = sum(registry.warmup().values())
    print(f"warmup: {n_exec} executables in {time.perf_counter() - t0:.2f}s")

    streams = make_workload(registry, clients=args.clients,
                            requests_per_client=args.requests,
                            max_rows=args.max_rows, seed=args.seed)

    base_tgt = baseline_target(registry,
                               workers=args.clients * args.window)
    base = run_load(base_tgt, streams, label="baseline",
                    window=args.window, atol=args.atol)
    base_tgt.close()

    cfg = EngineConfig(max_batch=args.max_batch,
                       max_queue=max(4096, 2 * args.clients * args.window),
                       timeout_s=300.0)
    with ServeEngine(registry, cfg) as engine:
        eng = run_load(engine_target(engine), streams, label="engine",
                       window=args.window, atol=args.atol)
        snap = engine.metrics.snapshot()

    speedup = eng.rows_per_s / max(base.rows_per_s, 1e-9)
    results = []
    print("| target | rows/s | p50 ms | p99 ms | done | rej | mismatch |")
    print("|--------|--------|--------|--------|------|-----|----------|")
    for rep in (base, eng):
        row = rep.row()
        row = {k: (round(v, 2) if isinstance(v, float) else v)
               for k, v in row.items()}
        if rep is eng:
            row.update(occupancy=round(snap["occupancy"], 4),
                       requests_per_dispatch=round(
                           snap["requests_per_dispatch"], 2),
                       rejection_rate=round(snap["rejection_rate"], 4),
                       speedup_rows_per_s=round(speedup, 2))
        results.append(row)
        print(f"| {rep.label} | {rep.rows_per_s:.0f} "
              f"| {rep.latency_ms['p50_ms']:.2f} "
              f"| {rep.latency_ms['p99_ms']:.2f} | {rep.completed} "
              f"| {rep.rejected} | {rep.mismatches} |", flush=True)
    print(f"speedup: {speedup:.2f}x rows/s | engine p99 "
          f"{eng.latency_ms['p99_ms']:.1f}ms vs baseline "
          f"{base.latency_ms['p99_ms']:.1f}ms | occupancy "
          f"{snap['occupancy']:.2f} | {snap['requests_per_dispatch']:.1f} "
          f"requests/dispatch")

    # the serving contracts, asserted hard in the fast gate
    assert base.mismatches == 0 and eng.mismatches == 0, \
        (base.mismatches, eng.mismatches)
    assert eng.completed == eng.requests and eng.rejected == 0, \
        (eng.completed, eng.requests, eng.rejected)
    assert snap["requests_per_dispatch"] > 1.0, \
        f"engine never coalesced ({snap['requests_per_dispatch']})"
    assert 0.0 < snap["occupancy"] <= 1.0, snap["occupancy"]
    if args.smoke:
        assert speedup > 0.8, \
            f"smoke floor: engine fell behind request-at-a-time ({speedup:.2f}x)"
        print("[smoke] serve contracts hold (0 mismatches, 0 rejections, "
              "coalescing > 1 request/dispatch)")
    else:
        assert speedup >= 2.0, \
            f"acceptance: continuous batching must give >= 2x rows/s " \
            f"({speedup:.2f}x)"
        assert eng.latency_ms["p99_ms"] <= base.latency_ms["p99_ms"], \
            "acceptance: engine p99 must be equal or better"

    from benchmarks.run import append_trajectory   # one trajectory format
    out = Path(args.out) if args.out else REPO_ROOT / "BENCH_serve.json"
    append_trajectory(out, {
        "benchmark": "serve_slo", "run_at": time.strftime(
            "%Y-%m-%dT%H:%M:%S"), "config": {
                "clients": args.clients, "requests": args.requests,
                "window": args.window, "max_rows": args.max_rows,
                "m": args.m, "d": args.d, "max_batch": args.max_batch,
                "atol": args.atol, "smoke": args.smoke,
                "backend": jax.default_backend()}, "results": results})
    print(f"appended {out}")


if __name__ == "__main__":
    main()
