"""Paper Table 1: formulation (4) vs (3) cost as m grows (Vehicle dataset).

Claim validated: (3)'s eigendecomposition+A-formation becomes the dominant
cost as m grows (O(m^3) + O(n m^2)), while (4) grows ~linearly in m; the
'fraction of time for A' column rises sharply with m.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import Row, timeit
from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_dataset


def run(scale: float = 0.05, ms=(128, 512, 2048)):
    X, y, Xt, yt, spec = make_dataset("vehicle", jax.random.PRNGKey(0),
                                      scale=scale, d_cap=100)
    config = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0),
                           lam=spec.lam, tron=TronConfig(max_iter=100))
    rows = []
    for m in ms:
        basis = random_basis(jax.random.PRNGKey(1), X, m)
        t4 = timeit(lambda: KernelMachine(config)
                    .fit(X, y, basis).state_["beta"])
        t0 = time.perf_counter()
        km3 = KernelMachine(config.replace(solver="linearized")).fit(
            X, y, basis)
        t3 = time.perf_counter() - t0
        frac_a = km3.result_.extras["time_eig_and_A"] / t3
        rows.append(Row(f"table1/form4_m{m}", t4 * 1e6,
                        f"total_s={t4:.3f};n={X.shape[0]}"))
        rows.append(Row(f"table1/form3_m{m}", t3 * 1e6,
                        f"total_s={t3:.3f};frac_time_for_A={frac_a:.4f}"))
    # claim check: A-fraction increases with m
    fracs = [float(r.derived.split("frac_time_for_A=")[1]) for r in rows[1::2]]
    ok = all(fracs[i] <= fracs[i + 1] + 0.05 for i in range(len(fracs) - 1))
    rows.append(Row("table1/claim_A_fraction_grows", 0.0,
                    f"fracs={['%.3f' % f for f in fracs]};ok={ok}"))
    return rows
