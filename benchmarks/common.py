"""Shared benchmark utilities. All paper-table benchmarks run at a reduced
CPU scale (this container) with the scale factor recorded in the output;
full-scale numbers come from the dry-run/roofline path."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timeit(fn: Callable, *args, repeat: int = 1) -> float:
    """Seconds for one call (min over repeats), blocking on jax outputs."""
    import jax
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best
