"""§Perf hillclimbs on the three chosen (arch x shape) pairs.

  PYTHONPATH=src python -m benchmarks.hillclimb [--pair NAME]

Pairs (chosen per the brief from the baseline roofline table):
  * grok_train    — grok-1-314b x train_4k: WORST roofline fit (TPU-modeled
                    peak 18.6 GiB > 16 GiB budget).
  * deepseek_train— deepseek-v2-236b x train_4k: most COLLECTIVE-bound
                    (collective 23.0 s vs compute 11.7 s per step).
  * llama_prefill — llama3.2-1b x prefill_32k: most PAPER-representative
                    (sub-quadratic kernel approximation of attention).

Each experiment is one hypothesis->change->measure cycle; results saved to
benchmarks/results/hillclimb/<pair>__<tag>.json and summarized for
EXPERIMENTS.md §Perf.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "hillclimb"

EXPERIMENTS = {
    # tag -> (arch, shape, dryrun kwargs)
    "grok_train": [
        ("baseline", "grok-1-314b", "train_4k", {}),
        # H1: shard the remat-saved residual stack over the model axis;
        # napkin: stack 6.1 GiB -> 0.38 GiB (/16), +1 all-gather of h per
        # period per microbatch (64*8*12 MiB/dev ~ 6 GiB collective).
        ("shard_carry", "grok-1-314b", "train_4k",
         {"cfg_override": {"shard_carry": True}}),
        # H2: 16 microbatches; napkin: halves the stack AND the live acts,
        # but doubles per-step weight all-gathers.
        ("micro16", "grok-1-314b", "train_4k", {"micro_override": 16}),
        # H3 (round 2): group 2 periods per checkpoint step — halves the
        # saved-carry stack with ZERO extra collectives (the within-group
        # recompute is already paid by remat). Predicted peak 18.6 - 3.0 =
        # ~15.6 GiB (fits), collective unchanged.
        ("pps2", "grok-1-314b", "train_4k",
         {"cfg_override": {"periods_per_scan_step": 2}}),
    ],
    "deepseek_train": [
        ("baseline", "deepseek-v2-236b", "train_4k", {}),
        # H1: collective bytes are dominated by per-microbatch FSDP weight
        # all-gathers (1.06 TB/dev ~ micro x params-scale); halving the
        # microbatch count should nearly halve them. Memory headroom comes
        # from shard_carry (stack /16).
        ("micro4_carry", "deepseek-v2-236b", "train_4k",
         {"micro_override": 4, "cfg_override": {"shard_carry": True}}),
        # H2: carry sharding alone (memory down, collectives ~flat).
        ("shard_carry", "deepseek-v2-236b", "train_4k",
         {"cfg_override": {"shard_carry": True}}),
        # H3 (round 2): REFUTED H1/H2 carry-sharding (collective 23->106 s:
        # resharding the MoE dispatch chain every period). The 990 GiB/dev
        # all-gather = FSDP expert-weight gathers x 8 microbatches; experts
        # are touched every microbatch regardless of batch size, so gather
        # volume scales with microbatch COUNT. micro4 + pps2 keeps the
        # memory flat (stack halved back) and should halve the gathers:
        # predicted collective ~12 s ~ compute 11.7 s.
        ("micro4_pps2", "deepseek-v2-236b", "train_4k",
         {"micro_override": 4,
          "cfg_override": {"periods_per_scan_step": 2}}),
    ],
    "llama_prefill": [
        ("baseline", "llama3.2-1b", "prefill_32k", {}),
        # H1: the paper's insight applied to attention: Nystrom landmark
        # attention, m=1024 landmarks; napkin: attention score+value FLOPs
        # drop from O(S^2/2) to O(S*m): 32768/2/1024 = 16x on the attention
        # term (which is ~2.7x the FFN term at 32k).
        ("nystrom1024", "llama3.2-1b", "prefill_32k",
         {"cfg_override": {"attention_variant": "nystrom",
                           "n_landmarks": 1024}}),
        # H2: sliding window 8192 (quality trade documented): 4x on attention.
        ("sliding8k", "llama3.2-1b", "prefill_32k",
         {"cfg_override": {"attention_variant": "sliding", "window": 8192}}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None, choices=list(EXPERIMENTS))
    args = ap.parse_args()
    from repro.launch.dryrun import dryrun_one
    RESULTS.mkdir(parents=True, exist_ok=True)
    pairs = [args.pair] if args.pair else list(EXPERIMENTS)
    for pair in pairs:
        for tag, arch, shape, kw in EXPERIMENTS[pair]:
            out = RESULTS / f"{pair}__{tag}.json"
            if out.exists():
                print(f"[skip] {pair}/{tag}")
                continue
            print(f"[run ] {pair}/{tag}", flush=True)
            res = dryrun_one(arch, shape, verbose=False, **kw)
            out.write_text(json.dumps(res, indent=2))
            r = res["roofline"]
            print(f"   compute={r['compute_s']:.3f}s "
                  f"collective={r['collective_s']:.3f}s "
                  f"peak_tpu={res['memory']['modeled_peak_gib_tpu']}GiB",
                  flush=True)


if __name__ == "__main__":
    main()
