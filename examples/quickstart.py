"""Quickstart: train a Nystrom kernel SVM with distributed TRON (paper
Algorithm 1) end-to-end on synthetic covtype-like data, a few hundred TRON
iterations — the paper's kind of 'end-to-end driver'.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (KernelSpec, TronConfig, predict, random_basis, solve)
from repro.data import make_dataset

t0 = time.time()
X, y, Xt, yt, spec = make_dataset("covtype", jax.random.PRNGKey(0),
                                  scale=0.02, d_cap=54)
print(f"data: n={X.shape[0]:,} d={X.shape[1]} (covtype-like)")

kern = KernelSpec("gaussian", sigma=1.2)
for m in (64, 256, 1024):
    basis = random_basis(jax.random.PRNGKey(1), X, m)
    t = time.time()
    mach = solve(X, y, basis, lam=0.01, kernel=kern,
                 cfg=TronConfig(max_iter=300, grad_rtol=1e-4))
    acc = mach.accuracy(Xt, yt)
    print(f"m={m:5d}: test_acc={acc:.4f} TRON iters={int(mach.stats.n_iter)} "
          f"(fg={int(mach.stats.n_fg)}, Hd={int(mach.stats.n_hd)}) "
          f"solve={time.time() - t:.2f}s")

print(f"total {time.time() - t0:.1f}s — accuracy rises with m (paper Fig. 1)")
