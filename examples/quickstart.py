"""Quickstart: train a Nystrom kernel SVM through the unified KernelMachine
estimator on synthetic covtype-like data — the paper's end-to-end driver.
The solver (TRON on formulation (4)) and execution plan (local | shard_map |
auto | otf | otf_shard | stream) are config fields, not code paths; swap
them freely. (The runnable README quickstart is kept fresh by the
scripts/verify.sh docs smoke; this example adds the m-sweep.)

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_dataset

t0 = time.time()
X, y, Xt, yt, spec = make_dataset("covtype", jax.random.PRNGKey(0),
                                  scale=0.02, d_cap=54)
print(f"data: n={X.shape[0]:,} d={X.shape[1]} (covtype-like)")

config = MachineConfig(kernel=KernelSpec("gaussian", sigma=1.2), lam=0.01,
                       solver="tron", plan="local",
                       tron=TronConfig(max_iter=300, grad_rtol=1e-4))
for m in (64, 256, 1024):
    basis = random_basis(jax.random.PRNGKey(1), X, m)
    t = time.time()
    km = KernelMachine(config).fit(X, y, basis)
    r = km.result_
    print(f"m={m:5d}: test_acc={km.score(Xt, yt):.4f} TRON iters={r.n_iter} "
          f"(fg={r.n_fg}, Hd={r.n_hd}) solve={time.time() - t:.2f}s")

# the same machine, saved and reloaded for serving
km.save("/tmp/quickstart_machine.npz")
km2 = KernelMachine.load("/tmp/quickstart_machine.npz")
assert km2.score(Xt, yt) == km.score(Xt, yt)
print(f"total {time.time() - t0:.1f}s — accuracy rises with m (paper Fig. 1); "
      f"checkpoint round-trip OK")
