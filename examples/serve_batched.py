"""Batched multi-request serving across three cache disciplines:
full KV, sliding-window ring (sub-quadratic long-context), and an SSM
(attention-free, O(1) state) — the decode paths the 40-combo dry-run lowers.

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.train.steps import make_serve_step

CASES = [
    ("tinyllama-1.1b", {}, "full KV cache"),
    ("tinyllama-1.1b", {"attention_variant": "sliding", "window": 16},
     "sliding ring buffer (window=16)"),
    ("mamba2-1.3b", {}, "SSM O(1) state"),
]

for arch, over, desc in CASES:
    cfg = ARCHS[arch].reduced(**over)
    model = make_model(cfg, max_dec_seq=96)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    B, steps = 8, 48
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
    cache = model.init_cache(params, {"tokens": toks}, 96)
    serve = jax.jit(make_serve_step(model))
    toks, _, cache = serve(params, toks, cache)          # compile
    t0 = time.time()
    for _ in range(steps):
        toks, logits, cache = serve(params, toks, cache)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache)) / 1e6
    print(f"{arch:16s} [{desc:32s}] {B * steps / dt:7.1f} tok/s  "
          f"cache={cache_bytes:6.2f} MB")
