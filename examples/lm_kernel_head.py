"""The paper's technique as a first-class framework feature: a Nystrom
kernel head trained with TRON on frozen transformer features.

A tiny LM backbone embeds synthetic token sequences; sequence classification
is then learnt by (a) a LINEAR head and (b) the paper's Nystrom kernel
machine (formulation (4) + TRON) on the same pooled features. The kernel
head wins on this nonlinearly-separable task — the reason kernel heads on
features are useful at all.

  PYTHONPATH=src python examples/lm_kernel_head.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.api import KernelMachine, MachineConfig
from repro.configs import ARCHS
from repro.core import KernelSpec, TronConfig, random_basis
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.models.transformer import forward_lm

cfg = ARCHS["tinyllama-1.1b"].reduced()
model = make_model(cfg)
params, _ = unzip(model.init(jax.random.PRNGKey(0)))

key = jax.random.PRNGKey(1)
n, nt, S = 2048, 512, 32
tokens = jax.random.randint(key, (n + nt, S), 0, cfg.vocab)


@jax.jit
def features(toks):
    logits, _, _ = forward_lm(params, cfg, {"tokens": toks}, remat=False)
    # mean-pool the last hidden layer's logits as frozen features
    return jnp.tanh(logits.mean(axis=1))


print("extracting frozen backbone features...")
F = jnp.concatenate([features(tokens[i: i + 256])
                     for i in range(0, n + nt, 256)])

# task: labels from an RBF teacher ON THE FEATURES — nonlinear structure a
# linear probe cannot capture but a kernel head should (the reason one puts
# a kernel machine on top of representations at all).
kc, ka = jax.random.split(jax.random.PRNGKey(7))
centers = F[jax.random.choice(kc, n + nt, (16,), replace=False)]
alpha = jax.random.normal(ka, (16,))
d2 = jnp.sum((F[:, None, :] - centers[None]) ** 2, axis=-1)
sig_t = 0.35 * jnp.sqrt(jnp.median(d2))   # local kernels (avoid the
teacher = jnp.exp(-d2 / (2 * sig_t ** 2)) @ alpha   # near-linear regime)
labels = jnp.sign(teacher - jnp.median(teacher))

Ftr, ytr, Fte, yte = F[:n], labels[:n], F[n:], labels[n:]

t0 = time.time()
lin = KernelMachine(MachineConfig(kernel=KernelSpec("linear"), lam=1e-3,
                                  tron=TronConfig(max_iter=100))
                    ).fit(Ftr, ytr, Ftr[:128])
acc_lin = lin.score(Fte, yte)
print(f"linear head:        test_acc={acc_lin:.4f} ({time.time() - t0:.1f}s)")

t0 = time.time()
basis = random_basis(jax.random.PRNGKey(2), Ftr, 256)
rbf = KernelMachine(MachineConfig(
    kernel=KernelSpec("gaussian", sigma=float(sig_t) * 1.5), lam=1e-3,
    tron=TronConfig(max_iter=100))).fit(Ftr, ytr, basis)
acc_rbf = rbf.score(Fte, yte)
print(f"nystrom kernel head: test_acc={acc_rbf:.4f} "
      f"(m=256, TRON iters={rbf.result_.n_iter}, {time.time() - t0:.1f}s)")
assert acc_rbf >= acc_lin, "kernel head should beat linear on nonlinear task"
