"""Stage-wise basis addition (paper §3, a key advantage of formulation (4)):
grow m via KernelMachine.partial_fit — beta warm-started, only the NEW
columns of C computed. Compares against solving each stage from scratch.

  PYTHONPATH=src python examples/stagewise_basis_growth.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_dataset

X, y, Xt, yt, spec = make_dataset("covtype", jax.random.PRNGKey(0),
                                  scale=0.015, d_cap=54)
config = MachineConfig(kernel=KernelSpec("gaussian", sigma=1.2), lam=0.01,
                       tron=TronConfig(max_iter=200, grad_rtol=1e-4))

full = random_basis(jax.random.PRNGKey(1), X, 1024)
stages = [full[:128], full[128:384], full[384:1024]]

print("== stage-wise (partial_fit, warm-started) ==")
t0 = time.time()
km = KernelMachine(config)
for new_pts in stages:
    km.partial_fit(X, y, new_pts)
    r = km.result_
    print(f"  m={r.m:5d}: f={r.f:10.2f} iters={r.n_iter:3d} "
          f"test_acc={km.score(Xt, yt):.4f}")
t_warm = time.time() - t0

print("== from scratch at each m ==")
t0 = time.time()
for m in (128, 384, 1024):
    cold = KernelMachine(config).fit(X, y, full[:m])
    print(f"  m={m:5d}: f={cold.result_.f:10.2f} "
          f"iters={cold.result_.n_iter:3d}")
t_cold = time.time() - t0

n = X.shape[0]
evals_stage = n * 1024                      # only NEW columns per stage
evals_scratch = n * (128 + 384 + 1024)      # full C rebuilt at each m
print(f"kernel evaluations: stagewise {evals_stage:,} vs "
      f"from-scratch {evals_scratch:,} ({evals_scratch / evals_stage:.2f}x) — "
      f"formulation (4) reuses every computed column; (3) would also need "
      f"an incremental SVD of W at each stage.")
print(f"objectives match from-scratch at every stage (same optimum); "
      f"times: {t_warm:.1f}s vs {t_cold:.1f}s at this toy scale.")
