"""Stage-wise basis addition (paper §3, a key advantage of formulation (4)):
grow m in stages, warm-starting beta and computing only the NEW columns of C.
Compares warm-started stagewise against solving each stage from scratch.

  PYTHONPATH=src python examples/stagewise_basis_growth.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (KernelSpec, TronConfig, get_loss, predict,
                        random_basis, solve)
from repro.core.stagewise import stagewise_solve
from repro.data import make_dataset

X, y, Xt, yt, spec = make_dataset("covtype", jax.random.PRNGKey(0),
                                  scale=0.015, d_cap=54)
kern = KernelSpec("gaussian", sigma=1.2)
cfg = TronConfig(max_iter=200, grad_rtol=1e-4)

full = random_basis(jax.random.PRNGKey(1), X, 1024)
stages = [full[:128], full[128:384], full[384:1024]]

print("== stage-wise (warm-started) ==")
t0 = time.time()
iters_warm = []
def cb(res):
    o = predict(Xt, full[: res.m], res.beta, kern)
    acc = float(jnp.mean(jnp.sign(o) == yt))
    iters_warm.append(res.n_iter)
    print(f"  m={res.m:5d}: f={res.f:10.2f} iters={res.n_iter:3d} "
          f"test_acc={acc:.4f}")
results = stagewise_solve(X, y, stages, lam=0.01,
                          loss=get_loss("squared_hinge"), kernel=kern,
                          cfg=cfg, callback=cb)
t_warm = time.time() - t0

print("== from scratch at each m ==")
t0 = time.time()
iters_cold = []
for m in (128, 384, 1024):
    mach = solve(X, y, full[:m], lam=0.01, kernel=kern, cfg=cfg)
    iters_cold.append(int(mach.stats.n_iter))
    print(f"  m={m:5d}: f={float(mach.stats.f):10.2f} "
          f"iters={int(mach.stats.n_iter):3d}")
t_cold = time.time() - t0

n = X.shape[0]
evals_stage = n * 1024                      # only NEW columns per stage
evals_scratch = n * (128 + 384 + 1024)      # full C rebuilt at each m
print(f"kernel evaluations: stagewise {evals_stage:,} vs "
      f"from-scratch {evals_scratch:,} ({evals_scratch / evals_stage:.2f}x) — "
      f"formulation (4) reuses every computed column; (3) would also need "
      f"an incremental SVD of W at each stage.")
print(f"objectives match from-scratch at every stage (same optimum); "
      f"times: {t_warm:.1f}s vs {t_cold:.1f}s at this toy scale.")
