"""Worker program for the simulated multi-controller fleet (see rig.py).

Usage: ``worker.py <task> <num_processes> <process_id> <port> [extra...]``

The rig exports ``XLA_FLAGS=--xla_force_host_platform_device_count=K``
into this process's environment before Python starts, so plain jax
imports below already see K fake local devices; :func:`multihost.init`
then joins them into the ``num_processes * K``-device global mesh.

Process 0 prints ONE JSON line as its final stdout output — the task's
result payload the rig hands back to the test.
"""
import hashlib
import json
import sys
import time

import numpy as np

TASK, NPROC, PID, PORT = (sys.argv[1], int(sys.argv[2]), int(sys.argv[3]),
                          sys.argv[4])
EXTRA = sys.argv[5:]

from repro.sharding import multihost  # noqa: E402

multihost.init(f"127.0.0.1:{PORT}", NPROC, PID)

import jax  # noqa: E402

from repro.api import KernelMachine, MachineConfig  # noqa: E402
from repro.core import KernelSpec, TronConfig  # noqa: E402

M = 32


def _problem():
    """The conditioned parity problem: sigma=1 keeps the Nystrom W block
    near identity and lam=1e-1 keeps the objective strongly convex, so a
    1e-6 gradient tolerance pins beta well past the 1e-4 acceptance band
    (ill-conditioned problems amplify last-bit psum-association noise
    into macroscopic beta differences — that would test the conditioning,
    not the distribution)."""
    rng = np.random.default_rng(0)
    X = rng.standard_normal((512, 6)).astype(np.float32)
    w = rng.standard_normal(6)
    y = np.where(X @ w > 0, 1, -1).astype(np.int64)
    return X, y


def _config(plan, max_iter=200):
    return MachineConfig(kernel=KernelSpec("gaussian", sigma=1.0), lam=1e-1,
                         plan=plan, m=M,
                         tron=TronConfig(max_iter=max_iter, grad_rtol=1e-6))


def _beta_payload(km):
    beta32 = np.asarray(km.state_["beta"], np.float32)
    r = km.result_
    return {"beta": np.asarray(beta32, np.float64).ravel().tolist(),
            "beta_sha": hashlib.sha256(beta32.tobytes()).hexdigest(),
            "f": float(r.f), "n_iter": int(r.n_iter),
            "n_devices": jax.device_count(),
            "num_processes": multihost.process_count()}


def task_fit(plan):
    X, y = _problem()
    km = KernelMachine(_config(plan), mesh=multihost.spanning_mesh())
    km.fit(X, y)
    return _beta_payload(km)


def task_ckpt(mode, ckpt_dir, head_iters):
    """Checkpointed stream fit: 'full' runs uninterrupted (writing steps),
    'head' stops after ``head_iters`` outer iterations, 'resume' restores
    the newest step and finishes — all over whatever process count this
    fleet was launched with (elastic restore across P != P')."""
    from repro.checkpoint import CheckpointConfig
    X, y = _problem()
    max_iter = int(head_iters) if mode == "head" else 200
    ck = CheckpointConfig(dir=ckpt_dir, interval=1, keep=0, background=False,
                          resume=(mode == "resume"),
                          write=multihost.is_primary())
    km = KernelMachine(_config("stream", max_iter=max_iter),
                       mesh=multihost.spanning_mesh())
    km.fit(X, y, checkpoint=ck)
    multihost.sync("ckpt-done")      # step files durable on every exit path
    return _beta_payload(km)


def task_payload():
    """Instrumentation-count the cross-host bytes of one chunk evaluation
    (training) and one served request (SpanningServer) on the real
    process-spanning mesh."""
    from repro.core.distributed import DistConfig, DistributedNystrom
    from repro.core.introspect import collective_payload_bytes_jaxpr
    from repro.data.chunks import ArrayChunkSource
    from repro.sharding.multihost import SpanningServer

    X, y = _problem()
    basis = X[:M].copy()
    mesh = multihost.spanning_mesh()
    kern = KernelSpec("gaussian", sigma=1.0)
    solver = DistributedNystrom(mesh, 1e-1, "squared_hinge", kern,
                                DistConfig(fused=True, materialize=False))
    sc = solver.make_stream_closures(ArrayChunkSource(X, y, chunk_rows=128),
                                     basis)
    cr, d = sc.chunk_rows, X.shape[1]
    f32 = np.float32

    def count(fn, *shapes):
        with mesh:
            closed = jax.make_jaxpr(fn)(
                *[jax.ShapeDtypeStruct(s, f32) for s in shapes])
        return collective_payload_bytes_jaxpr(closed.jaxpr)

    fg_bytes = count(sc.fg_chunk, (cr, d), (cr,), (cr,), (M, d), (M,))
    hd_bytes = count(sc.hd_chunk, (cr, d), (cr,), (M, d), (M,))
    server = SpanningServer(basis, np.zeros((M,), f32), kern, mesh,
                            max_batch=64)
    out = {"m": M, "chunk_rows": cr, "n_chunks": sc.n_chunks,
           "itemsize": 4, "max_batch": 64,
           "fg_chunk_bytes": int(fg_bytes),
           "hd_chunk_bytes": int(hd_bytes),
           "serve_request_bytes": int(server.collective_payload_bytes())}
    server.stop()
    return out


def task_spin():
    """Lockstep broadcast rounds for ~5 minutes: the fault-injection
    target. A SIGKILLed peer must surface as a fleet failure long before
    the rounds run out."""
    deadline = time.time() + 300
    i = 0
    while time.time() < deadline:
        multihost.broadcast_from_primary(np.asarray([i], np.int64))
        i += 1
    return {"rounds": i}


def main():
    if TASK == "fit":
        out = task_fit(EXTRA[0])
    elif TASK == "ckpt":
        out = task_ckpt(EXTRA[0], EXTRA[1], EXTRA[2] if len(EXTRA) > 2 else 3)
    elif TASK == "payload":
        out = task_payload()
    elif TASK == "spin":
        out = task_spin()
    else:
        raise SystemExit(f"unknown task {TASK!r}")
    multihost.sync("task-done")
    if multihost.is_primary():
        print(json.dumps(out))


if __name__ == "__main__":
    main()
