"""Simulated multi-controller fleet runner for the multihost tests.

Spawns N copies of ``tests/multihost/worker.py`` — one subprocess per
simulated host, each forcing its own local device count via ``XLA_FLAGS``
*before* jax imports and joining a ``jax.distributed`` cluster on a
freshly bound localhost port. The rig is the fault model of the paper's
Hadoop deployment in miniature:

* a watchdog polls the fleet and kills every survivor the moment one
  worker exits nonzero (a hung gloo collective can never outlive the
  test timeout);
* ``kill=(pid, after_s)`` SIGKILLs a chosen worker mid-run to prove
  worker loss surfaces as a fast, attributable :class:`FleetError`
  rather than a hang;
* ``faults=FaultPlan`` generalizes that arm: the plan's fleet schedule
  (``.kill(pid, after_s)`` / ``.stall(pid, after_s, duration_s)``) is
  executed by the watchdog — SIGSTOP/SIGCONT stalls model a straggler or
  a paused VM rather than a death — and the plan's in-process rules ride
  into every worker via the ``REPRO_FAULTS`` environment variable;
* per-process logs are captured and attached to every failure.

Process 0's final stdout line is the worker's JSON result payload.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import socket
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

_HERE = os.path.dirname(os.path.abspath(__file__))
WORKER = os.path.join(_HERE, "worker.py")
SRC = os.path.join(_HERE, "..", "..", "src")


def free_port() -> int:
    """A currently free localhost TCP port for the coordinator."""
    s = socket.socket()
    try:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]
    finally:
        s.close()


@dataclass
class FleetResult:
    """A successful fleet run: process 0's JSON + per-process logs."""
    result: dict
    logs: List[str]
    returncodes: List[int]
    elapsed: float


class FleetError(RuntimeError):
    """A worker died (or the fleet hung): carries exit codes + log tails."""

    def __init__(self, message: str, returncodes: Sequence[Optional[int]],
                 logs: Sequence[str], elapsed: float):
        self.returncodes = list(returncodes)
        self.logs = list(logs)
        self.elapsed = elapsed
        tails = "\n".join(
            f"--- process {i} (rc={rc}) ---\n" + "\n".join(
                log.strip().splitlines()[-8:])
            for i, (rc, log) in enumerate(zip(returncodes, logs)))
        super().__init__(f"{message}\n{tails}")


def run_fleet(task: str, num_processes: int, devices_per_proc: int = 1, *,
              extra: Sequence[str] = (), timeout: float = 600.0,
              kill: Optional[Tuple[int, float]] = None,
              faults: Optional[Any] = None,
              env_extra: Optional[Dict[str, str]] = None) -> FleetResult:
    """Run ``worker.py <task> <nproc> <pid> <port> [extra...]`` N times.

    ``kill=(pid, after_s)`` SIGKILLs worker ``pid`` once it has been
    alive ``after_s`` seconds (the fault-injection arm); ``faults`` (a
    :class:`repro.faults.FaultPlan`) carries a whole schedule of kill and
    SIGSTOP/SIGCONT stall events, plus in-process rules shipped to every
    worker via ``REPRO_FAULTS``. Raises :class:`FleetError` on any
    nonzero exit or on timeout; the watchdog guarantees the failure is
    reported within ~``timeout`` seconds even when survivors block inside
    a collective.
    """
    events: List[Dict[str, Any]] = []
    if kill is not None:
        events.append({"kind": "kill", "pid": int(kill[0]),
                       "at": float(kill[1])})
    if faults is not None:
        events.extend(dict(e) for e in faults.schedule)
    port = free_port()
    workdir = tempfile.mkdtemp(prefix="mh-fleet-")
    procs: List[subprocess.Popen] = []
    logpaths = [os.path.join(workdir, f"proc{p}.log")
                for p in range(num_processes)]
    try:
        for p in range(num_processes):
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={devices_per_proc}")
            env.setdefault("JAX_PLATFORMS", "cpu")
            env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
            if faults is not None and faults.rules:
                env["REPRO_FAULTS"] = faults.to_json()
            env.update(env_extra or {})
            procs.append(subprocess.Popen(
                [sys.executable, WORKER, task, str(num_processes), str(p),
                 str(port), *extra],
                stdout=open(logpaths[p], "wb"), stderr=subprocess.STDOUT,
                env=env, cwd=workdir))

        t0 = time.monotonic()
        while True:
            rcs = [pr.poll() for pr in procs]
            elapsed = time.monotonic() - t0
            _run_events(events, elapsed, procs, rcs)
            if all(rc is not None for rc in rcs):
                break
            if any(rc not in (None, 0) for rc in rcs) \
                    or elapsed > timeout:
                for pr in procs:
                    if pr.poll() is None:
                        pr.kill()
                for pr in procs:
                    pr.wait()
                rcs = [pr.poll() for pr in procs]
                if elapsed > timeout:
                    raise FleetError(
                        f"fleet timed out after {elapsed:.1f}s "
                        f"(task={task!r}, {num_processes} processes)",
                        rcs, _read_logs(logpaths), elapsed)
                break
            time.sleep(0.05)

        rcs = [pr.returncode for pr in procs]
        logs = _read_logs(logpaths)
        elapsed = time.monotonic() - t0
        if any(rc != 0 for rc in rcs):
            dead = next(i for i, rc in enumerate(rcs) if rc != 0)
            raise FleetError(
                f"process {dead} of task {task!r} exited rc={rcs[dead]}; "
                f"remaining workers were killed {elapsed:.1f}s in",
                rcs, logs, elapsed)
        try:
            result = json.loads(logs[0].strip().splitlines()[-1])
        except (IndexError, ValueError) as e:
            raise FleetError(
                f"process 0 of task {task!r} produced no JSON result ({e})",
                rcs, logs, elapsed)
        return FleetResult(result=result, logs=logs, returncodes=rcs,
                           elapsed=elapsed)
    finally:
        for pr in procs:
            if pr.poll() is None:
                pr.kill()
        shutil.rmtree(workdir, ignore_errors=True)


def _run_events(events: List[Dict[str, Any]], elapsed: float,
                procs: Sequence[subprocess.Popen],
                rcs: Sequence[Optional[int]]) -> None:
    """Execute due fleet fault events (kill / stall) against live workers.

    SIGKILL needs no unstick step (it terminates stopped processes too);
    stalls send SIGSTOP at ``at`` and SIGCONT at ``at + duration`` —
    peers block inside their next collective until the straggler resumes,
    so stall durations must stay well under the collective timeout."""
    for e in events:
        pid = e["pid"]
        if not 0 <= pid < len(procs) or rcs[pid] is not None:
            continue
        if e["kind"] == "kill":
            if not e.get("done") and elapsed >= e["at"]:
                procs[pid].kill()
                e["done"] = True
        elif e["kind"] == "stall":
            if not e.get("stopped") and elapsed >= e["at"]:
                procs[pid].send_signal(signal.SIGSTOP)
                e["stopped"] = True
            if e.get("stopped") and not e.get("done") \
                    and elapsed >= e["at"] + e["duration"]:
                try:
                    procs[pid].send_signal(signal.SIGCONT)
                except ProcessLookupError:
                    pass
                e["done"] = True


def _read_logs(paths: Sequence[str]) -> List[str]:
    out = []
    for path in paths:
        try:
            with open(path, "r", errors="replace") as fh:
                out.append(fh.read())
        except OSError:
            out.append("")
    return out
