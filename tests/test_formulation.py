"""Formulation (4): analytic grad/Hd vs autodiff; equivalence with (3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Formulation4, KernelSpec, TronConfig, build_C, build_W,
                        get_loss, random_basis, solve)
from repro.core.linearized import solve_linearized
from repro.core.nystrom import nystrom_approx_kernel
from repro.data import make_classification


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    X, y = make_classification(key, 512, 10, clusters_per_class=3)
    kern = KernelSpec("gaussian", sigma=2.0)
    basis = random_basis(jax.random.PRNGKey(1), X, 64)
    C = build_C(X, basis, kern)
    W = build_W(basis, kern)
    return X, y, basis, kern, C, W


@pytest.mark.parametrize("loss_name", ["squared_hinge", "logistic", "squared"])
def test_grad_matches_autodiff(setup, loss_name):
    X, y, basis, kern, C, W = setup
    form = Formulation4(lam=0.7, loss=get_loss(loss_name))
    beta = jax.random.normal(jax.random.PRNGKey(2), (64,)) * 0.1
    f, g, D = form.fgrad(C, W, y, beta)
    f2, g2 = jax.value_and_grad(lambda b: form.value(C, W, y, b))(beta)
    np.testing.assert_allclose(f, f2, rtol=1e-5)
    np.testing.assert_allclose(g, g2, rtol=1e-4, atol=1e-4)


def test_hessd_matches_gauss_newton(setup):
    """For the squared loss the Gauss-Newton product IS the Hessian product."""
    X, y, basis, kern, C, W = setup
    form = Formulation4(lam=0.7, loss=get_loss("squared"))
    beta = jax.random.normal(jax.random.PRNGKey(3), (64,)) * 0.1
    d = jax.random.normal(jax.random.PRNGKey(4), (64,))
    _, _, D = form.fgrad(C, W, y, beta)
    hd = form.hessd(C, W, D, d)
    hd2 = jax.jvp(jax.grad(lambda b: form.value(C, W, y, b)), (beta,), (d,))[1]
    np.testing.assert_allclose(hd, hd2, rtol=1e-4, atol=1e-4)


def test_formulations_3_and_4_equivalent(setup):
    X, y, basis, kern, C, W = setup
    mach4 = solve(X, y, basis, lam=1.0, kernel=kern,
                  cfg=TronConfig(max_iter=100, grad_rtol=1e-5))
    res3 = solve_linearized(X, y, basis, lam=1.0,
                            loss=get_loss("squared_hinge"), kernel=kern,
                            cfg=TronConfig(max_iter=100, grad_rtol=1e-5))
    o4 = C @ mach4.beta
    o3 = C @ res3.beta
    # same optimum => same decision function values
    np.testing.assert_allclose(o3, o4, rtol=5e-2, atol=5e-2)
    assert abs(float(mach4.stats.f) - res3.f) / abs(res3.f) < 1e-2


def test_nystrom_approximation_improves_with_m():
    """||K - C W^+ C^T|| decreases as m grows (paper §2.1)."""
    key = jax.random.PRNGKey(5)
    X, _ = make_classification(key, 256, 8, clusters_per_class=3)
    kern = KernelSpec("gaussian", sigma=2.0)
    K = build_C(X, X, kern)
    errs = []
    for m in (16, 64, 256):
        basis = random_basis(jax.random.PRNGKey(6), X, m)
        Kt = nystrom_approx_kernel(X, basis, kern)
        errs.append(float(jnp.linalg.norm(K - Kt)))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 1e-2 * float(jnp.linalg.norm(K))  # m=n => near-exact
