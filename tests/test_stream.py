"""Chunked dataset layer (repro.data.chunks) + out-of-core stream fits.

The chunk sources are the foundation the ``stream`` execution plan stands
on: chunk addressing, shard-spanning reads, mmap round-trips, and row
gathers must be exact before any solver math runs over them. The fit tests
here exercise the paths test_plans' in-memory matrix cannot: training
straight from a shard directory and checkpoint round-trips of StreamConfig.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import KernelMachine, MachineConfig, StreamConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data.chunks import (ArrayChunkSource, MmapChunkSource,
                               as_chunk_source, random_basis_from_source,
                               save_chunks)
from repro.data import make_classification

N, D, M = 256, 8, 32


@pytest.fixture(scope="module")
def data():
    X, y = make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=2)
    return np.asarray(X), np.asarray(y)


@pytest.fixture(scope="module")
def shard_dir(data, tmp_path_factory):
    """Dataset written as .npy shard pairs whose boundaries (100 rows) do
    NOT align with any chunk size the tests use."""
    d = tmp_path_factory.mktemp("shards")
    save_chunks(d, *data, rows_per_shard=100)
    return d


# ------------------------------------------------------------- chunk sources
def test_array_source_chunks_cover_exactly(data):
    X, y = data
    src = ArrayChunkSource(X, y, chunk_rows=48)
    assert src.shape == (N, D) and src.n_chunks == -(-N // 48)
    Xcat = np.concatenate([c[0] for c in src.iter_chunks()])
    ycat = np.concatenate([c[1] for c in src.iter_chunks()])
    np.testing.assert_array_equal(Xcat, X)
    np.testing.assert_array_equal(ycat, y)


@pytest.mark.parametrize("compress", [False, True])
def test_mmap_source_round_trip(data, tmp_path, compress):
    X, y = data
    save_chunks(tmp_path, X, y, rows_per_shard=90, compress=compress)
    src = MmapChunkSource(tmp_path, chunk_rows=48)
    assert src.shape == (N, D)
    Xcat = np.concatenate([c[0] for c in src.iter_chunks()])
    ycat = np.concatenate([c[1] for c in src.iter_chunks()])
    np.testing.assert_array_equal(Xcat, X)
    np.testing.assert_array_equal(ycat, y)


def test_chunk_spanning_shard_boundary(data, shard_dir):
    """One chunk read crossing a shard file boundary must stitch exactly."""
    X, _ = data
    src = MmapChunkSource(shard_dir, chunk_rows=96)
    Xc, _ = src.chunk(1)                    # rows 96..192 span shard 0|1
    np.testing.assert_array_equal(Xc, X[96:192])


def test_take_rows_unsorted_across_shards(data, shard_dir):
    X, _ = data
    src = MmapChunkSource(shard_dir, chunk_rows=64)
    idx = np.array([250, 0, 99, 100, 101, 7, 199])
    np.testing.assert_array_equal(src.take_rows(idx), X[idx])


def test_random_basis_from_source_matches_in_memory(data, shard_dir):
    """Same key -> the streamed gather picks exactly the rows the in-memory
    random_basis would."""
    X, _ = data
    key = jax.random.PRNGKey(3)
    want = np.asarray(random_basis(key, jnp.asarray(X), M))
    got = random_basis_from_source(key, MmapChunkSource(shard_dir), M)
    np.testing.assert_array_equal(got, want)


def test_as_chunk_source_coercions(data, shard_dir):
    X, y = data
    src = as_chunk_source(X, y, chunk_rows=32)
    assert isinstance(src, ArrayChunkSource) and src.chunk_rows == 32
    assert as_chunk_source(src) is src
    assert as_chunk_source(src, chunk_rows=16).chunk_rows == 16
    assert isinstance(as_chunk_source(shard_dir), MmapChunkSource)
    with pytest.raises(ValueError, match="needs y"):
        as_chunk_source(X)


def test_bad_shard_dirs_rejected(tmp_path):
    with pytest.raises(FileNotFoundError, match="no X_"):
        MmapChunkSource(tmp_path)
    with pytest.raises(FileNotFoundError, match="not a directory"):
        MmapChunkSource(tmp_path / "nope")


# ---------------------------------------------------------- streaming fits
CFG = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=0.5,
                    plan="stream", tron=TronConfig(max_iter=200,
                                                   grad_rtol=1e-5),
                    stream=StreamConfig(chunk_rows=64))


def test_fit_from_shard_directory_matches_local(data, shard_dir):
    """The out-of-core acceptance path: fit straight from disk shards,
    same optimum as the in-memory local plan."""
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    ref = KernelMachine(CFG.replace(plan="local")).fit(X, y, basis)
    src = MmapChunkSource(shard_dir, chunk_rows=64)
    km = KernelMachine(CFG).fit(src, None, basis)
    b, br = np.asarray(km.state_["beta"]), np.asarray(ref.state_["beta"])
    assert np.linalg.norm(b - br) / np.linalg.norm(br) < 1e-4


def test_fit_source_with_auto_basis_and_predict(data, shard_dir):
    """basis=None over a chunked source samples m rows without a full read;
    the fitted machine serves in-memory queries as usual."""
    X, y = data
    src = MmapChunkSource(shard_dir)
    km = KernelMachine(CFG.replace(m=M)).fit(src, None)
    assert km.state_["basis"].shape == (M, D)
    assert km.score(X[:64], y[:64]) > 0.8


def test_stream_config_checkpoint_round_trip(tmp_path, data):
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    km = KernelMachine(CFG.replace(
        stream=StreamConfig(chunk_rows=32, mmap=False))).fit(X, y, basis)
    path = str(tmp_path / "m.npz")
    km.save(path)
    km2 = KernelMachine.load(path)
    assert km2.config == km.config
    assert km2.config.stream == StreamConfig(chunk_rows=32, mmap=False)
    o1, o2 = km.decision_function(X[:16]), km2.decision_function(X[:16])
    assert float(jnp.max(jnp.abs(o1 - o2))) == 0.0


def test_rff_solver_rejects_chunk_source(data, shard_dir):
    with pytest.raises(TypeError, match="needs X in memory"):
        KernelMachine(CFG.replace(solver="rff")).fit(
            MmapChunkSource(shard_dir), None)
