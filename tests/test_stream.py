"""Chunked dataset layer (repro.data.chunks) + out-of-core stream fits.

The chunk sources are the foundation the ``stream`` execution plan stands
on: chunk addressing, shard-spanning reads, mmap round-trips, and row
gathers must be exact before any solver math runs over them. The fit tests
here exercise the paths test_plans' in-memory matrix cannot: training
straight from a shard directory and checkpoint round-trips of StreamConfig.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import KernelMachine, MachineConfig, StreamConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data.chunks import (ArrayChunkSource, MmapChunkSource,
                               as_chunk_source, random_basis_from_source,
                               save_chunks)
from repro.data import make_classification

N, D, M = 256, 8, 32


@pytest.fixture(scope="module")
def data():
    X, y = make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=2)
    return np.asarray(X), np.asarray(y)


@pytest.fixture(scope="module")
def shard_dir(data, tmp_path_factory):
    """Dataset written as .npy shard pairs whose boundaries (100 rows) do
    NOT align with any chunk size the tests use."""
    d = tmp_path_factory.mktemp("shards")
    save_chunks(d, *data, rows_per_shard=100)
    return d


# ------------------------------------------------------------- chunk sources
def test_array_source_chunks_cover_exactly(data):
    X, y = data
    src = ArrayChunkSource(X, y, chunk_rows=48)
    assert src.shape == (N, D) and src.n_chunks == -(-N // 48)
    Xcat = np.concatenate([c[0] for c in src.iter_chunks()])
    ycat = np.concatenate([c[1] for c in src.iter_chunks()])
    np.testing.assert_array_equal(Xcat, X)
    np.testing.assert_array_equal(ycat, y)


@pytest.mark.parametrize("compress", [False, True])
def test_mmap_source_round_trip(data, tmp_path, compress):
    X, y = data
    save_chunks(tmp_path, X, y, rows_per_shard=90, compress=compress)
    src = MmapChunkSource(tmp_path, chunk_rows=48)
    assert src.shape == (N, D)
    Xcat = np.concatenate([c[0] for c in src.iter_chunks()])
    ycat = np.concatenate([c[1] for c in src.iter_chunks()])
    np.testing.assert_array_equal(Xcat, X)
    np.testing.assert_array_equal(ycat, y)


def test_chunk_spanning_shard_boundary(data, shard_dir):
    """One chunk read crossing a shard file boundary must stitch exactly."""
    X, _ = data
    src = MmapChunkSource(shard_dir, chunk_rows=96)
    Xc, _ = src.chunk(1)                    # rows 96..192 span shard 0|1
    np.testing.assert_array_equal(Xc, X[96:192])


def test_take_rows_unsorted_across_shards(data, shard_dir):
    X, _ = data
    src = MmapChunkSource(shard_dir, chunk_rows=64)
    idx = np.array([250, 0, 99, 100, 101, 7, 199])
    np.testing.assert_array_equal(src.take_rows(idx), X[idx])


def test_random_basis_from_source_matches_in_memory(data, shard_dir):
    """Same key -> the streamed gather picks exactly the rows the in-memory
    random_basis would."""
    X, _ = data
    key = jax.random.PRNGKey(3)
    want = np.asarray(random_basis(key, jnp.asarray(X), M))
    got = random_basis_from_source(key, MmapChunkSource(shard_dir), M)
    np.testing.assert_array_equal(got, want)


def test_as_chunk_source_coercions(data, shard_dir):
    X, y = data
    src = as_chunk_source(X, y, chunk_rows=32)
    assert isinstance(src, ArrayChunkSource) and src.chunk_rows == 32
    assert as_chunk_source(src) is src
    assert as_chunk_source(src, chunk_rows=16).chunk_rows == 16
    assert isinstance(as_chunk_source(shard_dir), MmapChunkSource)
    with pytest.raises(ValueError, match="needs y"):
        as_chunk_source(X)


def test_bad_shard_dirs_rejected(tmp_path):
    with pytest.raises(FileNotFoundError, match="no X_"):
        MmapChunkSource(tmp_path)
    with pytest.raises(FileNotFoundError, match="not a directory"):
        MmapChunkSource(tmp_path / "nope")


# ---------------------------------------------------------- streaming fits
CFG = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=0.5,
                    plan="stream", tron=TronConfig(max_iter=200,
                                                   grad_rtol=1e-5),
                    stream=StreamConfig(chunk_rows=64))


def test_fit_from_shard_directory_matches_local(data, shard_dir):
    """The out-of-core acceptance path: fit straight from disk shards,
    same optimum as the in-memory local plan."""
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    ref = KernelMachine(CFG.replace(plan="local")).fit(X, y, basis)
    src = MmapChunkSource(shard_dir, chunk_rows=64)
    km = KernelMachine(CFG).fit(src, None, basis)
    b, br = np.asarray(km.state_["beta"]), np.asarray(ref.state_["beta"])
    assert np.linalg.norm(b - br) / np.linalg.norm(br) < 1e-4


def test_fit_source_with_auto_basis_and_predict(data, shard_dir):
    """basis=None over a chunked source samples m rows without a full read;
    the fitted machine serves in-memory queries as usual."""
    X, y = data
    src = MmapChunkSource(shard_dir)
    km = KernelMachine(CFG.replace(m=M)).fit(src, None)
    assert km.state_["basis"].shape == (M, D)
    assert km.score(X[:64], y[:64]) > 0.8


def test_stream_config_checkpoint_round_trip(tmp_path, data):
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    km = KernelMachine(CFG.replace(
        stream=StreamConfig(chunk_rows=32, mmap=False))).fit(X, y, basis)
    path = str(tmp_path / "m.npz")
    km.save(path)
    km2 = KernelMachine.load(path)
    assert km2.config == km.config
    assert km2.config.stream == StreamConfig(chunk_rows=32, mmap=False)
    o1, o2 = km.decision_function(X[:16]), km2.decision_function(X[:16])
    assert float(jnp.max(jnp.abs(o1 - o2))) == 0.0


def test_rff_solver_rejects_chunk_source(data, shard_dir):
    with pytest.raises(TypeError, match="needs X in memory"):
        KernelMachine(CFG.replace(solver="rff")).fit(
            MmapChunkSource(shard_dir), None)


# ------------------------------------------------- out-of-core scoring
def test_decision_function_accepts_mmap_source(data, shard_dir):
    """Acceptance: a stream-plan machine scores a shard-directory test set
    straight from disk — margins, chunk iterator, and score all match the
    in-memory evaluation."""
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    km = KernelMachine(CFG).fit(X, y, basis)
    src = MmapChunkSource(shard_dir, chunk_rows=64)
    o_disk = km.decision_function(src)
    o_mem = np.asarray(km.decision_function(X, plan="local"))
    assert isinstance(o_disk, np.ndarray) and o_disk.shape == (N,)
    assert np.max(np.abs(o_disk - o_mem)) < 1e-5
    # a shard-directory PATH routes the same way
    o_path = km.decision_function(str(shard_dir))
    np.testing.assert_array_equal(o_path, o_disk)
    # score with y=None reads labels from the source's y shards
    assert km.score(src) == km.score(X, y)
    # chunked prediction iterator covers the set exactly, in order
    preds = np.concatenate(list(km.predict_chunks(src)))
    np.testing.assert_array_equal(preds, np.asarray(km.predict(X)))


def test_chunked_source_rejected_by_in_memory_plans(data, shard_dir):
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    km = KernelMachine(CFG.replace(plan="local")).fit(X, y, basis)
    src = MmapChunkSource(shard_dir)
    # no explicit plan: chunked inputs auto-route through 'stream'
    assert km.decision_function(src).shape == (N,)
    with pytest.raises(ValueError, match="stream"):
        km.decision_function(src, plan="local")


def test_labelless_source_scoring_needs_explicit_y(data):
    """A y=None ArrayChunkSource (inference view) must refuse
    label-from-source scoring instead of silently grading against its
    synthetic zero labels; passing y explicitly still works, and matches
    the in-memory path exactly even at a non-power-of-two n."""
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    km = KernelMachine(CFG).fit(X, y, basis)
    src = ArrayChunkSource(X[:200], None, chunk_rows=48)   # ragged, no labels
    assert km.decision_function(src).shape == (200,)       # margins: fine
    with pytest.raises(ValueError, match="without labels"):
        km.score(src)
    assert km.score(src, y[:200]) == km.score(X[:200], y[:200])


def test_stream_multiclass_scoring_from_disk(data, tmp_path):
    """One multi-RHS margin pass per chunk: (n, K) margins from disk match
    the local dense reference; chunked score equals in-memory score."""
    X, _ = data
    yi = (np.argmax(np.asarray(X[:, :3]), axis=1)).astype(np.int64)
    save_chunks(tmp_path, X, yi, rows_per_shard=100)
    src = MmapChunkSource(tmp_path, chunk_rows=64)
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    km = KernelMachine(CFG).fit(src, None, basis)
    o_disk = km.decision_function(src)
    assert o_disk.shape == (N, 3)
    o_mem = np.asarray(km.decision_function(X, plan="local"))
    assert np.max(np.abs(o_disk - o_mem)) < 1e-5
    assert km.score(src) == km.score(X, yi)


# -------------------------------------------- chunk I/O pipeline (_ChunkFeeder)
def _stream_closures(data, chunk_rows=48, cache_chunks=None, prefetch=2,
                     classes=None):
    from repro.core.compat import make_mesh
    from repro.core.distributed import DistConfig, DistributedNystrom
    X, y = data
    mesh = make_mesh((1,), ("data",))
    solver = DistributedNystrom(
        mesh, 0.5, "squared_hinge", KernelSpec("gaussian", sigma=2.0),
        DistConfig(materialize=False, fused=True))
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    src = ArrayChunkSource(X, y, chunk_rows)
    return solver.make_stream_closures(src, basis, classes=classes,
                                       cache_chunks=cache_chunks,
                                       prefetch=prefetch), basis


@pytest.mark.parametrize("cache_chunks,prefetch", [(0, 0), (0, 2), (2, 2),
                                                   (None, 2), (None, 4)])
def test_feeder_cache_and_prefetch_invariance(data, cache_chunks, prefetch):
    """Every cache size x prefetch depth yields the same f/g/Hd values as
    the synchronous uncached walk — the pipeline changes WHEN bytes move,
    never what is computed."""
    sc0, basis = _stream_closures(data, cache_chunks=0, prefetch=0)
    sc, _ = _stream_closures(data, cache_chunks=cache_chunks,
                             prefetch=prefetch)
    b = np.linspace(-1, 1, M).astype(np.float32)
    f0, g0, aux0 = sc0.fgrad(b)
    f1, g1, aux1 = sc.fgrad(b)
    assert float(f0) == float(f1)
    np.testing.assert_array_equal(g0, g1)
    h0 = sc0.hessd(aux0, g0)
    h1 = sc.hessd(aux1, g1)
    np.testing.assert_array_equal(h0, h1)
    # and again with the cache warm
    np.testing.assert_array_equal(h0, sc.hessd(aux1, g1))


def test_feeder_device_cache_stops_retransfer(data):
    """Acceptance: with the chunk cache warm, repeated evaluations move
    zero host->device bytes; with the cache off, every evaluation re-pays
    the full transfer (the PR 3 behavior)."""
    sc_on, basis = _stream_closures(data, cache_chunks=None)   # auto: all fit
    sc_off, _ = _stream_closures(data, cache_chunks=0)
    assert sc_on.feeder.cache_chunks == sc_on.n_chunks
    b = np.zeros((M,), np.float32)
    _, _, aux_on = sc_on.fgrad(b)
    warm = sc_on.feeder.h2d_bytes
    sc_on.hessd(aux_on, b)
    sc_on.hessd(aux_on, b)
    assert sc_on.feeder.h2d_bytes == warm        # zero new bytes when warm
    _, _, aux_off = sc_off.fgrad(b)
    cold = sc_off.feeder.h2d_bytes
    sc_off.hessd(aux_off, b)
    assert sc_off.feeder.h2d_bytes > cold        # uncached: re-transfers


def test_feeder_host_cache_pads_ragged_chunk_once(data):
    """Satellite: the padded host arrays (ragged-tail X, y targets, weight
    mask) are built once per chunk and reused across evaluations — but
    full-size X chunks are NOT host-cached (out-of-core contract)."""
    X, y = data
    sc, _ = _stream_closures((X[:200], y[:200]), chunk_rows=48,
                             cache_chunks=0)
    feeder = sc.feeder
    first = [feeder._host_chunk(i) for i in range(feeder.source.n_chunks)]
    second = [feeder._host_chunk(i) for i in range(feeder.source.n_chunks)]
    for i, ((X1, y1, w1), (X2, y2, w2)) in enumerate(zip(first, second)):
        assert y1 is y2 and w1 is w2             # mask/targets cached
        ragged = (i == feeder.source.n_chunks - 1)
        assert (X1 is X2) == ragged              # only the padded tail is
        assert X1.shape == (48, D)               # held; full chunks re-read
    np.testing.assert_array_equal(first[-1][0][8:], 0.0)   # 200 = 4*48 + 8
    np.testing.assert_array_equal(first[-1][2][:8], 1.0)
    np.testing.assert_array_equal(first[-1][2][8:], 0.0)


def test_feeder_prefetch_propagates_errors(data):
    """An exception in the background reader surfaces to the caller (not a
    hang, not a swallowed thread death)."""
    sc, _ = _stream_closures(data, cache_chunks=0, prefetch=2)

    class Boom(RuntimeError):
        pass

    def explode(i):
        raise Boom("disk on fire")

    sc.feeder.source.chunk = explode
    with pytest.raises(Boom, match="disk on fire"):
        list(sc.feeder.chunks())


def test_stream_multiclass_from_shard_directory(data, tmp_path):
    """Out-of-core one-vs-rest: integer labels live in .npy shards, class
    discovery reads only the y files, each chunk expands to ±1 targets on
    the host, and the fit matches the in-memory local multi-RHS fit."""
    X, _ = data
    yi = (np.argmax(np.asarray(X[:, :3]), axis=1)).astype(np.int64)
    save_chunks(tmp_path, X, yi, rows_per_shard=100)
    src = MmapChunkSource(tmp_path, chunk_rows=64)
    np.testing.assert_array_equal(np.asarray(src.unique_labels()), [0, 1, 2])
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    km = KernelMachine(CFG).fit(src, None, basis)
    assert km.state_["beta"].shape == (M, 3)
    ref = KernelMachine(CFG.replace(plan="local")).fit(X, jnp.asarray(yi),
                                                       basis)
    b, br = np.asarray(km.state_["beta"]), np.asarray(ref.state_["beta"])
    assert np.linalg.norm(b - br) / np.linalg.norm(br) < 5e-3
    assert km.score(X[:64], yi[:64]) == ref.score(X[:64], yi[:64])


def test_stream_config_new_knobs_round_trip(tmp_path, data):
    """cache_chunks/prefetch survive save/load; configs written before
    the knobs existed (no such keys) still load with defaults."""
    X, y = data
    basis = np.asarray(random_basis(jax.random.PRNGKey(2), jnp.asarray(X), M))
    sconf = StreamConfig(chunk_rows=32, cache_chunks=1, prefetch=0)
    km = KernelMachine(CFG.replace(stream=sconf)).fit(X, y, basis)
    path = str(tmp_path / "m.npz")
    km.save(path)
    assert KernelMachine.load(path).config.stream == sconf
    legacy = CFG.stream.__class__(**{"chunk_rows": 16})   # pre-knob dict
    assert legacy.cache_chunks is None and legacy.prefetch == 2
