"""Infrastructure tests: optimizer, checkpoint, microbatching, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import ARCHS
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.sgd import sgd_init, sgd_update
from repro.sharding.partitioning import DEFAULT_RULES, FSDP, spec_for_axes
from repro.train.steps import make_train_step


def test_adamw_matches_reference_scalar():
    """One AdamW step against the textbook update."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.5])}
    st = adamw_init(p, cfg)
    p2, st2 = adamw_update(g, st, p, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = 2.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(p2["w"], want, rtol=1e-6)
    assert int(st2["step"]) == 1


def test_adamw_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype="bfloat16")
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(p, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    p2, st2 = adamw_update({"w": jnp.ones((4,), jnp.bfloat16)}, st, p, cfg)
    assert st2["v"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


def test_sgd_descends_quadratic():
    p = {"w": jnp.array(4.0)}
    st = sgd_init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st = sgd_update(g, st, p, lr=0.02, momentum=0.5)
    assert abs(float(p["w"])) < 0.1


def test_checkpoint_roundtrip():
    cfg = ARCHS["tinyllama-1.1b"].reduced()
    model = make_model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, params, {"arch": cfg.name})
        loaded = load_checkpoint(path, params)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), params, loaded)


def test_microbatched_train_step_matches_full_batch():
    """Gradient accumulation over 4 microbatches == one full-batch step."""
    cfg = ARCHS["tinyllama-1.1b"].reduced(dtype="float32")
    model = make_model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                          cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                          cfg.vocab)}
    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)
    p1, _, m1 = jax.jit(make_train_step(model, ocfg))(params, opt, batch)
    p4, _, m4 = jax.jit(make_train_step(model, ocfg, microbatches=4))(
        params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_sharding_rules_cover_all_model_axes():
    """Every logical axis used by any arch's params has a rule."""
    for name, cfg in ARCHS.items():
        model = make_model(cfg.reduced())
        ann = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        _, axes = unzip(ann)
        for t in jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple)):
            for ax in t:
                assert ax in DEFAULT_RULES, f"{name}: unknown axis {ax!r}"


def test_spec_for_axes_fsdp_resolution():
    from repro.core.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    spec = spec_for_axes(("embed", "ffn"), mesh)
    assert spec == jax.sharding.PartitionSpec(("data",), "model")


def test_sharded_loader_and_kernel_dataset():
    from repro.data.pipeline import ShardedLoader, shard_kernel_dataset, synthetic_lm_loader
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1), ("data", "model"))
    cfg = ARCHS["tinyllama-1.1b"].reduced()
    loader = synthetic_lm_loader(mesh, cfg, batch=2, seq=16)
    it = iter(loader)
    b1 = next(it)
    b2 = next(it)
    assert b1["tokens"].shape == (2, 16)
    assert not bool(jnp.all(b1["tokens"] == b2["tokens"]))  # streams differ
    # kernel dataset sharding truncates to divisible rows
    X = jnp.ones((10, 4)); y = jnp.ones((10,))
    Xs, ys = shard_kernel_dataset(mesh, X, y)
    assert Xs.shape[0] == 10 and ys.shape == (10,)
