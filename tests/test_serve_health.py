"""Self-healing serving: circuit breaker + health gauge under injected
dispatch faults.

Satellite 2's regression lives here — a dispatcher that raises mid-batch
fails exactly that batch's requests and nothing else; the engine keeps
serving. On top of that, the tentpole's breaker contract: a persistently
failing model OPENs its circuit (fast :class:`CircuitOpen` rejections,
health DEGRADED), other models keep serving, and once the fault clears a
half-open probe re-CLOSEs the circuit and health returns to READY.
"""
import time

import jax
import numpy as np
import pytest

from repro.api import KernelMachine, MachineConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_classification, make_multiclass
from repro.faults import FaultPlan
from repro.serve import (DEGRADED, READY, STARTING, CircuitBreaker,
                         CircuitOpen, EngineConfig, ModelRegistry,
                         ServeEngine)

N, D, M = 256, 8, 16
CFG = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=1.0,
                    tron=TronConfig(max_iter=40))


@pytest.fixture(scope="module")
def km():
    X, y = make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=4)
    return KernelMachine(CFG).fit(X, y, random_basis(jax.random.PRNGKey(1),
                                                     X, M))


@pytest.fixture(scope="module")
def km_mc():
    X, y = make_multiclass(jax.random.PRNGKey(0), N, D, 3,
                           clusters_per_class=2)
    return KernelMachine(CFG).fit(X, y, random_basis(jax.random.PRNGKey(1),
                                                     X, M))


@pytest.fixture(scope="module")
def registry(km, km_mc):
    reg = ModelRegistry(max_batch=32)
    reg.add("bin", km)
    reg.add("mc3", km_mc)
    reg.warmup()
    return reg


# -------------------------------------------------- breaker state machine
def test_breaker_opens_probes_and_recloses():
    t = [0.0]
    br = CircuitBreaker(threshold=3, cooldown_s=1.0, clock=lambda: t[0])
    assert br.allow()
    assert not br.record_failure()
    assert not br.record_failure()
    assert br.record_failure()                  # 3rd consecutive: opens
    assert br.state == br.OPEN
    assert not br.allow()                       # fast-reject during cooldown
    t[0] = 1.5
    assert br.allow()                           # half-open: one probe
    assert br.state == br.HALF_OPEN
    assert not br.allow()                       # second caller: still blocked
    assert br.record_success()                  # probe ok: re-closed
    assert br.state == br.CLOSED
    assert br.allow()


def test_failed_probe_reopens():
    t = [0.0]
    br = CircuitBreaker(threshold=1, cooldown_s=1.0, clock=lambda: t[0])
    assert br.record_failure()
    t[0] = 1.1
    assert br.allow()
    assert br.record_failure()                  # probe failed: re-opened
    assert br.state == br.OPEN
    assert not br.allow()
    t[0] = 2.5
    assert br.allow()                           # next cooldown: probes again


def test_lost_probe_expires():
    """A probe whose outcome never reports (request timed out in queue)
    must not wedge the breaker in HALF_OPEN forever."""
    t = [0.0]
    br = CircuitBreaker(threshold=1, cooldown_s=1.0, clock=lambda: t[0])
    br.record_failure()
    t[0] = 1.1
    assert br.allow()                           # probe admitted, never reports
    assert not br.allow()
    t[0] = 2.5
    assert br.allow()                           # lost probe expired: new probe


def test_success_resets_consecutive_count():
    br = CircuitBreaker(threshold=3, cooldown_s=1.0)
    br.record_failure()
    br.record_failure()
    br.record_success()
    assert br.consecutive_failures == 0
    br.record_failure()
    br.record_failure()
    assert br.state == br.CLOSED                # never 3 *consecutive*


def test_threshold_zero_disables():
    br = CircuitBreaker(threshold=0)
    for _ in range(20):
        assert not br.record_failure()
        assert br.allow()
    assert br.state == br.CLOSED


# --------------------------------------- satellite 2: mid-batch dispatch
def test_injected_dispatch_fault_fails_only_its_batch(registry):
    """Three coalesced requests, one injected dispatch exception: all
    three futures fail, nothing else does, and the engine keeps serving
    — the batcher thread never dies."""
    engine = ServeEngine(registry, EngineConfig(max_batch=32),
                         autostart=False)
    X = np.zeros((2, D), np.float32)
    futs = [engine.submit(X, model="bin") for _ in range(3)]
    with FaultPlan().inject("serve.dispatch", exc="RuntimeError", times=1):
        engine.start()
        for f in futs:
            with pytest.raises(RuntimeError, match="injected fault"):
                f.result(30)
    snap = engine.metrics.snapshot()
    assert snap["failed"] == 3
    assert snap["breaker_opened"] == 0          # 1 failure < default threshold
    assert engine.health == READY
    # same model serves again; the other model was never touched
    assert engine(X, model="bin").shape == (2,)
    assert engine(X, model="mc3").shape == (2, 3)
    assert engine.inflight == 0
    engine.stop()


def test_breaker_opens_then_probe_recloses(registry):
    """End-to-end self-healing: repeated dispatch faults trip the breaker
    (CircuitOpen + DEGRADED), the healthy model keeps serving, and after
    the cooldown one successful probe re-closes the circuit (READY)."""
    cfg = EngineConfig(max_batch=32, breaker_threshold=2,
                       breaker_cooldown_s=0.3)
    X = np.zeros((2, D), np.float32)
    with ServeEngine(registry, cfg) as engine:
        with FaultPlan().inject("serve.dispatch", exc="RuntimeError",
                                times=2):
            for _ in range(2):
                with pytest.raises(RuntimeError):
                    engine(X, model="bin")
        snap = engine.metrics.snapshot()
        assert snap["breaker_opened"] == 1
        assert engine.health == DEGRADED
        assert snap["health"] == DEGRADED
        with pytest.raises(CircuitOpen):
            engine.submit(X, model="bin")       # fast-rejected, not queued
        assert engine.metrics.snapshot()["rejected_open"] == 1
        assert engine(X, model="mc3").shape == (2, 3)   # unaffected model
        time.sleep(0.35)                        # past the cooldown
        assert engine(X, model="bin").shape == (2,)     # probe succeeds
        snap = engine.metrics.snapshot()
        assert snap["breaker_closed"] == 1
        assert engine.health == READY
        assert snap["health"] == READY


def test_health_transitions(registry):
    engine = ServeEngine(registry, EngineConfig(max_batch=32),
                         autostart=False)
    assert engine.health == STARTING
    engine.start()
    assert engine.health == READY
    assert engine.metrics.snapshot()["health"] == READY
    engine.stop()
    assert engine.health == STARTING
