"""Preemption-safe in-training checkpoints: atomic commits, the async
writer, and bit-exact resume of both TRON drivers.

The resume contract under test (see ``repro.core.tron``): the canonical
cross-segment state is the O(m·K) TronSnapshot, f/g/aux are re-derived
from beta inside the same program on restore, so a run resumed from ANY
committed step walks the bit-identical trajectory of the uninterrupted
checkpointed run — on the traced driver (in-memory plans) and the host
driver (stream plan), binary and one-vs-rest multiclass alike.
Kill-at-any-instant durability (SIGKILL mid-write) is exercised by the
subprocess suite in ``tests/test_kill_resume.py``; here the commit
protocol is tested at the file level (temp files invisible, corrupt
newest step skipped, pruning).
"""
import os
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import KernelMachine, MachineConfig
from repro.checkpoint import (AsyncCheckpointWriter, CheckpointConfig,
                              TrainingCheckpointer, check_resume_config,
                              list_steps, load_latest, load_step,
                              save_checkpoint, steps_dir_for, write_step)
from repro.core import KernelSpec, TronConfig
from repro.data import make_classification, make_multiclass

CFG_KW = dict(kernel=KernelSpec("gaussian", sigma=2.0), lam=0.1, m=32,
              seed=3, tron=TronConfig(max_iter=25))


def _data(multiclass=False):
    key = jax.random.PRNGKey(0)
    if multiclass:
        X, y = make_multiclass(key, 256, 6, 3, clusters_per_class=2)
        return np.asarray(X), np.asarray(y)
    X, y = make_classification(key, 256, 6, clusters_per_class=4)
    return np.asarray(X), np.asarray(y)


# ------------------------------------------------------- commit protocol
def test_save_checkpoint_leaves_no_temp_files(tmp_path):
    path = tmp_path / "a.npz"
    nbytes = save_checkpoint(str(path), {"x": np.arange(4)})
    assert path.exists() and nbytes == path.stat().st_size > 0
    assert os.listdir(tmp_path) == ["a.npz"]    # no mkstemp leftovers


def test_write_step_stamps_and_prunes(tmp_path):
    d = str(tmp_path / "steps")
    for s in (2, 4, 6, 8):
        write_step(d, s, {"beta": np.zeros(3), "delta": np.float32(1),
                          "gnorm0": np.float32(1), "active": np.bool_(True),
                          "it": np.int64(s), "n_fg": np.int64(s),
                          "n_hd": np.int64(0)}, {"config": {}}, keep=3)
    assert [s for s, _ in list_steps(d)] == [4, 6, 8]
    rs = load_step(list_steps(d)[-1][1])
    assert rs.step == 8 and rs.snapshot.it == 8
    assert rs.meta["format"] == "train-ckpt-1" and "wall_time" in rs.meta


def test_list_steps_ignores_temp_and_foreign_files(tmp_path):
    d = tmp_path / "steps"
    d.mkdir()
    (d / ".tmp-ckpt-abc.npz").write_bytes(b"torn half-write")
    (d / "notes.txt").write_text("hi")
    (d / "step-bogus.npz").write_bytes(b"")
    assert list_steps(str(d)) == []
    assert list_steps(str(d / "missing")) == []


def test_load_latest_skips_corrupt_newest(tmp_path):
    d = str(tmp_path / "steps")
    tree = {"beta": np.ones(3, np.float32), "delta": np.float32(1),
            "gnorm0": np.float32(2), "active": np.bool_(True),
            "it": np.int64(5), "n_fg": np.int64(6), "n_hd": np.int64(7)}
    write_step(d, 5, tree, {})
    # a corrupt later file (external damage) must not break resume
    with open(os.path.join(d, "step-00000009.npz"), "wb") as f:
        f.write(b"\x00" * 16)
    rs = load_latest(d)
    assert rs.step == 5 and rs.snapshot.n_hd == 7
    with pytest.raises(FileNotFoundError):
        load_latest(str(tmp_path / "empty"))


def test_check_resume_config_pins_objective():
    cfg = MachineConfig(**CFG_KW)
    check_resume_config(cfg, {"config": cfg.to_dict()})
    bad = dict(cfg.to_dict(), lam=9.0)
    with pytest.raises(ValueError, match="lam"):
        check_resume_config(cfg, {"config": bad})
    check_resume_config(cfg, {})          # legacy/absent meta: permissive


# ----------------------------------------------------------- async writer
def test_async_writer_writes_and_accounts(tmp_path):
    d = str(tmp_path)
    w = AsyncCheckpointWriter(
        lambda step, tree, md: write_step(d, step, tree, md))
    tree = {"beta": np.zeros(4, np.float32), "delta": np.float32(1),
            "gnorm0": np.float32(1), "active": np.bool_(True),
            "it": np.int64(1), "n_fg": np.int64(1), "n_hd": np.int64(0)}
    w.submit(1, tree, {})
    w.submit(2, dict(tree, it=np.int64(2)), {})
    w.close(flush=True)
    st = w.stats()
    assert st["snapshots_written"] >= 1 and st["errors"] == 0
    assert st["bytes_written"] > 0 and st["last_step"] == 2
    assert [s for s, _ in list_steps(d)][-1] == 2
    with pytest.raises(RuntimeError):
        w.submit(3, tree, {})


def test_async_writer_drop_oldest_never_blocks():
    gate = threading.Event()
    done = []

    def slow_write(step, tree, md):
        gate.wait(10)
        done.append(step)
        return 1

    w = AsyncCheckpointWriter(slow_write)
    w.submit(1, {}, {})               # taken by the writer, blocks on gate
    time.sleep(0.05)
    t0 = time.perf_counter()
    w.submit(2, {}, {})               # pending
    w.submit(3, {}, {})               # replaces 2 (drop-oldest)
    assert time.perf_counter() - t0 < 1.0   # producer never blocked on I/O
    gate.set()
    w.close(flush=True)
    assert done == [1, 3]
    st = w.stats()
    assert st["snapshots_submitted"] == 3
    assert st["snapshots_dropped"] == 1 and st["snapshots_written"] == 2


def test_async_writer_survives_write_errors(tmp_path):
    calls = []

    def flaky(step, tree, md):
        calls.append(step)
        if step == 1:
            raise OSError("disk on fire")
        return 7

    w = AsyncCheckpointWriter(flaky)
    w.submit(1, {}, {})
    w.flush(5)
    w.submit(2, {}, {})               # writer must still be alive
    w.close(flush=True)
    st = w.stats()
    # step 1 fails persistently: the default policy retries the transient-
    # looking OSError twice before recording the error, then the writer
    # keeps serving step 2 (tests/test_async_writer_edges.py covers the
    # transient case where a retry succeeds)
    assert calls == [1, 1, 1, 2]
    assert st["errors"] == 1 and st["snapshots_written"] == 1
    assert st["write_retries"] == 2
    assert st["last_step"] == 2


# ------------------------------------------------- resume: traced driver
@pytest.mark.parametrize("multiclass", [False, True],
                         ids=["binary", "ovr3"])
def test_local_plan_resume_bitwise_from_every_step(tmp_path, multiclass):
    X, y = _data(multiclass)
    cfg = MachineConfig(solver="tron", plan="local", **CFG_KW)
    d = steps_dir_for(str(tmp_path / "model.npz"))
    ck = CheckpointConfig(dir=d, interval=2, keep=0, background=False)
    km = KernelMachine(cfg).fit(X, y, checkpoint=ck)
    ref = np.asarray(km.state_["beta"])
    r = km.result_
    steps = list_steps(d)
    assert len(steps) >= 2
    assert r.extras["ckpt"]["snapshots_written"] == len(steps)
    for cut in range(len(steps) - 1):
        d2 = str(tmp_path / f"cut{cut}")
        os.makedirs(d2)
        src = steps[cut][1]
        dst = os.path.join(d2, os.path.basename(src))
        with open(src, "rb") as fi, open(dst, "wb") as fo:
            fo.write(fi.read())
        km2 = KernelMachine(cfg).fit(
            X, y, checkpoint=CheckpointConfig(dir=d2, interval=2,
                                              resume=True))
        got = np.asarray(km2.state_["beta"])
        assert np.array_equal(ref, got), \
            f"resume from step {steps[cut][0]} diverged"
        # counter comparability: the restore re-eval is not counted
        assert km2.result_.n_iter == r.n_iter
        assert km2.result_.n_fg == r.n_fg
        assert km2.result_.extras["ckpt"]["resumed_step"] == steps[cut][0]


# --------------------------------------------------- resume: host driver
@pytest.mark.parametrize("multiclass", [False, True],
                         ids=["binary", "ovr3"])
def test_stream_plan_resume_bitwise(tmp_path, multiclass):
    X, y = _data(multiclass)
    cfg = MachineConfig(solver="tron", plan="stream", **CFG_KW)
    d = str(tmp_path / "steps")
    ck = CheckpointConfig(dir=d, interval=3, keep=0, background=True)
    km = KernelMachine(cfg).fit(X, y, checkpoint=ck)
    ref = np.asarray(km.state_["beta"])
    steps = list_steps(d)
    assert steps, "no steps committed"
    # keep only the earliest step and resume from it
    for _, p in steps[1:]:
        os.unlink(p)
    km2 = KernelMachine(cfg).fit(
        X, y, checkpoint=CheckpointConfig(dir=d, interval=3, resume=True))
    assert np.array_equal(ref, np.asarray(km2.state_["beta"]))
    if multiclass:
        np.testing.assert_array_equal(np.asarray(km.state_["classes"]),
                                      np.asarray(km2.state_["classes"]))
    st = km2.result_.extras["ckpt"]
    assert st["resumed_step"] == steps[0][0]
    # the stream feeder identity travels with every step file
    rs = load_latest(d)
    feeder = rs.meta.get("feeder")
    assert feeder is not None and feeder["n"] == X.shape[0] \
        and feeder["d"] == X.shape[1] and feeder["h2d_bytes"] > 0


def test_resume_refuses_other_objective(tmp_path):
    X, y = _data()
    d = str(tmp_path / "steps")
    cfg = MachineConfig(solver="tron", plan="local", **CFG_KW)
    KernelMachine(cfg).fit(X, y, checkpoint=CheckpointConfig(
        dir=d, interval=2, background=False))
    other = MachineConfig(solver="tron", plan="local",
                          **dict(CFG_KW, lam=5.0))
    with pytest.raises(ValueError, match="incompatible config"):
        KernelMachine(other).fit(X, y, checkpoint=CheckpointConfig(
            dir=d, interval=2, resume=True))


def test_checkpoint_rejected_for_non_tron_solver(tmp_path):
    X, y = _data()
    cfg = MachineConfig(solver="rff", plan="local", **CFG_KW)
    with pytest.raises(ValueError, match="tron"):
        KernelMachine(cfg).fit(X, y, checkpoint=CheckpointConfig(
            dir=str(tmp_path), interval=2))


def test_checkpoint_config_validation(tmp_path):
    with pytest.raises(ValueError, match="interval"):
        CheckpointConfig(dir=str(tmp_path), interval=0)


def test_checkpointer_async_overlap_accounting(tmp_path):
    """The FitResult surfaces writer accounting — the h2d-bytes idiom for
    checkpoint I/O — and async commits do not run on the calling thread."""
    X, y = _data()
    cfg = MachineConfig(solver="tron", plan="local", **CFG_KW)
    d = str(tmp_path / "steps")
    km = KernelMachine(cfg).fit(X, y, checkpoint=CheckpointConfig(
        dir=d, interval=2, keep=2, background=True))
    st = km.result_.extras["ckpt"]
    assert st["background"] is True and st["errors"] == 0
    assert st["snapshots_written"] + st["snapshots_dropped"] \
        == st["snapshots_submitted"] >= 1
    assert st["bytes_written"] > 0 and st["write_seconds"] >= 0
    assert len(list_steps(d)) <= 2            # keep pruning applied


def test_training_checkpointer_restores_feeder_state():
    class FakeFeeder:
        def __init__(self):
            self.restored = None
            self.h2d_bytes = 0

        def state(self):
            return {"n": 10, "d": 2, "h2d_bytes": self.h2d_bytes}

        def restore_state(self, st):
            self.restored = st

    ck = TrainingCheckpointer(
        CheckpointConfig(dir="/nonexistent", interval=1, background=False),
        meta={}, resume_meta={"feeder": {"n": 10, "d": 2, "h2d_bytes": 99},
                              "step": 4})
    f = FakeFeeder()
    ck.attach_feeder(f)
    assert f.restored == {"n": 10, "d": 2, "h2d_bytes": 99}
    assert ck.stats()["resumed_step"] == 4


# -------------------------------------------- multi-controller elasticity
@pytest.mark.slow
@pytest.mark.requires_devices(4)
@pytest.mark.requires_multiprocess(timeout=1500)
def test_multihost_elastic_resume_bitwise_across_process_counts(tmp_path):
    """A run checkpointed at P=2 processes (2 devices each) resumes
    bitwise-identically at P'=4 processes (1 device each): the snapshot
    is the replicated O(m) TRON state, the global mesh is the same 4
    devices either way, so re-partitioning the hosts re-slices only WHERE
    rows live — never a single bit of the trajectory. The ``write`` gate
    means only process 0 commits step files; the resume arm restores the
    same shared directory on every process."""
    from multihost.rig import run_fleet
    d_full = str(tmp_path / "full-steps")
    d_head = str(tmp_path / "head-steps")
    full = run_fleet("ckpt", 2, 2, extra=["full", d_full]).result
    head = run_fleet("ckpt", 2, 2, extra=["head", d_head, "3"]).result
    assert head["n_iter"] <= 3 < full["n_iter"]
    assert list_steps(d_head), "head run committed no step files"
    resumed = run_fleet("ckpt", 4, 1, extra=["resume", d_head]).result
    assert resumed["num_processes"] == 4 and full["num_processes"] == 2
    assert resumed["beta_sha"] == full["beta_sha"], (
        "resume at P'=4 of a P=2 checkpoint diverged bitwise: "
        f"rel l2 {np.linalg.norm(np.subtract(resumed['beta'], full['beta'])):.2e}")
    assert resumed["f"] == full["f"]
