"""TRON solver unit tests: exactness on quadratics, monotonicity, counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tron import TronConfig, tron


def quad_problem(key, m=32, cond=100.0):
    k1, k2 = jax.random.split(key)
    Q = jax.random.normal(k1, (m, m))
    evals = jnp.logspace(0, np.log10(cond), m)
    U, _ = jnp.linalg.qr(Q)
    H = (U * evals) @ U.T
    b = jax.random.normal(k2, (m,))
    return H, b


def test_tron_solves_quadratic_exactly():
    H, b = quad_problem(jax.random.PRNGKey(0))
    # f = 0.5 x'Hx - b'x; grad = Hx - b; Hd = Hd
    fgrad = lambda x: (0.5 * x @ (H @ x) - b @ x, H @ x - b, jnp.zeros(()))
    hessd = lambda aux, d: H @ d
    res = tron(fgrad, hessd, jnp.zeros_like(b),
               TronConfig(max_iter=100, grad_rtol=1e-6, cg_rtol=1e-3,
                          cg_max_iter=200))
    x_star = jnp.linalg.solve(H, b)
    np.testing.assert_allclose(res.beta, x_star, rtol=1e-3, atol=1e-4)
    assert bool(res.converged)


def test_tron_monotone_decrease():
    H, b = quad_problem(jax.random.PRNGKey(1), m=16)
    fs = []

    def fgrad(x):
        f = 0.5 * x @ (H @ x) - b @ x
        fs.append(float(f)) if not isinstance(f, jax.core.Tracer) else None
        return f, H @ x - b, jnp.zeros(())

    # run eagerly (no jit) to observe f values
    res = tron(fgrad, lambda a, d: H @ d, jnp.ones_like(b),
               TronConfig(max_iter=50))
    f0 = 0.5 * jnp.ones_like(b) @ (H @ jnp.ones_like(b)) - b @ jnp.ones_like(b)
    assert float(res.f) < float(f0)


def test_tron_counts_and_stats():
    H, b = quad_problem(jax.random.PRNGKey(2), m=8, cond=10)
    res = tron(lambda x: (0.5 * x @ (H @ x) - b @ x, H @ x - b, jnp.zeros(())),
               lambda a, d: H @ d, jnp.zeros_like(b), TronConfig(max_iter=50))
    assert int(res.n_fg) == int(res.n_iter) + 1
    assert int(res.n_hd) >= int(res.n_iter)   # >=1 CG step per outer iter
    assert float(res.gnorm) < 1e-2 * float(jnp.linalg.norm(b))


def test_tron_jittable():
    H, b = quad_problem(jax.random.PRNGKey(3), m=8)
    run = jax.jit(lambda b0: tron(
        lambda x: (0.5 * x @ (H @ x) - b @ x, H @ x - b, jnp.zeros(())),
        lambda a, d: H @ d, b0, TronConfig(max_iter=50)))
    res = run(jnp.zeros_like(b))
    assert bool(res.converged)
