"""Concurrent serving correctness: the repro.serve engine under real
thread interleaving.

The engine's whole claim is that coalescing many callers' rows into one
bucketed dispatch changes *when* margins are computed but never *what*
they are. These tests prove it the hard way: client threads fire
interleaved mixed-size, mixed-K requests and every response must be
BITWISE the synchronous bucketed-decider result for that caller's rows
(per-row margins are batch-composition independent — the bucket floor in
``repro.api.infer.MIN_BUCKET`` exists exactly to keep that true), and
within 1e-6 of the eager ``decision_function`` path. Liveness is proven
too: queue saturation and expired deadlines reject cleanly and the
batcher keeps serving afterwards — no deadlock, no wedged queue.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.api import KernelMachine, MachineConfig
from repro.api.infer import BucketedDecider, bucket_rows, scatter_rows
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_classification, make_multiclass
from repro.serve import (EngineConfig, EngineStopped, ModelRegistry,
                         QueueFull, RequestTimeout, ServeEngine,
                         ServeMetrics, baseline_target, engine_target,
                         make_workload, percentiles, run_load)

N, D, M = 256, 8, 16
CFG = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=1.0,
                    tron=TronConfig(max_iter=40))


@pytest.fixture(scope="module")
def km():
    X, y = make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=4)
    return KernelMachine(CFG).fit(X, y, random_basis(jax.random.PRNGKey(1),
                                                     X, M))


@pytest.fixture(scope="module")
def km_mc():
    X, y = make_multiclass(jax.random.PRNGKey(0), N, D, 3,
                           clusters_per_class=2)
    return KernelMachine(CFG).fit(X, y, random_basis(jax.random.PRNGKey(1),
                                                     X, M))


@pytest.fixture(scope="module")
def registry(km, km_mc):
    reg = ModelRegistry(max_batch=32)
    reg.add("bin", km)
    reg.add("mc3", km_mc)
    reg.warmup()
    return reg


# ----------------------------------------------------------------- pieces
def test_scatter_rows_inverts_concat():
    rng = np.random.default_rng(0)
    parts = [rng.standard_normal((n, 3)) for n in (1, 4, 2, 7)]
    out = scatter_rows(np.concatenate(parts), [p.shape[0] for p in parts])
    assert len(out) == len(parts)
    for got, want in zip(out, parts):
        np.testing.assert_array_equal(got, want)
    assert scatter_rows(np.zeros((0, 2)), []) == []


def test_bucket_floor_is_multirow():
    # the determinism contract: no (1, d) dispatch shape ever exists
    assert bucket_rows(1, 256) == 2
    assert BucketedDecider(lambda x: x, max_batch=8).padded_rows(1) == 2


def test_warmup_precompiles_every_bucket(km):
    dec = BucketedDecider(km.decider(), max_batch=32)
    assert dec.n_executables == 0
    n = dec.warmup(D)
    assert n == dec.n_executables == 5          # {2, 4, 8, 16, 32}
    # traffic of every size adds no executables after warmup
    for s in range(1, 33):
        dec(np.zeros((s, D), np.float32))
    assert dec.n_executables == 5


def test_registry_warmup_and_routing(registry):
    counts = registry.warmup()
    assert set(counts) == {"bin", "mc3"}
    assert registry.get("bin").n_classes == 0
    assert registry.get("mc3").n_classes == 3
    assert registry.get().name == "bin"          # first added is default
    with pytest.raises(KeyError, match="unknown model"):
        registry.get("nope")


# ---------------------------------------------- concurrent correctness
def test_concurrent_mixed_requests_bitwise(registry, km, km_mc):
    """4 client threads, interleaved mixed-size and mixed-K requests:
    every response bitwise-matches the synchronous bucketed result for
    that caller's rows and is within 1e-6 of eager decision_function —
    zero cross-request row leakage."""
    machines = {"bin": km, "mc3": km_mc}
    clients, per_client = 4, 40
    streams = make_workload(registry, clients=clients,
                            requests_per_client=per_client, max_rows=32,
                            seed=7)
    errors = []
    with ServeEngine(registry, EngineConfig(max_batch=32,
                                            timeout_s=60.0)) as engine:
        def client(stream, ci):
            try:
                for ri, req in enumerate(stream):
                    got = engine(req.X, model=req.model)
                    assert got.shape == req.reference.shape
                    np.testing.assert_array_equal(
                        got, req.reference,
                        err_msg=f"client {ci} request {ri} "
                                f"({req.model}, {req.X.shape})")
                    eager = np.asarray(
                        machines[req.model].decision_function(req.X))
                    np.testing.assert_allclose(got, eager, atol=1e-6)
            except Exception as exc:            # surface in the main thread
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(s, i))
                   for i, s in enumerate(streams)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = engine.metrics.snapshot()
    if errors:
        raise errors[0]
    assert snap["completed"] == clients * per_client
    assert snap["rejected_full"] == snap["rejected_timeout"] == 0
    assert 0.0 < snap["occupancy"] <= 1.0


def test_engine_vs_baseline_same_margins(registry):
    """The load harness's two targets agree exactly on every response."""
    streams = make_workload(registry, clients=2, requests_per_client=15,
                            max_rows=32, seed=3)
    base = run_load(baseline_target(registry), streams, label="baseline")
    with ServeEngine(registry, EngineConfig(max_batch=32,
                                            timeout_s=60.0)) as engine:
        eng = run_load(engine_target(engine), streams, label="engine")
    assert base.mismatches == 0 and eng.mismatches == 0
    assert base.completed == eng.completed == 30
    assert set(eng.latency_ms) == {"p50_ms", "p95_ms", "p99_ms"}


def test_multiclass_never_coalesces_with_binary(registry):
    """Per-model grouping: a (n,) and an (n, 3) machine served from one
    engine return correct shapes even when submitted back to back."""
    with ServeEngine(registry, EngineConfig(max_batch=32)) as engine:
        futs = []
        for i in range(10):
            X = np.random.default_rng(i).standard_normal((3, D)) \
                  .astype(np.float32)
            futs.append((engine.submit(X, model="bin"),
                         engine.submit(X, model="mc3")))
        for fb, fm in futs:
            assert fb.result(30).shape == (3,)
            assert fm.result(30).shape == (3, 3)


# ------------------------------------------------- admission / liveness
def test_queue_saturation_rejects_cleanly(registry):
    """Submissions beyond the bounded queue raise QueueFull without
    wedging the batcher: once started, the admitted backlog completes and
    the engine keeps serving fresh traffic."""
    engine = ServeEngine(registry,
                         EngineConfig(max_batch=32, max_queue=4),
                         autostart=False)
    X = np.zeros((2, D), np.float32)
    admitted = [engine.submit(X) for _ in range(4)]
    with pytest.raises(QueueFull):
        engine.submit(X)
    assert engine.metrics.snapshot()["rejected_full"] == 1
    engine.start()
    for fut in admitted:
        assert fut.result(30).shape == (2,)
    # the engine is not wedged: a post-saturation request still serves
    assert engine(X).shape == (2,)
    engine.stop()


def test_inflight_cap_rejects(registry):
    engine = ServeEngine(registry,
                         EngineConfig(max_batch=32, max_queue=100,
                                      max_inflight=2),
                         autostart=False)
    X = np.zeros((1, D), np.float32)
    engine.submit(X), engine.submit(X)
    with pytest.raises(QueueFull, match="max_inflight"):
        engine.submit(X)
    engine.start()
    time.sleep(0.1)
    assert engine.inflight == 0                  # drained after start
    engine.stop()


def test_timeout_rejects_cleanly_without_wedging(registry):
    """Requests whose deadline lapses while queued fail with
    RequestTimeout; the batcher survives and serves what follows."""
    engine = ServeEngine(registry, EngineConfig(max_batch=32),
                         autostart=False)
    X = np.zeros((2, D), np.float32)
    doomed = [engine.submit(X, timeout=0.02) for _ in range(3)]
    alive = engine.submit(X, timeout=60.0)
    time.sleep(0.1)                              # deadlines lapse unqueued
    engine.start()
    for fut in doomed:
        with pytest.raises(RequestTimeout):
            fut.result(30)
    assert alive.result(30).shape == (2,)
    snap = engine.metrics.snapshot()
    assert snap["rejected_timeout"] == 3
    assert snap["completed"] == 1
    # liveness after the rejections
    assert engine(X).shape == (2,)
    engine.stop()


def test_stop_fails_pending_requests(registry):
    engine = ServeEngine(registry, EngineConfig(max_batch=32),
                         autostart=False)
    fut = engine.submit(np.zeros((2, D), np.float32))
    engine.stop()
    with pytest.raises(EngineStopped):
        fut.result(5)
    assert engine.metrics.snapshot()["cancelled"] == 1


def test_stop_releases_inflight_and_rejects_new_submits(registry):
    """Every terminal path — completed, timeout, cancelled-at-stop,
    rejected-at-push — must release its in-flight slot, and a stopped
    engine must reject submits instead of stranding them in a queue
    nobody pops."""
    engine = ServeEngine(registry,
                         EngineConfig(max_batch=32, max_queue=100,
                                      max_inflight=100),
                         autostart=False)
    X = np.zeros((2, D), np.float32)
    served = engine.submit(X)                    # completes after start
    doomed = engine.submit(X, timeout=0.01)      # expires in queue
    time.sleep(0.05)
    engine.start()
    assert served.result(30).shape == (2,)
    with pytest.raises(RequestTimeout):
        doomed.result(30)
    stranded = engine.submit(X)          # races stop: served OR cancelled,
    engine.stop()                        # but NEVER left hanging
    try:
        assert stranded.result(5).shape == (2,)
    except EngineStopped:
        pass
    with pytest.raises(EngineStopped):           # post-stop submit: rejected
        engine.submit(X)
    assert engine.inflight == 0, \
        "a terminal path leaked its in-flight slot"


def test_stop_start_cycle_serves_again_without_spurious_queuefull(registry):
    """Saturate to the in-flight cap, stop (cancelling everything), then
    restart: the engine must serve a full load again. Before the lifecycle
    fixes, slots leaked by stop()/failed dispatches survived the restart
    as phantom occupancy and fresh traffic died with QueueFull."""
    cap = 8
    engine = ServeEngine(registry,
                         EngineConfig(max_batch=32, max_queue=100,
                                      max_inflight=cap),
                         autostart=False)
    X = np.zeros((2, D), np.float32)
    # Saturate while the batcher is NOT running, so admission is
    # deterministic: exactly cap slots fill, the next submit must be
    # rejected, and stop() cancels every queued request.
    futs = [engine.submit(X) for _ in range(cap)]
    with pytest.raises(QueueFull):
        engine.submit(X)
    engine.stop()
    for fut in futs:
        with pytest.raises(EngineStopped):
            fut.result(5)
    assert engine.inflight == 0, "stop() leaked in-flight slots"
    for cycle in range(3):
        engine.start()
        # a full complement of NEW requests must be admitted and served:
        # phantom occupancy surviving the restart would reject these
        # with QueueFull at admission.
        again = [engine.submit(X) for _ in range(cap)]
        for fut in again:
            assert fut.result(30).shape == (2,)
        engine.stop()
        assert engine.inflight == 0, f"slots leaked in cycle {cycle}"
    with pytest.raises(EngineStopped):
        engine.submit(X)


def test_dispatch_failure_releases_slots_and_keeps_batcher_alive(registry):
    """A model unregistered between admission and dispatch fails ITS
    requests (never the batcher thread) and releases their slots."""
    reg = ModelRegistry(max_batch=32)
    reg.add("bin", registry.get("bin").km)
    engine = ServeEngine(reg, EngineConfig(max_batch=32, max_inflight=8),
                         autostart=False)
    X = np.zeros((2, D), np.float32)
    doomed = engine.submit(X, model="bin")
    reg.remove("bin")                    # lookup now fails inside _dispatch
    engine.start()
    with pytest.raises(KeyError):
        doomed.result(30)
    assert engine.metrics.snapshot()["failed"] == 1
    reg.add("bin", registry.get("bin").km)
    assert engine(X, model="bin").shape == (2,)   # batcher still alive
    assert engine.inflight == 0
    engine.stop()


def test_submit_validates_shape(registry):
    with ServeEngine(registry, EngineConfig(max_batch=32)) as engine:
        with pytest.raises(ValueError, match="serves"):
            engine.submit(np.zeros((2, D + 1), np.float32))
        # zero-row requests complete immediately with empty margins
        assert engine.submit(np.zeros((0, D), np.float32)).result(5) \
            .shape == (0,)
        assert engine.submit(np.zeros((0, D), np.float32),
                             model="mc3").result(5).shape == (0, 3)


# ------------------------------------------------------------- metrics
def test_metrics_occupancy_and_percentiles():
    m = ServeMetrics()
    m.add(dispatches=2, dispatched_rows=48, padded_rows=64,
          coalesced_requests=6, submitted=8, rejected_full=2)
    assert m.occupancy() == 48 / 64
    assert m.requests_per_dispatch() == 3.0
    assert m.rejection_rate() == 0.25
    with pytest.raises(AttributeError):
        m.add(not_a_counter=1)
    p = percentiles([0.001] * 99 + [0.1])
    assert p["p50_ms"] == pytest.approx(1.0)
    assert p["p99_ms"] > 1.0
    assert percentiles([]) == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}


@pytest.mark.parametrize("n", [1, 2, 3, 99])
def test_percentiles_tiny_samples_clamp_to_observations(n):
    """On n < 100 samples the tail percentiles must be actual observations
    (the "higher" order statistic), clamped in range — never interpolated
    below the worst sample, never an out-of-range index. The failure this
    pins down: with one slow outlier among fast requests, interpolation
    reported a p99 ~equal to the median, silently erasing the tail a
    smoke-scale SLO run exists to measure."""
    slow, fast = 0.100, 0.001
    samples = [fast] * (n - 1) + [slow]
    p = percentiles(samples)
    assert p["p99_ms"] == pytest.approx(slow * 1e3)   # the worst REAL sample
    assert p["p95_ms"] in (pytest.approx(fast * 1e3), pytest.approx(slow * 1e3))
    if n == 1:
        # single sample: every percentile is that sample (no IndexError)
        assert p["p50_ms"] == p["p95_ms"] == p["p99_ms"] \
            == pytest.approx(slow * 1e3)
    if n >= 3:
        assert p["p50_ms"] == pytest.approx(fast * 1e3)
    # percentile ordering invariant
    assert p["p50_ms"] <= p["p95_ms"] <= p["p99_ms"]


def test_percentiles_n2_tail_is_not_the_median():
    # the regression shape: n=2 once reported p99 ≈ p50 via interpolation
    p = percentiles([0.001, 0.100])
    assert p["p99_ms"] == pytest.approx(100.0)
    assert p["p95_ms"] == pytest.approx(100.0)
