"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(16, 8, 4), (64, 32, 16), (300, 130, 50), (512, 256, 256),
          (257, 129, 100), (1000, 333, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]
KINDS = ["gaussian", "linear"]


def _data(n, m, d, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(k1, (n, d), dtype)
    z = jax.random.normal(k2, (m, d), dtype)
    beta = jax.random.normal(k3, (m,), jnp.float32)
    v = jax.random.normal(k4, (n,), jnp.float32)
    return x, z, beta, v


def _sigma(d):
    return float(np.sqrt(d))   # keep exp() in a meaningful range


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_gram_matches_ref(shape, dtype, kind):
    n, m, d = shape
    x, z, _, _ = _data(n, m, d, dtype)
    got = ops.gram(x, z, kind=kind, sigma=_sigma(d))
    want = ref.gram_ref(x, z, kind=kind, sigma=_sigma(d))
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol)


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_kmvp_fwd_matches_ref(shape, dtype, kind):
    n, m, d = shape
    x, z, beta, _ = _data(n, m, d, dtype)
    got = ops.kmvp_fwd(x, z, beta, kind=kind, sigma=_sigma(d))
    want = ref.kmvp_ref(x, z, beta, kind=kind, sigma=_sigma(d))
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol * np.sqrt(m))


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_kmvp_t_matches_ref(shape, dtype, kind):
    n, m, d = shape
    x, z, _, v = _data(n, m, d, dtype)
    got = ops.kmvp_t(x, z, v, kind=kind, sigma=_sigma(d))
    want = ref.kmvp_t_ref(x, z, v, kind=kind, sigma=_sigma(d))
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol * np.sqrt(n))


def test_block_shape_invariance():
    """Result must not depend on BlockSpec tile choice."""
    x, z, beta, v = _data(384, 256, 96, jnp.float32)
    base = ops.gram(x, z, sigma=10.0, bn=256, bm=256, bd=256)
    for bn, bm, bd in [(64, 128, 128), (8, 128, 256), (128, 256, 128)]:
        got = ops.gram(x, z, sigma=10.0, bn=bn, bm=bm, bd=bd)
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-6)


def test_gram_backend_integration():
    """core.nystrom routes backend='pallas' through the kernel."""
    from repro.core.nystrom import KernelSpec, gram
    x, z, _, _ = _data(100, 40, 12, jnp.float32)
    kern = KernelSpec("gaussian", sigma=3.0)
    np.testing.assert_allclose(gram(x, z, kern, "pallas"),
                               gram(x, z, kern, "jnp"), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 16, 8, 3, 4), (3, 64, 32, 2, 16),
                                   (1, 128, 64, 4, 32)])
def test_ssd_chunk_matches_ref(shape):
    """Pallas SSD within-chunk kernel vs jnp oracle."""
    G, Q, N, H, P = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    Cc = jax.random.normal(ks[0], (G, Q, N), jnp.float32)
    Bc = jax.random.normal(ks[1], (G, Q, N), jnp.float32)
    dA = -jnp.abs(jax.random.normal(ks[2], (G, H, Q), jnp.float32)) * 0.1
    xdt = jax.random.normal(ks[3], (G, H, Q, P), jnp.float32)
    got = ops.ssd_chunk(Cc, Bc, dA, xdt)
    want = ref.ssd_chunk_ref(Cc, Bc, dA, xdt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_consistent_with_model_path():
    """Kernel output == the ssd_scan diagonal term used by the model."""
    from repro.models.ssm import _segsum
    G, Q, N, H, P = 2, 32, 16, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    Cc = jax.random.normal(ks[0], (G, Q, N), jnp.float32)
    Bc = jax.random.normal(ks[1], (G, Q, N), jnp.float32)
    dA = -jnp.abs(jax.random.normal(ks[2], (G, H, Q), jnp.float32)) * 0.1
    xdt = jax.random.normal(ks[3], (G, H, Q, P), jnp.float32)
    L = jnp.exp(_segsum(dA))
    scores = jnp.einsum("gqn,gkn->gqk", Cc, Bc)
    want = jnp.einsum("ghqk,ghkp->ghqp",
                      jnp.where(jnp.isfinite(L), scores[:, None] * L, 0.0), xdt)
    got = ops.ssd_chunk(Cc, Bc, dA, xdt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
