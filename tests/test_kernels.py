"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_allclose_dtype
from repro.kernels import ops, ref

SHAPES = [(16, 8, 4), (64, 32, 16), (300, 130, 50), (512, 256, 256),
          (257, 129, 100), (1000, 333, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]
KINDS = ["gaussian", "linear"]


def _data(n, m, d, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(k1, (n, d), dtype)
    z = jax.random.normal(k2, (m, d), dtype)
    beta = jax.random.normal(k3, (m,), jnp.float32)
    v = jax.random.normal(k4, (n,), jnp.float32)
    return x, z, beta, v


def _sigma(d):
    return float(np.sqrt(d))   # keep exp() in a meaningful range


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_gram_matches_ref(shape, dtype, kind):
    n, m, d = shape
    x, z, _, _ = _data(n, m, d, dtype)
    got = ops.gram(x, z, kind=kind, sigma=_sigma(d))
    want = ref.gram_ref(x, z, kind=kind, sigma=_sigma(d))
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol)


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_kmvp_fwd_matches_ref(shape, dtype, kind):
    n, m, d = shape
    x, z, beta, _ = _data(n, m, d, dtype)
    got = ops.kmvp_fwd(x, z, beta, kind=kind, sigma=_sigma(d))
    want = ref.kmvp_ref(x, z, beta, kind=kind, sigma=_sigma(d))
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol * np.sqrt(m))


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_kmvp_t_matches_ref(shape, dtype, kind):
    n, m, d = shape
    x, z, _, v = _data(n, m, d, dtype)
    got = ops.kmvp_t(x, z, v, kind=kind, sigma=_sigma(d))
    want = ref.kmvp_t_ref(x, z, v, kind=kind, sigma=_sigma(d))
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol * np.sqrt(n))


# --------------------------------------------------------- parity test grid
# Deliberately odd, non-block-aligned shapes: every value in {1, 3, 127,
# 129, 257} appears in each of the n/m/d positions at least once, so the
# zero-padding claim in ops.py is a tested invariant, not a docstring.
ODD_SHAPES = [(1, 1, 1), (1, 3, 127), (3, 129, 1), (127, 1, 129),
              (129, 257, 3), (257, 127, 257)]


@pytest.mark.parametrize("shape", ODD_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_parity_grid(shape, dtype, kind):
    """gram / kmvp_fwd / kmvp_t vs the dense ref.py path on one dataset."""
    n, m, d = shape
    x, z, beta, v = _data(n, m, d, dtype)
    kw = dict(kind=kind, sigma=_sigma(d))
    assert_allclose_dtype(ops.gram(x, z, **kw), ref.gram_ref(x, z, **kw),
                          dtype)
    assert_allclose_dtype(ops.kmvp_fwd(x, z, beta, **kw),
                          ref.kmvp_ref(x, z, beta, **kw), dtype)
    assert_allclose_dtype(ops.kmvp_t(x, z, v, **kw),
                          ref.kmvp_t_ref(x, z, v, **kw), dtype)


@pytest.mark.parametrize("shape", ODD_SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_chunked_fallback_parity(shape, kind):
    """The jnp on-the-fly fallbacks match the dense path too."""
    n, m, d = shape
    x, z, beta, v = _data(n, m, d, jnp.float32)
    kw = dict(kind=kind, sigma=_sigma(d))
    assert_allclose_dtype(ops.kmvp_fwd_chunked(x, z, beta, **kw),
                          ref.kmvp_ref(x, z, beta, **kw), jnp.float32)
    assert_allclose_dtype(ops.kmvp_t_chunked(x, z, v, **kw),
                          ref.kmvp_t_ref(x, z, v, **kw), jnp.float32)
    # explicit chunk override exercises the padded-tail path
    assert_allclose_dtype(
        ops.kmvp_t_chunked(x, z, v, block_rows=8, **kw),
        ref.kmvp_t_ref(x, z, v, **kw), jnp.float32)


# ------------------------------------------------------- multi-RHS (m, k)
# k = 1 keeps the 2-D block shape (not the squeezed vector path), odd k
# exercises the 128-lane padding, k = 8 a real one-vs-rest class count.
MULTI_KS = [1, 3, 8]


def _multi_data(n, m, d, k, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(k1, (n, d), dtype)
    z = jax.random.normal(k2, (m, d), dtype)
    B = jax.random.normal(k3, (m, k), jnp.float32)
    V = jax.random.normal(k4, (n, k), jnp.float32)
    return x, z, B, V


@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_multirhs_parity_grid(k, dtype, kind):
    """(m, k) / (n, k) RHS blocks match the dense oracle — Pallas and the
    chunked jnp fallback — including non-block-aligned shapes."""
    for shape in [(64, 32, 16), (129, 257, 3)]:
        n, m, d = shape
        x, z, B, V = _multi_data(n, m, d, k, dtype)
        kw = dict(kind=kind, sigma=_sigma(d))
        G = ref.gram_ref(x, z, **kw)
        got_fwd = ops.kmvp_fwd(x, z, B, **kw)
        got_t = ops.kmvp_t(x, z, V, **kw)
        assert got_fwd.shape == (n, k) and got_t.shape == (m, k)
        assert_allclose_dtype(got_fwd, G @ B, dtype)
        assert_allclose_dtype(got_t, G.T @ V, dtype)
        if dtype == jnp.float32:
            assert_allclose_dtype(ops.kmvp_fwd_chunked(x, z, B, **kw),
                                  G @ B, dtype)
            assert_allclose_dtype(ops.kmvp_t_chunked(x, z, V, **kw),
                                  G.T @ V, dtype)


@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("impl", ["pallas", "chunked"])
def test_multirhs_column_independence(k, kind, impl):
    """Each column of a multi-RHS call equals the single-vector call on
    that column: the batched contraction is K independent matvecs sharing
    gram recomputation, never mixing columns."""
    n, m, d = 65, 40, 7
    x, z, B, V = _multi_data(n, m, d, k, jnp.float32)
    kw = dict(kind=kind, sigma=_sigma(d))
    fwd = ops.kmvp_fwd if impl == "pallas" else ops.kmvp_fwd_chunked
    t = ops.kmvp_t if impl == "pallas" else ops.kmvp_t_chunked
    O, G = fwd(x, z, B, **kw), t(x, z, V, **kw)
    for c in range(k):
        np.testing.assert_allclose(np.asarray(O[:, c]),
                                   np.asarray(fwd(x, z, B[:, c], **kw)),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(G[:, c]),
                                   np.asarray(t(x, z, V[:, c], **kw)),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("impl", ["pallas", "chunked"])
def test_multirhs_adjoint(k, kind, impl):
    """<kmvp_fwd(x,z,B), V>_F == <B, kmvp_t(x,z,V)>_F: the multi-RHS
    kernels stay adjoints of the same implicit C, column-batched."""
    n, m, d = 129, 64, 16
    x, z, B, V = _multi_data(n, m, d, k, jnp.float32)
    kw = dict(kind=kind, sigma=_sigma(d))
    if impl == "pallas":
        O, G = ops.kmvp_fwd(x, z, B, **kw), ops.kmvp_t(x, z, V, **kw)
    else:
        O = ops.kmvp_fwd_chunked(x, z, B, **kw)
        G = ops.kmvp_t_chunked(x, z, V, **kw)
    lhs, rhs = float(jnp.sum(O * V)), float(jnp.sum(B * G))
    scale = max(1.0, abs(lhs), abs(rhs))
    assert abs(lhs - rhs) / scale < 1e-5, (lhs, rhs)


# ----------------------------------------------------- dtype-policy parity
# The policy axis is orthogonal to the input-dtype axis above: inputs stay
# fp32 and the *policy* decides what the tiles cast to / accumulate in.
POLICY_COMPUTE = {"bf16": jnp.bfloat16, "fp16": jnp.float16}


@pytest.mark.dtype
@pytest.mark.parametrize("shape", ODD_SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_policy_fp32_bitwise(shape, kind):
    """policy='fp32' must be the identity: every cast is a trace-time
    no-op, so outputs are bitwise equal to the unpolicied call — the
    default-path guarantee the whole policy layer rests on."""
    n, m, d = shape
    x, z, beta, v = _data(n, m, d, jnp.float32)
    kw = dict(kind=kind, sigma=_sigma(d))
    pairs = [
        (ops.gram(x, z, **kw), ops.gram(x, z, policy="fp32", **kw)),
        (ops.kmvp_fwd(x, z, beta, **kw),
         ops.kmvp_fwd(x, z, beta, policy="fp32", **kw)),
        (ops.kmvp_t(x, z, v, **kw),
         ops.kmvp_t(x, z, v, policy="fp32", **kw)),
        (ops.kmvp_fwd_chunked(x, z, beta, **kw),
         ops.kmvp_fwd_chunked(x, z, beta, policy="fp32", **kw)),
        (ops.kmvp_t_chunked(x, z, v, **kw),
         ops.kmvp_t_chunked(x, z, v, policy="fp32", **kw)),
    ]
    for base, policied in pairs:
        assert np.array_equal(np.asarray(base), np.asarray(policied))


@pytest.mark.dtype
@pytest.mark.parametrize("k", MULTI_KS)
@pytest.mark.parametrize("policy", sorted(POLICY_COMPUTE))
@pytest.mark.parametrize("kind", KINDS)
def test_policy_parity_grid(k, policy, kind):
    """bf16/fp16 policies vs the fp32 dense oracle at per-dtype tolerance,
    Pallas and chunked-jnp backends, odd shapes x kinds x k."""
    comp = POLICY_COMPUTE[policy]
    for shape in [(1, 3, 127), (129, 257, 3), (257, 127, 129)]:
        n, m, d = shape
        x, z, B, V = _multi_data(n, m, d, k, jnp.float32)
        kw = dict(kind=kind, sigma=_sigma(d))
        G = np.asarray(ref.gram_ref(x, z, **kw))
        assert_allclose_dtype(ops.gram(x, z, policy=policy, **kw), G, comp)
        for fwd, t in [(ops.kmvp_fwd, ops.kmvp_t),
                       (ops.kmvp_fwd_chunked, ops.kmvp_t_chunked)]:
            O = fwd(x, z, B, policy=policy, **kw)
            Gt = t(x, z, V, policy=policy, **kw)
            assert O.dtype == jnp.float32 and Gt.dtype == jnp.float32
            assert_allclose_dtype(O, G @ np.asarray(B), comp)
            assert_allclose_dtype(Gt, G.T @ np.asarray(V), comp)


@pytest.mark.dtype
@pytest.mark.parametrize("policy", sorted(POLICY_COMPUTE))
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("impl", ["pallas", "chunked"])
def test_policy_adjoint(policy, kind, impl):
    """Adjointness under a low-precision policy holds to the compute
    dtype's tolerance: fwd rounds B while t rounds V, so the pairing is
    exact only up to one input-rounding step on each side. The gap is
    normalized by the term mass sum(|O.V|) + sum(|B.G|), not the (heavily
    cancelled) pairing value itself — rounding acts on the terms."""
    from conftest import _DTYPE_TOL
    n, m, d = 129, 64, 16
    x, z, B, V = _multi_data(n, m, d, 3, jnp.float32)
    kw = dict(kind=kind, sigma=_sigma(d), policy=policy)
    if impl == "pallas":
        O, G = ops.kmvp_fwd(x, z, B, **kw), ops.kmvp_t(x, z, V, **kw)
    else:
        O = ops.kmvp_fwd_chunked(x, z, B, **kw)
        G = ops.kmvp_t_chunked(x, z, V, **kw)
    lhs, rhs = float(jnp.sum(O * V)), float(jnp.sum(B * G))
    scale = max(1.0, float(jnp.sum(jnp.abs(O * V)))
                + float(jnp.sum(jnp.abs(B * G))))
    tol = _DTYPE_TOL[np.dtype(POLICY_COMPUTE[policy]).name]
    assert abs(lhs - rhs) / scale < tol, (lhs, rhs, scale)


@pytest.mark.dtype
def test_policy_otf_memory_contract():
    """Under bf16 the Pallas otf path keeps fp32 out of HBM entirely
    (the f32 accumulator is VMEM scratch); the jnp fallback's finished
    chunk materializes at bf16 — its only fp32 transient is the
    chunk-sized dot accumulator, never the full C block."""
    from repro.core.introspect import max_intermediate_elems_of_dtype
    n, d, m, br = 64, 8, 32, 16
    x, z, _, _ = _data(n, m, d, jnp.float32)
    v = jnp.ones((n, 1), jnp.float32)
    kw = dict(kind="gaussian", sigma=_sigma(d))

    def otf_pallas(x, z, v):
        return ops.otf_kmvp_t(x, z, v, backend="pallas", block_rows=br,
                              policy="bf16", **kw)

    def otf_jnp(x, z, v):
        return ops.kmvp_t_chunked(x, z, v, block_rows=br, policy="bf16",
                                  **kw)

    # pallas: strictly no fp32 (rows, m) block anywhere in HBM
    worst = max_intermediate_elems_of_dtype(otf_pallas, "float32", x, z, v)
    assert worst < br * m, worst
    # fallback: fp32 bounded by one chunk (full C forbidden), and the
    # finished chunk really exists at the compute dtype
    worst32 = max_intermediate_elems_of_dtype(otf_jnp, "float32", x, z, v)
    worst16 = max_intermediate_elems_of_dtype(otf_jnp, "bfloat16", x, z, v)
    assert worst32 <= br * m < n * m, worst32
    assert worst16 >= br * m, worst16


def test_kmvp_block_divisibility_errors():
    """The raw Pallas entry points reject non-divisible dims with errors
    naming the offending dim and block (the old bare asserts said nothing)."""
    from repro.kernels import kmvp
    x = jnp.zeros((100, 128))
    z = jnp.zeros((128, 128))
    b = jnp.zeros((128, 1))
    v = jnp.zeros((100, 1))
    with pytest.raises(ValueError, match=r"n=100.*bn=256"):
        kmvp.kmvp_fwd_pallas(x, z, b, bn=256, bm=128, bd=128)
    with pytest.raises(ValueError, match=r"m=128.*bm=96"):
        kmvp.kmvp_fwd_pallas(jnp.zeros((128, 128)), z, b,
                             bn=128, bm=96, bd=128)
    with pytest.raises(ValueError, match=r"d=128.*bd=100"):
        kmvp.kmvp_t_pallas(jnp.zeros((128, 128)), z, jnp.zeros((128, 1)),
                           bn=128, bm=128, bd=100)
    with pytest.raises(ValueError, match=r"kmvp_t_pallas.*n=100"):
        kmvp.kmvp_t_pallas(x, z, v, bn=256, bm=128, bd=128)
    with pytest.raises(ValueError, match=r"positive"):
        kmvp.kmvp_fwd_pallas(x, z, b, bn=0, bm=128, bd=128)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("shape", [(64, 32, 16), (129, 257, 3)])
@pytest.mark.parametrize("impl", ["pallas", "chunked"])
def test_kmvp_adjoint(shape, kind, impl):
    """<kmvp_fwd(x,z,b), v> == <b, kmvp_t(x,z,v)>: the two fused kernels
    are adjoints of the same implicit C and can never drift apart."""
    n, m, d = shape
    x, z, beta, v = _data(n, m, d, jnp.float32)
    kw = dict(kind=kind, sigma=_sigma(d))
    if impl == "pallas":
        o, g = ops.kmvp_fwd(x, z, beta, **kw), ops.kmvp_t(x, z, v, **kw)
    else:
        o = ops.kmvp_fwd_chunked(x, z, beta, **kw)
        g = ops.kmvp_t_chunked(x, z, v, **kw)
    lhs, rhs = float(o @ v), float(beta @ g)
    scale = max(1.0, abs(lhs), abs(rhs))
    assert abs(lhs - rhs) / scale < 1e-5, (lhs, rhs)


def test_block_tiny_size_regression():
    """_block must not balloon a 1-row input to a full alignment block."""
    assert ops._block(1, 256, 8, True) == 1        # interpret: exact size
    assert ops._block(3, 256, 128, True) == 3
    assert ops._block(1, 256, 8, False) == 8       # TPU: one align unit
    assert ops._block(1, 256, 128, False) == 128
    assert ops._block(2, 4, 8, False) == 8         # want < align stays legal
    assert ops._block(300, 256, 8, True) == 256    # large sizes unchanged
    # end-to-end: n=1 stays correct through the padding path
    x, z, beta, v = _data(1, 37, 5, jnp.float32)
    kw = dict(kind="gaussian", sigma=_sigma(5))
    assert ops.gram(x, z, **kw).shape == (1, 37)
    assert_allclose_dtype(ops.gram(x, z, **kw), ref.gram_ref(x, z, **kw),
                          jnp.float32)
    assert_allclose_dtype(ops.kmvp_fwd(x, z, beta, **kw),
                          ref.kmvp_ref(x, z, beta, **kw), jnp.float32)


def test_otf_block_heuristics():
    """Per-shard-n heuristics: aligned, bounded, never a full-C chunk."""
    for n in (8, 64, 256, 4096, 100_000):
        for m in (16, 128, 1024):
            bn = ops.otf_block_rows(n, m, 10)
            assert bn % 8 == 0 and bn >= 8
            assert bn * m * 4 <= max(1 << 20, 8 * m * 4)   # budget or floor
            if n >= 64:
                assert bn < n                               # real chunking
    bn, bm, bd = ops.otf_tiles(4096, 512, 784)
    assert bn % 8 == 0 and bm % 128 == 0 and bd % 128 == 0
    assert 4 * (bn * bd + bm * bd + bn * bm) <= 4 << 20


def test_block_shape_invariance():
    """Result must not depend on BlockSpec tile choice."""
    x, z, beta, v = _data(384, 256, 96, jnp.float32)
    base = ops.gram(x, z, sigma=10.0, bn=256, bm=256, bd=256)
    for bn, bm, bd in [(64, 128, 128), (8, 128, 256), (128, 256, 128)]:
        got = ops.gram(x, z, sigma=10.0, bn=bn, bm=bm, bd=bd)
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-6)


def test_gram_backend_integration():
    """core.nystrom routes backend='pallas' through the kernel."""
    from repro.core.nystrom import KernelSpec, gram
    x, z, _, _ = _data(100, 40, 12, jnp.float32)
    kern = KernelSpec("gaussian", sigma=3.0)
    np.testing.assert_allclose(gram(x, z, kern, "pallas"),
                               gram(x, z, kern, "jnp"), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 16, 8, 3, 4), (3, 64, 32, 2, 16),
                                   (1, 128, 64, 4, 32)])
def test_ssd_chunk_matches_ref(shape):
    """Pallas SSD within-chunk kernel vs jnp oracle."""
    G, Q, N, H, P = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    Cc = jax.random.normal(ks[0], (G, Q, N), jnp.float32)
    Bc = jax.random.normal(ks[1], (G, Q, N), jnp.float32)
    dA = -jnp.abs(jax.random.normal(ks[2], (G, H, Q), jnp.float32)) * 0.1
    xdt = jax.random.normal(ks[3], (G, H, Q, P), jnp.float32)
    got = ops.ssd_chunk(Cc, Bc, dA, xdt)
    want = ref.ssd_chunk_ref(Cc, Bc, dA, xdt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_consistent_with_model_path():
    """Kernel output == the ssd_scan diagonal term used by the model."""
    from repro.models.ssm import _segsum
    G, Q, N, H, P = 2, 32, 16, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    Cc = jax.random.normal(ks[0], (G, Q, N), jnp.float32)
    Bc = jax.random.normal(ks[1], (G, Q, N), jnp.float32)
    dA = -jnp.abs(jax.random.normal(ks[2], (G, H, Q), jnp.float32)) * 0.1
    xdt = jax.random.normal(ks[3], (G, H, Q, P), jnp.float32)
    L = jnp.exp(_segsum(dA))
    scores = jnp.einsum("gqn,gkn->gqk", Cc, Bc)
    want = jnp.einsum("ghqk,ghkp->ghqp",
                      jnp.where(jnp.isfinite(L), scores[:, None] * L, 0.0), xdt)
    got = ops.ssd_chunk(Cc, Bc, dA, xdt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
