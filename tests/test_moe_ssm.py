"""MoE routing and Mamba2/SSD unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import unzip


# ------------------------------------------------------------------- MoE
def _moe_setup(E=4, k=2, d=32, ff=64, cf=8.0):
    cfg = ARCHS["grok-1-314b"].reduced(
        n_experts=E, top_k=k, moe_d_ff=ff, d_model=d, capacity_factor=cf)
    params, _ = unzip(moe_mod.init_moe(jax.random.PRNGKey(0), cfg))
    return cfg, params


def test_moe_output_shape_and_finite():
    cfg, params = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y, aux = moe_mod.apply_moe(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0


def test_moe_matches_dense_expert_sum():
    """With huge capacity (no dropping), grouped dispatch must equal the
    direct per-token weighted sum over its top-k experts."""
    cfg, params = _moe_setup(cf=100.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 32), jnp.float32)
    y, _ = moe_mod.apply_moe(params, cfg, x)

    xt = x.reshape(8, 32)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(xt)
    for t in range(8):
        acc = jnp.zeros((32,))
        for j in range(cfg.top_k):
            e = int(gi[t, j])
            h = jax.nn.silu(xt[t] @ params["w1"][e]) * (xt[t] @ params["w3"][e])
            acc = acc + gv[t, j] * (h @ params["w2"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """With capacity_factor ~ 0 tokens get dropped -> output ~ 0 (no shared)."""
    cfg, params = _moe_setup(cf=1e-6)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 32), jnp.float32)
    y, _ = moe_mod.apply_moe(params, cfg, x)
    # capacity floor is 4 per expert -> most tokens dropped, tiny norm
    full_cfg, _ = _moe_setup(cf=100.0)
    y_full, _ = moe_mod.apply_moe(params, full_cfg, x)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))


# ------------------------------------------------------------------- SSD
def naive_ssd(xh, dt, Bm, Cm, A):
    """Direct recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t; y = C h."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = np.zeros((Bsz, H, N, P), np.float64)
    ys = []
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t] * A[None, :], np.float64))
        upd = np.einsum("bn,bh,bhp->bhnp", np.asarray(Bm[:, t], np.float64),
                        np.asarray(dt[:, t], np.float64),
                        np.asarray(xh[:, t], np.float64))
        h = decay[:, :, None, None] * h + upd
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, t], np.float64), h))
    return np.stack(ys, axis=1)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_scan_matches_naive_recurrence(chunk):
    key = jax.random.PRNGKey(0)
    Bsz, S, H, P, N = 2, 16, 3, 4, 5
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    Bm = jax.random.normal(ks[2], (Bsz, S, N))
    Cm = jax.random.normal(ks[3], (Bsz, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    got = ssm_mod.ssd_scan(xh, dt, Bm, Cm, A, chunk)
    want = naive_ssd(xh, dt, Bm, Cm, np.asarray(A))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


def test_ssm_decode_matches_train():
    """ssm_train over a sequence == repeated ssm_decode state updates."""
    cfg = ARCHS["mamba2-1.3b"].reduced(ssm_chunk=8)
    params, _ = unzip(ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg))
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y_train = ssm_mod.ssm_train(params, cfg, h)
    cache = jax.tree.map(lambda x: x[0],
                         ssm_mod.init_ssm_cache(cfg, 2, layers=1))
    outs = []
    for t in range(16):
        y, cache = ssm_mod.ssm_decode(params, cfg, h[:, t: t + 1], cache, t)
        outs.append(y[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=2e-3, atol=2e-3)
