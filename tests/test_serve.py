"""kernel_serve internals: bucketing, executable-cache bounds, plan
routing, and multiclass label fidelity.

The serving driver rides the shared plan-registry inference engine
(``KernelMachine.decider``) — these tests pin the pieces the ``--selftest``
smoke exercises only end-to-end: power-of-two bucket arithmetic at its
boundaries, the jit-cache staying bounded under a mixed-size request
stream, the stream->local plan flip for out-of-core-trained machines, and
served multiclass argmax labels equalling ``predict``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelMachine, MachineConfig, StreamConfig
from repro.core import KernelSpec, TronConfig, random_basis
from repro.data import make_classification, make_multiclass
from repro.launch.kernel_serve import ServingEndpoint, _bucket, _serving_plan

N, D, M = 512, 12, 32
CFG = MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=1.0,
                    tron=TronConfig(max_iter=60),
                    stream=StreamConfig(chunk_rows=128))


@pytest.fixture(scope="module")
def km():
    X, y = make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=4)
    basis = random_basis(jax.random.PRNGKey(1), X, M)
    return KernelMachine(CFG).fit(X, y, basis)


@pytest.fixture(scope="module")
def km_mc():
    X, y = make_multiclass(jax.random.PRNGKey(0), N, D, 3,
                           clusters_per_class=2)
    basis = random_basis(jax.random.PRNGKey(1), X, M)
    return KernelMachine(CFG).fit(X, y, basis)


# ------------------------------------------------------------------ buckets
def test_bucket_boundaries():
    # floor is MIN_BUCKET=2: a (1, d) dispatch lowers to a different XLA
    # dot strategy than multi-row shapes, and the resulting one-ULP drift
    # would break the serve engine's coalescing determinism contract
    assert _bucket(1, 256) == 2
    assert _bucket(2, 256) == 2
    assert _bucket(3, 256) == 4          # just above a bucket -> next pow2
    assert _bucket(64, 256) == 64        # exact power of two: no padding
    assert _bucket(65, 256) == 128
    assert _bucket(256, 256) == 256      # n == max_batch: top bucket
    assert _bucket(257, 256) == 256      # capped (caller splits oversize)


def test_endpoint_boundary_batches(km):
    """n == 1, n == max_batch, and n just above a bucket all serve and
    match the direct decision path."""
    endpoint = ServingEndpoint(km, max_batch=64)
    for n in (1, 2, 3, 63, 64, 65):
        Xq = jax.random.normal(jax.random.PRNGKey(n), (n, D))
        served = endpoint(Xq)
        assert served.shape == (n,)
        direct = km.decision_function(Xq)
        assert float(jnp.max(jnp.abs(served - direct))) < 1e-5, n


def test_endpoint_splits_oversize_requests(km):
    endpoint = ServingEndpoint(km, max_batch=64)
    Xq = jax.random.normal(jax.random.PRNGKey(3), (150, D))  # 64+64+22
    served = endpoint(Xq)
    assert served.shape == (150,)
    direct = km.decision_function(Xq)
    assert float(jnp.max(jnp.abs(served - direct))) < 1e-5
    # oversize splitting reuses the same buckets, so 64 and 32 only
    assert endpoint.n_executables <= 2


def test_executable_cache_bounded_under_mixed_sizes(km):
    """A mixed-size request stream compiles at most log2(max_batch)+1
    executables — the whole point of bucketing."""
    endpoint = ServingEndpoint(km, max_batch=64)
    rng = np.random.default_rng(0)
    for s in rng.integers(1, 65, size=40):
        endpoint(jnp.zeros((int(s), D)))
    assert endpoint.n_executables <= 7    # {1,2,4,8,16,32,64}
    # replaying the same stream adds nothing
    before = endpoint.n_executables
    for s in rng.integers(1, 65, size=40):
        endpoint(jnp.zeros((int(s), D)))
    assert endpoint.n_executables == before


# ----------------------------------------------------------- plan routing
def test_serving_plan_resolution(km):
    assert _serving_plan(km, None) == "local"
    assert _serving_plan(km, "otf_shard") == "otf_shard"
    stream_km = KernelMachine(CFG.replace(plan="stream"))
    stream_km.state_ = km.state_          # plan routing only reads config
    assert _serving_plan(stream_km, None) == "local"
    assert _serving_plan(stream_km, "stream") == "local"


def test_stream_trained_machine_serves(km):
    """The plan-override symmetry: a stream-trained machine serves small
    batches through the local decide arm, matching its own chunked path."""
    X, y = make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=4)
    basis = random_basis(jax.random.PRNGKey(1), X, M)
    skm = KernelMachine(CFG.replace(plan="stream")).fit(X, y, basis)
    endpoint = ServingEndpoint(skm, max_batch=64)
    assert endpoint.plan == "local"
    Xq = jax.random.normal(jax.random.PRNGKey(5), (37, D))
    served = endpoint(Xq)
    chunked = skm.decision_function(Xq)        # config plan: stream
    assert float(np.max(np.abs(np.asarray(served) -
                               np.asarray(chunked)))) < 1e-5


def test_endpoint_fused_plan_arm(km):
    """Serving through a mesh decide arm (otf_shard) matches local."""
    endpoint = ServingEndpoint(km, max_batch=64, plan="otf_shard")
    Xq = jax.random.normal(jax.random.PRNGKey(6), (21, D))
    direct = km.decision_function(Xq, plan="local")
    assert float(jnp.max(jnp.abs(endpoint(Xq) - direct))) < 1e-5


# ------------------------------------------------------------- multiclass
def test_served_multiclass_labels_equal_predict(km_mc):
    """Served (b, K) margins come from ONE multi-RHS evaluation and their
    argmax labels equal the direct predict path, across bucket sizes."""
    endpoint = ServingEndpoint(km_mc, max_batch=64)
    for n in (1, 37, 64):
        Xq = jax.random.normal(jax.random.PRNGKey(n), (n, D))
        served = endpoint(Xq)
        assert served.shape == (n, 3)
        labels = km_mc.state_["classes"][jnp.argmax(served, axis=-1)]
        np.testing.assert_array_equal(np.asarray(labels),
                                      np.asarray(km_mc.predict(Xq)))
