"""Hypothesis property tests on system invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dependency: property tests need hypothesis")
import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import Formulation4, KernelSpec, build_C, build_W, get_loss
from repro.core.tron import TronConfig, tron
from repro.kernels import ops, ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

finite_f32 = st.floats(-5.0, 5.0, allow_nan=False, width=32)


@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=2, max_side=24),
                  elements=finite_f32))
def test_gaussian_gram_range_and_symmetry(x):
    """0 < W_kl <= 1, W symmetric, diag == 1 (gaussian kernel axioms)."""
    kern = KernelSpec("gaussian", sigma=1.5)
    W = np.asarray(build_W(jnp.asarray(x), kern))
    assert (W >= 0).all() and (W <= 1.0 + 1e-6).all()  # exp may underflow to 0
    np.testing.assert_allclose(W, W.T, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.diag(W), 1.0, rtol=1e-5)


@given(hnp.arrays(np.float32, (12, 6), elements=finite_f32),
       hnp.arrays(np.float32, (5, 6), elements=finite_f32))
def test_gram_psd_nystrom(x, z):
    """W must be PSD (it is a Gram matrix) — min eigenvalue >= -eps."""
    W = np.asarray(build_W(jnp.asarray(z), KernelSpec("gaussian", sigma=2.0)))
    evals = np.linalg.eigvalsh(W)
    assert evals.min() > -1e-4


@given(st.integers(1, 40), st.integers(1, 30), st.integers(1, 20),
       st.sampled_from(["gaussian", "linear"]))
def test_pallas_gram_any_shape(n, m, d, kind):
    """Pallas gram == oracle for arbitrary (unaligned) shapes."""
    k = jax.random.PRNGKey(n * 1000 + m * 10 + d)
    x = jax.random.normal(k, (n, d), jnp.float32)
    z = jax.random.normal(jax.random.fold_in(k, 1), (m, d), jnp.float32)
    got = ops.gram(x, z, kind=kind, sigma=float(np.sqrt(d)))
    want = ref.gram_ref(x, z, kind=kind, sigma=float(np.sqrt(d)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(hnp.arrays(np.float32, (16,), elements=finite_f32),
       hnp.arrays(np.float32, (16,), elements=st.floats(-1, 1, width=32)))
def test_kmvp_linearity(beta1, beta2):
    """kmvp(beta1 + beta2) == kmvp(beta1) + kmvp(beta2) (linear operator)."""
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (24, 8), jnp.float32)
    z = jax.random.normal(jax.random.fold_in(k, 1), (16, 8), jnp.float32)
    o12 = ops.kmvp_fwd(x, z, jnp.asarray(beta1 + beta2), sigma=3.0)
    o1 = ops.kmvp_fwd(x, z, jnp.asarray(beta1), sigma=3.0)
    o2 = ops.kmvp_fwd(x, z, jnp.asarray(beta2), sigma=3.0)
    np.testing.assert_allclose(o12, o1 + o2, rtol=1e-3, atol=1e-3)


@given(st.sampled_from(["squared_hinge", "logistic", "squared"]),
       hnp.arrays(np.float32, (9,), elements=finite_f32))
def test_loss_gauss_newton_diag_nonneg(loss_name, o):
    """D >= 0 — required for the Gauss-Newton Hd to be PSD (CG validity)."""
    loss = get_loss(loss_name)
    y = jnp.asarray(np.sign(np.arange(9) % 2 - 0.5), jnp.float32)
    D = np.asarray(loss.diag(jnp.asarray(o), y))
    assert (D >= 0).all()


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10)
def test_tron_objective_never_increases(seed):
    """Final objective <= initial objective for any PSD quadratic."""
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (12, 12))
    H = A @ A.T + 0.1 * jnp.eye(12)
    b = jax.random.normal(jax.random.fold_in(key, 1), (12,))
    x0 = jax.random.normal(jax.random.fold_in(key, 2), (12,))
    fgrad = lambda x: (0.5 * x @ (H @ x) - b @ x, H @ x - b, jnp.zeros(()))
    res = tron(fgrad, lambda a, d: H @ d, x0, TronConfig(max_iter=30))
    f0 = 0.5 * x0 @ (H @ x0) - b @ x0
    assert float(res.f) <= float(f0) + 1e-5
