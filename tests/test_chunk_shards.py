"""Shard-boundary property tests for :class:`MmapChunkSource`.

The stream plan's correctness rests on ``_rows(lo, hi)`` returning
exactly rows ``[lo, hi)`` of the logical concatenation of the shards —
for ANY alignment of chunk boundaries against shard boundaries. The
risky geometries are chunk sizes coprime with the shard size (every
chunk straddles differently), chunks spanning MORE than two shards, and
a ragged final shard shorter than the rest. Each case is checked
row-for-row against the in-memory array the shards were written from,
for both ``.npy`` (mmap'd) and ``.npz`` (lazily inflated) layouts, with
and without ``meta.json`` fast-path layout probing.
"""
import numpy as np
import pytest

from repro.data.chunks import MmapChunkSource, save_chunks


def _make(tmp_path, n, d=5, rows_per_shard=16, compress=False, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.integers(0, 3, size=n).astype(np.int64)
    dd = tmp_path / f"shards_n{n}_r{rows_per_shard}_{int(compress)}_s{seed}"
    save_chunks(dd, X, y, rows_per_shard=rows_per_shard, compress=compress)
    return X, y, dd


def _check_all_chunks(src, X, y):
    at = 0
    for i in range(src.n_chunks):
        Xc, yc = src.chunk(i)
        rows = Xc.shape[0]
        np.testing.assert_array_equal(Xc, X[at:at + rows])
        np.testing.assert_array_equal(yc, y[at:at + rows])
        at += rows
    assert at == X.shape[0], "chunks did not cover every row exactly once"


@pytest.mark.parametrize("compress", [False, True], ids=["npy", "npz"])
@pytest.mark.parametrize("n,rows_per_shard,chunk_rows", [
    (100, 16, 7),     # 7 coprime 16: every boundary lands differently
    (100, 16, 37),    # chunk spans 3+ shards
    (100, 16, 100),   # one chunk spans ALL shards (incl. ragged last: 4)
    (64, 16, 16),     # exact alignment (degenerate control)
    (65, 16, 64),     # ragged final shard of 1 row
    (30, 7, 11),      # ragged shards AND coprime chunks
])
def test_chunks_reassemble_exactly(tmp_path, compress, n, rows_per_shard,
                                   chunk_rows):
    X, y, dd = _make(tmp_path, n, rows_per_shard=rows_per_shard,
                     compress=compress)
    src = MmapChunkSource(dd, chunk_rows=chunk_rows)
    assert (src.n, src.d) == X.shape
    _check_all_chunks(src, X, y)
    # rechunking reuses the probed layout; must stay exact
    _check_all_chunks(src.with_chunk_rows(max(1, chunk_rows // 2)), X, y)


def test_rows_every_span(tmp_path):
    """Exhaustive (lo, hi) sweep at small n: every window, every length —
    including windows spanning 3, 4 and all 5 shards."""
    X, y, dd = _make(tmp_path, 37, rows_per_shard=8)
    src = MmapChunkSource(dd, chunk_rows=8)
    for lo in range(37):
        for hi in range(lo + 1, 38):
            Xr, yr = src._rows(lo, hi)
            assert Xr.shape[0] == hi - lo, f"short read on [{lo}, {hi})"
            np.testing.assert_array_equal(Xr, X[lo:hi])
            np.testing.assert_array_equal(yr, y[lo:hi])


def test_probe_without_meta_json(tmp_path):
    """Layout probing must agree with meta.json fast path (header reads)."""
    X, y, dd = _make(tmp_path, 50, rows_per_shard=8)
    (dd / "meta.json").unlink()
    src = MmapChunkSource(dd, chunk_rows=13)
    assert (src.n, src.d) == X.shape
    _check_all_chunks(src, X, y)


def test_take_rows_across_shards(tmp_path):
    X, y, dd = _make(tmp_path, 60, rows_per_shard=8)
    src = MmapChunkSource(dd, chunk_rows=16)
    # unsorted, duplicated, boundary-adjacent indices spanning many shards
    idx = np.array([59, 0, 8, 7, 8, 23, 24, 55, 16, 0, 39, 40, 15])
    np.testing.assert_array_equal(src.take_rows(idx), X[idx])
    # boundary-exact block reads
    np.testing.assert_array_equal(src.take_rows(np.arange(8, 24)), X[8:24])


def test_labels_only_reads(tmp_path):
    X, y, dd = _make(tmp_path, 45, rows_per_shard=8)
    src = MmapChunkSource(dd, chunk_rows=10)
    np.testing.assert_array_equal(np.concatenate(list(src.iter_y())), y)
    np.testing.assert_array_equal(src.unique_labels(), np.unique(y))


def test_randomized_geometry_hammer(tmp_path):
    """Seeded sweep over (n, rows_per_shard, chunk_rows) geometries."""
    rng = np.random.default_rng(42)
    for trial in range(12):
        n = int(rng.integers(10, 200))
        rps = int(rng.integers(3, 40))
        cr = int(rng.integers(1, n + 1))
        X, y, dd = _make(tmp_path, n, rows_per_shard=rps, seed=trial + 1)
        src = MmapChunkSource(dd, chunk_rows=cr)
        _check_all_chunks(src, X, y)
        lo = int(rng.integers(0, n))
        hi = int(rng.integers(lo + 1, n + 1))
        Xr, _ = src._rows(lo, hi)
        np.testing.assert_array_equal(Xr, X[lo:hi])
