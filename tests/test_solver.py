"""End-to-end kernel machine behaviour (paper's empirical claims, scaled)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (KernelSpec, TronConfig, get_loss, random_basis,
                        select_basis, solve)
from repro.core import ppacksvm as pps
from repro.core.stagewise import stagewise_solve
from repro.data import make_classification, make_dataset


@pytest.fixture(scope="module")
def data():
    X_all, y_all = make_classification(jax.random.PRNGKey(0), 6144, 16,
                                       clusters_per_class=8, margin=1.0)
    return (X_all[:4096], y_all[:4096], X_all[4096:], y_all[4096:])


def test_accuracy_increases_with_m(data):
    """Fig. 1: test accuracy rises with basis size and saturates."""
    X, y, Xt, yt = data
    kern = KernelSpec("gaussian", sigma=2.0)
    accs = []
    for m in (16, 64, 512):
        basis = random_basis(jax.random.PRNGKey(1), X, m)
        mach = solve(X, y, basis, lam=1.0, kernel=kern,
                     cfg=TronConfig(max_iter=60))
        accs.append(mach.accuracy(Xt, yt))
    assert accs[0] < accs[1] < accs[2] + 1e-3
    assert accs[2] > 0.97


def test_nonlinear_beats_linear(data):
    X, y, Xt, yt = data
    basis = random_basis(jax.random.PRNGKey(1), X, 256)
    rbf = solve(X, y, basis, lam=1.0, kernel=KernelSpec("gaussian", sigma=2.0))
    lin = solve(X, y, basis, lam=1.0, kernel=KernelSpec("linear"))
    assert rbf.accuracy(Xt, yt) > lin.accuracy(Xt, yt) + 0.05


def test_kmeans_basis_beats_random_at_small_m(data):
    """Table 2: K-means selection helps when m is small."""
    X, y, Xt, yt = data
    kern = KernelSpec("gaussian", sigma=2.0)
    accs = {}
    for strat in ("random", "kmeans"):
        basis = select_basis(jax.random.PRNGKey(7), X, 24, strategy=strat,
                             n_iter=5)
        mach = solve(X, y, basis, lam=1.0, kernel=kern,
                     cfg=TronConfig(max_iter=60))
        accs[strat] = mach.accuracy(Xt, yt)
    assert accs["kmeans"] >= accs["random"] - 0.02  # usually strictly better


def test_stagewise_matches_from_scratch(data):
    """Stage-wise basis addition reaches the same optimum as one shot."""
    X, y, Xt, yt = data
    kern = KernelSpec("gaussian", sigma=2.0)
    basis = random_basis(jax.random.PRNGKey(2), X, 128)
    stages = [basis[:32], basis[32:64], basis[64:]]
    loss = get_loss("squared_hinge")
    cfg = TronConfig(max_iter=80, grad_rtol=1e-4)
    results = stagewise_solve(X, y, stages, lam=1.0, loss=loss, kernel=kern,
                              cfg=cfg)
    mach = solve(X, y, basis, lam=1.0, kernel=kern, cfg=cfg)
    assert results[-1].m == 128
    # same final objective value
    assert abs(results[-1].f - float(mach.stats.f)) / float(mach.stats.f) < 1e-2
    # objective decreases as basis grows
    assert results[0].f >= results[1].f >= results[2].f


def test_ppacksvm_baseline_reasonable(data):
    X, y, Xt, yt = data
    kern = KernelSpec("gaussian", sigma=2.0)
    res = pps.ppacksvm(jax.random.PRNGKey(3), X[:2048], y[:2048], lam=1e-3,
                       kernel=kern, epochs=2, pack_size=64)
    o = pps.predict(res.alpha, X[:2048], Xt, kern)
    acc = float(jnp.mean(jnp.sign(o) == yt))
    assert acc > 0.9
    assert res.n_rounds == (2048 * 2) // 64


def test_paper_dataset_simulators():
    for name in ("vehicle", "covtype", "ccat", "mnist8m"):
        X, y, Xt, yt, spec = make_dataset(name, jax.random.PRNGKey(0),
                                          scale=0.005, d_cap=64)
        assert X.shape[0] >= 256 and X.shape[1] <= 64
        assert set(jnp.unique(y).tolist()) <= {-1.0, 1.0}


def test_rff_baseline_and_nystrom_edge(data):
    """Paper §5: RFF alternative; data-dependent Nystrom >= RFF at small m."""
    from repro.core.rff import rff_features, sample_rff, solve_rff
    X, y, Xt, yt = data
    sigma = 2.0
    # RFF approximates the kernel in expectation
    basis = sample_rff(jax.random.PRNGKey(0), X.shape[1], 2048, sigma)
    approx = rff_features(X[:64], basis) @ rff_features(X[:64], basis).T
    from repro.core import KernelSpec, build_C
    exact = build_C(X[:64], X[:64], KernelSpec("gaussian", sigma=sigma))
    assert float(jnp.max(jnp.abs(approx - exact))) < 0.15
    # accuracy at equal budget
    m = 48
    rff = solve_rff(jax.random.PRNGKey(1), X, y, m, lam=1.0, sigma=sigma,
                    cfg=TronConfig(max_iter=60))
    nys = solve(X, y, random_basis(jax.random.PRNGKey(2), X, m), lam=1.0,
                kernel=KernelSpec("gaussian", sigma=sigma),
                cfg=TronConfig(max_iter=60))
    assert nys.accuracy(Xt, yt) >= rff.accuracy(Xt, yt) - 0.03
