"""Basis selection (repro.core.basis): strategy dispatch, determinism, and
mesh/local agreement of the distributed K-means — paper §3.2's recipe.

``select_basis`` is the entry every fit() without an explicit basis goes
through, so a silent dispatch regression (auto picking the wrong strategy,
kmeans drifting between runs) would skew every downstream accuracy table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.basis import kmeans, random_basis, select_basis
from repro.core.compat import make_mesh
from repro.data import make_classification

N, D, M = 512, 6, 16
KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def X():
    return make_classification(jax.random.PRNGKey(0), N, D,
                               clusters_per_class=4)[0]


# ------------------------------------------------------------------ dispatch
def test_auto_picks_kmeans_below_threshold(X):
    """auto == kmeans when m and d sit under both thresholds."""
    auto = select_basis(KEY, X, M, strategy="auto")
    km = select_basis(KEY, X, M, strategy="kmeans")
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(km))


def test_auto_crosses_to_random_on_large_m(X):
    """auto == random once m exceeds kmeans_threshold (the paper's Table 2
    cost blow-up regime)."""
    auto = select_basis(KEY, X, M, strategy="auto", kmeans_threshold=M - 1)
    rnd = select_basis(KEY, X, M, strategy="random")
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(rnd))


def test_auto_crosses_to_random_on_wide_features(X):
    auto = select_basis(KEY, X, M, strategy="auto",
                        n_features_threshold=D - 1)
    rnd = select_basis(KEY, X, M, strategy="random")
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(rnd))


def test_explicit_strategies_differ(X):
    """kmeans must actually move points: Lloyd centroids are means, not
    members of X (random picks training rows verbatim)."""
    km = np.asarray(select_basis(KEY, X, M, strategy="kmeans"))
    rnd = np.asarray(select_basis(KEY, X, M, strategy="random"))
    assert km.shape == rnd.shape == (M, D)
    assert np.max(np.abs(km - rnd)) > 1e-3
    # every random-basis row is a training row; kmeans rows generally aren't
    Xn = np.asarray(X)
    assert all((Xn == r).all(axis=1).any() for r in rnd)


def test_unknown_strategy_raises(X):
    with pytest.raises(ValueError, match="unknown basis strategy"):
        select_basis(KEY, X, M, strategy="medoid")


# -------------------------------------------------------------- determinism
def test_kmeans_deterministic_under_fixed_key(X):
    c1, t1 = kmeans(KEY, X, M, n_iter=3)
    c2, t2 = kmeans(KEY, X, M, n_iter=3)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_kmeans_inertia_decreases(X):
    _, trace = kmeans(KEY, X, M, n_iter=4)
    trace = np.asarray(trace)
    assert trace.shape == (4,)
    assert trace[-1] <= trace[0]


def test_random_basis_rows_unique(X):
    b = np.asarray(random_basis(KEY, X, M))
    assert np.unique(b, axis=0).shape[0] == M     # without replacement


# --------------------------------------------------------- mesh/local parity
def test_kmeans_mesh_matches_local(X):
    """The distributed Lloyd step (local partial sums + psum) must agree
    with the single-device scan — identical math, different reduction."""
    mesh = make_mesh((1,), ("data",))
    c_local, t_local = kmeans(KEY, X, M, n_iter=3)
    c_mesh, t_mesh = kmeans(KEY, X, M, n_iter=3, mesh=mesh,
                            data_axes=("data",))
    np.testing.assert_allclose(np.asarray(c_mesh), np.asarray(c_local),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(t_mesh), np.asarray(t_local),
                               rtol=1e-5, atol=1e-3)


def test_select_basis_kmeans_routes_through_mesh(X):
    mesh = make_mesh((1,), ("data",))
    c_mesh = select_basis(KEY, X, M, strategy="kmeans", mesh=mesh,
                          data_axes=("data",))
    c_local = select_basis(KEY, X, M, strategy="kmeans")
    np.testing.assert_allclose(np.asarray(c_mesh), np.asarray(c_local),
                               rtol=1e-5, atol=1e-5)
