"""Unified KernelMachine API: registries, parity with legacy entrypoints,
save/load round-trips, stage-wise partial_fit."""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.api import (KernelMachine, MachineConfig, available_plans,
                       available_solvers, get_solver, valid_combinations,
                       validate)
from repro.core import KernelSpec, TronConfig, get_loss, random_basis
from repro.data import make_classification

KERN = KernelSpec("gaussian", sigma=2.0)
CFG = MachineConfig(kernel=KERN, lam=0.5, tron=TronConfig(max_iter=60),
                    rff_features=64)


@pytest.fixture(scope="module")
def data():
    X_all, y_all = make_classification(jax.random.PRNGKey(0), 1280, 12,
                                       clusters_per_class=4, margin=1.0)
    return X_all[:1024], y_all[:1024], X_all[1024:], y_all[1024:]


@pytest.fixture(scope="module")
def basis(data):
    return random_basis(jax.random.PRNGKey(1), data[0], 64)


# ---------------------------------------------------------------- registries
def test_registries_populated():
    assert set(available_solvers()) == {"tron", "linearized", "rff",
                                        "ppacksvm"}
    assert set(available_plans()) == {"local", "shard_map", "auto", "otf",
                                      "otf_shard", "stream"}


def test_invalid_composition_raises_at_construction():
    with pytest.raises(ValueError, match="does not support execution plan"):
        KernelMachine(CFG.replace(solver="ppacksvm", plan="shard_map"))
    with pytest.raises(KeyError, match="unknown solver"):
        validate("no_such_solver", "local")
    with pytest.raises(KeyError, match="unknown execution plan"):
        validate("tron", "no_such_plan")


@pytest.mark.parametrize("solver,plan", valid_combinations())
def test_every_valid_combination_trains(data, basis, solver, plan):
    """Registry round-trip: every solver x valid plan fits synthetic data."""
    X, y, Xt, yt = data
    km = KernelMachine(CFG.replace(solver=solver, plan=plan))
    km.fit(X, y, basis if get_solver(solver).needs_basis else None)
    assert km.result_.solver == solver and km.result_.plan == plan
    assert km.score(Xt, yt) > 0.85
    assert km.decision_function(Xt).shape == (Xt.shape[0],)


# ------------------------------------------------------------ legacy parity
def test_fit_matches_legacy_solve_every_solver(data, basis):
    """beta parity vs the pre-API entrypoints at 1e-5."""
    X, y, _, _ = data
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import solve
        from repro.core.rff import solve_rff
    from repro.core.linearized import solve_linearized
    from repro.core import ppacksvm as pps

    km = KernelMachine(CFG).fit(X, y, basis)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        mach = solve(X, y, basis, lam=0.5, kernel=KERN,
                     cfg=TronConfig(max_iter=60))
    assert float(jnp.max(jnp.abs(km.state_["beta"] - mach.beta))) < 1e-5

    km = KernelMachine(CFG.replace(solver="rff", seed=3)).fit(X, y)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        rff = solve_rff(jax.random.PRNGKey(3), X, y, 64, lam=0.5, sigma=2.0,
                        cfg=TronConfig(max_iter=60))
    assert float(jnp.max(jnp.abs(km.state_["beta"] - rff.w))) < 1e-5

    km = KernelMachine(CFG.replace(solver="linearized")).fit(X, y, basis)
    res = solve_linearized(X, y, basis, lam=0.5, loss=get_loss("squared_hinge"),
                           kernel=KERN, cfg=TronConfig(max_iter=60))
    assert float(jnp.max(jnp.abs(km.state_["beta"] - res.beta))) < 1e-5

    km = KernelMachine(CFG.replace(solver="ppacksvm", seed=5)).fit(X, y)
    res = pps.ppacksvm(jax.random.PRNGKey(5), X, y, lam=0.5, kernel=KERN,
                       epochs=1, pack_size=64)
    assert float(jnp.max(jnp.abs(km.state_["beta"] - res.alpha))) < 1e-5


@pytest.mark.parametrize("plan", available_plans())
def test_same_fit_call_under_every_plan(data, basis, plan):
    """Acceptance: identical call site, plan swapped by config only."""
    X, y, _, _ = data
    km_ref = KernelMachine(CFG).fit(X, y, basis)
    km = KernelMachine(CFG.replace(plan=plan)).fit(X, y, basis)
    # same optimum: objective match tight, beta match loose (otf recomputes
    # gram tiles in a different association order)
    assert abs(km.result_.f - km_ref.result_.f) / abs(km_ref.result_.f) < 1e-4
    assert float(jnp.max(jnp.abs(km.state_["beta"] -
                                 km_ref.state_["beta"]))) < 1e-2


# ---------------------------------------------------------------- save/load
@pytest.mark.parametrize("solver", ["tron", "linearized", "rff", "ppacksvm"])
def test_save_load_identical_decisions(tmp_path, data, basis, solver):
    X, y, Xt, _ = data
    km = KernelMachine(CFG.replace(solver=solver)).fit(
        X, y, basis if get_solver(solver).needs_basis else None)
    path = str(tmp_path / f"{solver}.npz")
    km.save(path)
    km2 = KernelMachine.load(path)
    assert km2.config == km.config
    o1, o2 = km.decision_function(Xt), km2.decision_function(Xt)
    assert float(jnp.max(jnp.abs(o1 - o2))) == 0.0


def test_load_rejects_foreign_checkpoint(tmp_path):
    from repro.checkpoint import save_checkpoint
    path = str(tmp_path / "foreign.npz")
    save_checkpoint(path, {"w": jnp.ones((3,))}, metadata={"other": 1})
    with pytest.raises(ValueError, match="not a KernelMachine checkpoint"):
        KernelMachine.load(path)


# --------------------------------------------------------------- partial_fit
def test_partial_fit_matches_one_shot(data, basis):
    """Stage-wise growth reaches the one-shot optimum (paper §3)."""
    X, y, _, _ = data
    cfg = CFG.replace(tron=TronConfig(max_iter=80, grad_rtol=1e-4))
    km = KernelMachine(cfg)
    km.partial_fit(X, y, basis[:16]).partial_fit(X, y, basis[16:40])
    km.partial_fit(X, y, basis[40:])
    ref = KernelMachine(cfg).fit(X, y, basis)
    assert [r.m for r in km.history_] == [16, 40, 64]
    fs = [r.f for r in km.history_]
    assert fs[0] >= fs[1] >= fs[2]          # objective falls as basis grows
    assert abs(fs[-1] - ref.result_.f) / abs(ref.result_.f) < 1e-2
    assert km.state_["beta"].shape == (64,)


def test_partial_fit_after_fit_grows_basis(data, basis):
    X, y, _, _ = data
    km = KernelMachine(CFG).fit(X, y, basis[:32])
    km.partial_fit(X, y, basis[32:])
    assert km.state_["basis"].shape == basis.shape
    assert len(km.history_) == 2


def test_partial_fit_detects_swapped_same_shape_data(data, basis):
    """Regression: the local-plan (C, W) growth cache used to be keyed on
    X.shape alone, so growing a basis after swapping X for *different*
    data of the same shape silently reused stale kernel columns. The cache
    is now keyed on a sampled-checksum fingerprint: the grown machine must
    land on the optimum of the data it actually saw."""
    X, y, _, _ = data
    # a different dataset of the SAME shape (fresh draw, same generator)
    from repro.data import make_classification
    X2_all, y2_all = make_classification(jax.random.PRNGKey(7), 1280, 12,
                                         clusters_per_class=4, margin=1.0)
    X2, y2 = X2_all[:1024], y2_all[:1024]
    assert X2.shape == X.shape

    cfg = CFG.replace(tron=TronConfig(max_iter=120, grad_rtol=1e-5))
    km = KernelMachine(cfg)
    km.partial_fit(X, y, basis[:32])      # builds the (C, W) cache on X
    km.partial_fit(X2, y2, basis[32:])    # swapped data: must rebuild

    # reference: the identical call sequence with the cache force-cleared
    ref = KernelMachine(cfg)
    ref.partial_fit(X, y, basis[:32])
    ref._cw = ref._cw_key = None
    ref.partial_fit(X2, y2, basis[32:])
    assert float(jnp.max(jnp.abs(km.state_["beta"] -
                                 ref.state_["beta"]))) == 0.0
    assert km.result_.f == ref.result_.f

    # and the fast path still holds: growing on the SAME data reuses the
    # cache — the old basis columns of C are never rebuilt
    import repro.api.machine as machine_mod
    km2 = KernelMachine(cfg)
    km2.partial_fit(X, y, basis[:32])
    orig_build_C, rebuilds = machine_mod.build_C, []
    machine_mod.build_C = lambda *a, **k: (rebuilds.append(1),
                                           orig_build_C(*a, **k))[1]
    try:
        km2.partial_fit(X, y, basis[32:40])
    finally:
        machine_mod.build_C = orig_build_C
    assert not rebuilds                       # cache hit: no full C rebuild
    assert km2._cw[0].shape == (1024, 40)     # grew FROM the cached block


def test_partial_fit_rejected_for_non_growing_solver(data):
    X, y, _, _ = data
    km = KernelMachine(CFG.replace(solver="ppacksvm"))
    with pytest.raises(ValueError, match="stage-wise"):
        km.partial_fit(X, y, X[:8])


def test_stagewise_shim_accepts_loss_string(data, basis):
    """The satellite fix: stagewise accepts loss by name like everyone else."""
    X, y, _, _ = data
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core.stagewise import stagewise_solve
        results = stagewise_solve(X, y, [basis[:32], basis[32:]], lam=0.5,
                                  loss="squared_hinge", kernel=KERN,
                                  cfg=TronConfig(max_iter=40))
    assert [r.m for r in results] == [32, 64]
    assert results[0].f >= results[1].f


def test_solve_shim_accepts_custom_loss_object(data, basis):
    """Legacy solve() took ANY Loss object; the shim must keep that working
    by auto-registering it for the name-keyed config."""
    from repro.core.losses import SQUARED, Loss
    X, y, _, _ = data
    custom = Loss("custom_squared_for_test", SQUARED.value, SQUARED.grad,
                  SQUARED.diag)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import solve
        mach = solve(X, y, basis, lam=0.5, loss=custom, kernel=KERN,
                     cfg=TronConfig(max_iter=30))
    assert mach.beta.shape == (64,)


# ------------------------------------------------------------------- config
def test_config_json_round_trip():
    cfg = CFG.replace(solver="rff", plan="auto", model_axis="model",
                      linearized_rank=16)
    import json
    back = MachineConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg


def test_unknown_loss_rejected_at_config_time():
    with pytest.raises(KeyError, match="unknown loss"):
        MachineConfig(loss="hinge3")


def test_unfitted_machine_raises():
    km = KernelMachine(CFG)
    with pytest.raises(RuntimeError, match="not fitted"):
        km.decision_function(jnp.zeros((2, 12)))
