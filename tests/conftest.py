import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here (brief:
# smoke tests run on 1 device; multi-device tests spawn subprocesses).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
