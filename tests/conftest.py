import os
import signal
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here (brief:
# smoke tests run on 1 device; multi-device tests spawn subprocesses).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


# --------------------------------------------------------------- device gating
def _device_capability() -> int:
    """Devices a test (or its subprocess) can get on this host. The
    multi-device suites run in subprocesses that force
    --xla_force_host_platform_device_count, which works on any CPU-backed
    host for any count; on accelerators the real device count is the cap."""
    if jax.default_backend() == "cpu":
        return 1 << 30
    return jax.device_count()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "requires_devices(k): skip (not error) when fewer than k devices "
        "are available or simulatable (CPU hosts can fake any count in a "
        "subprocess via --xla_force_host_platform_device_count)")
    config.addinivalue_line(
        "markers",
        "requires_multiprocess(timeout=900): spawns a jax.distributed "
        "subprocess fleet; wall-clock guarded by SIGALRM so a hung "
        "collective fails the test instead of the session")


def pytest_runtest_setup(item):
    marker = item.get_closest_marker("requires_devices")
    if marker is not None:
        k = int(marker.args[0])
        have = _device_capability()
        if have < k:
            pytest.skip(f"needs {k} devices; this host has "
                        f"{jax.device_count()} and cannot simulate more")
    if item.get_closest_marker("requires_multiprocess") is not None \
            and not hasattr(signal, "SIGALRM"):
        pytest.skip("requires_multiprocess needs SIGALRM for its hang "
                    "guard (POSIX only)")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    """Wall-clock guard for ``requires_multiprocess`` tests: a fleet whose
    collective hangs (e.g. every worker blocked on a dead peer) raises in
    THIS process instead of stalling the whole pytest session. The rig has
    its own (tighter) watchdog; this alarm is the backstop above it."""
    marker = item.get_closest_marker("requires_multiprocess")
    if marker is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    budget = int(marker.kwargs.get("timeout", 900))

    def _alarm(signum, frame):
        raise TimeoutError(
            f"requires_multiprocess test exceeded its {budget}s wall "
            f"budget — subprocess fleet presumed hung")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(budget)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


# ------------------------------------------------------- shared parity asserts
_DTYPE_TOL = {"float32": 2e-4, "bfloat16": 3e-2, "float16": 4e-3}


def assert_allclose_dtype(got, want, dtype, *, rtol=None, atol=None):
    """allclose with per-dtype tolerances for the kernel parity sweeps.

    ``dtype`` is the *input* dtype of the kernel under test (accumulation
    is always f32, so bf16 inputs dominate the error). The default atol
    scales with the magnitude of ``want`` so linear-kernel outputs (which
    grow with d and m) and unit-range gaussian outputs share one helper.
    """
    want = np.asarray(want)
    tol = _DTYPE_TOL[np.dtype(dtype).name]
    if rtol is None:
        rtol = tol
    if atol is None:
        atol = tol * max(1.0, float(np.max(np.abs(want))) if want.size else 1.0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=rtol, atol=atol)


@pytest.fixture
def allclose_dtype():
    """Fixture view of :func:`assert_allclose_dtype` for tests that prefer
    injection over the conftest import."""
    return assert_allclose_dtype
