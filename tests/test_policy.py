"""DtypePolicy end to end: config plumbing, checkpoint back-compat,
int8-quantized serving checkpoints, and the solver x plan decide
equivalence matrix at the documented per-policy tolerances.

Tolerances below are measured, not aspirational (see the precision-policy
table in docs/paper_map.md): fp32 is plan-exact to f32 roundoff; fp16
margins sit ~1e-3 off fp32; bf16 local decide ~8e-3 and the fused/otf
arms ~1.3e-2 (inherent bf16 input rounding — ~0.4% per operand — not an
accumulation artifact, since accumulation stays fp32 everywhere).
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.config import MachineConfig
from repro.api.machine import KernelMachine
from repro.checkpoint import load_arrays, save_checkpoint
from repro.checkpoint.quant import (QUANT_KEYS, dequantize_int8,
                                    dequantize_state, quantize_int8,
                                    quantize_state)
from repro.core.nystrom import KernelSpec
from repro.kernels.policy import (BF16, FP32, POLICIES, DtypePolicy,
                                  get_policy)

N, D, M = 192, 16, 48


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    X = rng.standard_normal((N, D)).astype(np.float32)
    w = rng.standard_normal(D).astype(np.float32)
    y = np.sign(X @ w + 0.1 * rng.standard_normal(N)).astype(np.float32)
    return X, y


@pytest.fixture(scope="module")
def fitted(data):
    X, y = data
    cfg = MachineConfig(kernel=KernelSpec("gaussian", sigma=float(np.sqrt(D))),
                        solver="tron", plan="local", m=M, lam=0.1, seed=0)
    return KernelMachine(cfg).fit(X, y)


# ------------------------------------------------------------ policy objects
def test_policy_objects():
    assert get_policy(None) is FP32 and get_policy("fp32") is FP32
    assert get_policy("bf16") is BF16 and get_policy(BF16) is BF16
    assert FP32.is_default and not BF16.is_default
    assert BF16.compute_dtype == jnp.bfloat16
    assert BF16.accum_dtype == jnp.float32      # accumulation is never cut
    assert BF16.param_dtype == jnp.float32
    assert BF16.np_compute_dtype().itemsize == 2
    assert set(POLICIES) == {"fp32", "bf16", "fp16"}
    with pytest.raises(ValueError, match="unknown dtype policy"):
        get_policy("int4")
    with pytest.raises(TypeError):
        get_policy(32)
    with pytest.raises(TypeError):
        DtypePolicy(compute="not_a_dtype")
    DtypePolicy(store="int8")                   # quantized store is legal


def test_config_roundtrip_and_backcompat():
    cfg = MachineConfig(dtype_policy="bf16")
    assert cfg.get_policy() is BF16
    assert MachineConfig.from_dict(cfg.to_dict()).dtype_policy == "bf16"
    # configs serialized before the policy field existed carry no key:
    # they must load as the bitwise-unchanged fp32 default
    legacy = cfg.to_dict()
    del legacy["dtype_policy"]
    assert MachineConfig.from_dict(legacy).dtype_policy == "fp32"
    with pytest.raises(ValueError, match="unknown dtype policy"):
        MachineConfig(dtype_policy="int4")


# ------------------------------------------------------ checkpoint back-compat
def test_pre_policy_checkpoint_loads_and_serves_identically(
        tmp_path, fitted, data):
    """A checkpoint written by the pre-policy code (no dtype_policy config
    key, no quantization manifest) loads under the fp32 default and serves
    bitwise-identical margins."""
    X, _ = data
    ref = np.asarray(fitted.decision_function(X))
    cur = os.path.join(tmp_path, "cur.npz")
    old = os.path.join(tmp_path, "old.npz")
    fitted.save(cur)
    arrays, meta = load_arrays(cur)
    del meta["config"]["dtype_policy"]          # what an old writer produced
    assert "quantized" not in meta
    save_checkpoint(old, arrays, metadata=meta)
    km = KernelMachine.load(old)
    assert km.config.dtype_policy == "fp32"
    for k, v in fitted.state_.items():
        assert np.array_equal(np.asarray(km.state_[k]), np.asarray(v)), k
    assert np.array_equal(np.asarray(km.decision_function(X)), ref)


def test_load_policy_override(tmp_path, fitted, data):
    X, _ = data
    ref = np.asarray(fitted.decision_function(X))
    path = os.path.join(tmp_path, "km.npz")
    fitted.save(path)
    same = KernelMachine.load(path)
    assert np.array_equal(np.asarray(same.decision_function(X)), ref)
    km16 = KernelMachine.load(path, policy="bf16")
    assert km16.config.dtype_policy == "bf16"
    got = np.asarray(km16.decision_function(X))
    rel = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    assert 0 < rel < 3e-2, rel                  # close, but NOT bitwise


# ----------------------------------------------------------- int8 quantization
def test_quantize_int8_roundtrip_bound():
    rng = np.random.default_rng(3)
    # wildly different per-column dynamic ranges + an all-zero column
    A = rng.standard_normal((64, 6)).astype(np.float32)
    A *= np.float32(10.0) ** np.arange(-3, 3, dtype=np.float32)
    A[:, 2] = 0.0
    q, s = quantize_int8(A)
    assert q.dtype == np.int8 and s.dtype == np.float32 and s.shape == (6,)
    back = dequantize_int8(q, s)
    # symmetric rounding: per-element error <= half a quantization step,
    # i.e. each column reconstructs within amax_j / 254
    bound = np.maximum(np.abs(A), 0).max(axis=0) / 254.0 + 1e-12
    assert np.all(np.abs(back - A) <= bound[None, :] * (1 + 1e-6))
    assert np.array_equal(back[:, 2], A[:, 2])  # zero column exact
    # 1-D beta path: one column
    b = rng.standard_normal(32).astype(np.float32)
    qb, sb = quantize_int8(b)
    assert sb.shape == (1,)
    assert np.max(np.abs(dequantize_int8(qb, sb) - b)) \
        <= np.max(np.abs(b)) / 254.0 * (1 + 1e-6)


def test_quantize_state_manifest_validation():
    state = {"basis": np.ones((8, 4), np.float32),
             "beta": np.arange(8, dtype=np.float32),
             "classes": np.arange(3)}
    tree, manifest = quantize_state(state)
    assert set(manifest) == set(QUANT_KEYS)
    assert "basis::q8" in tree and "basis::scale" in tree
    assert np.array_equal(tree["classes"], state["classes"])  # passthrough
    back = dequantize_state(tree, manifest)
    assert set(back) == set(state)
    with pytest.raises(ValueError, match="unknown quantization scheme"):
        quantize_state(state, "int4")
    with pytest.raises(ValueError, match="does not declare"):
        dequantize_state(tree, {})              # undeclared quantized entry
    with pytest.raises(ValueError, match="absent from the checkpoint"):
        dequantize_state({"beta": state["beta"]}, {"basis": "int8"})


@pytest.mark.dtype
def test_quantized_checkpoint_roundtrip(tmp_path, fitted, data):
    """save(quantize='int8') -> load serves margins within the documented
    bound of the fp32 machine, and the loaded state is deterministic."""
    X, _ = data
    ref = np.asarray(fitted.decision_function(X))
    path = os.path.join(tmp_path, "q8.npz")
    fitted.save(path, quantize="int8")
    km = KernelMachine.load(path)
    got = np.asarray(km.decision_function(X))
    rel = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    assert rel < 5e-2, rel      # measured ~3e-2: basis rounding dominates
    # quantized checkpoint + bf16 serving policy: the intended fleet setup.
    # bf16 adds nothing measurable on top of int8 (3e-2 vs 3e-2): the
    # int8 step amax/254 is coarser than bf16's relative rounding here.
    km16 = KernelMachine.load(path, policy="bf16")
    got16 = np.asarray(km16.decision_function(X))
    rel16 = np.max(np.abs(got16 - ref)) / np.max(np.abs(ref))
    assert rel16 < 6e-2, rel16


@pytest.mark.dtype
def test_quantized_checkpoint_size_ratio(tmp_path):
    """At serving scale (m=1024) the int8 checkpoint is <= 0.3x the fp32
    bytes — the acceptance point; tiny machines are zip-overhead-bound."""
    rng = np.random.default_rng(0)
    km = KernelMachine(MachineConfig(m=1024))
    km.state_ = {"basis": jnp.asarray(rng.standard_normal((1024, 64)),
                                      jnp.float32),
                 "beta": jnp.asarray(rng.standard_normal(1024), jnp.float32)}
    full = os.path.join(tmp_path, "full.npz")
    q8 = os.path.join(tmp_path, "q8.npz")
    km.save(full)
    km.save(q8, quantize="int8")
    ratio = os.path.getsize(q8) / os.path.getsize(full)
    assert ratio <= 0.3, ratio


# ------------------------------------------------- decide equivalence matrix
#: plan -> per-policy relative-margin tolerance vs the fp32 local reference.
#: fp32 must agree to f32 roundoff on every plan; fp16 to ~1e-3; bf16 is
#: input-rounding-bound: ~8e-3 on the materialized local arm, ~1.3e-2 on
#: the fused/otf/stream arms (the gram tile is evaluated at bf16 there).
_MATRIX_TOL = {
    "fp32": {"local": 1e-5, "otf": 1e-5, "otf_shard": 1e-5,
             "shard_map": 1e-5, "stream": 1e-5},
    "fp16": {"local": 4e-3, "otf": 4e-3, "otf_shard": 4e-3,
             "shard_map": 4e-3, "stream": 4e-3},
    "bf16": {"local": 1e-2, "otf": 3e-2, "otf_shard": 3e-2,
             "shard_map": 3e-2, "stream": 3e-2},
}


@pytest.mark.dtype
@pytest.mark.parametrize("policy", sorted(_MATRIX_TOL))
def test_decide_equivalence_matrix(policy, fitted, data):
    """One fp32-trained state, every decide arm x this policy: margins stay
    within the documented tolerance of the fp32 local reference."""
    X, _ = data
    ref = np.asarray(fitted.decision_function(X))
    scale = np.max(np.abs(ref))
    km = KernelMachine(fitted.config.replace(dtype_policy=policy))
    km.state_ = fitted.state_
    for plan, tol in _MATRIX_TOL[policy].items():
        got = np.asarray(km.decision_function(X, plan=plan))
        rel = np.max(np.abs(got - ref)) / scale
        assert rel < tol, (policy, plan, rel)


@pytest.mark.dtype
def test_decide_fp32_policy_bitwise(fitted, data):
    """The explicit fp32 policy is not merely close on the local arm — it
    is the same trace, hence bitwise."""
    X, _ = data
    km = KernelMachine(fitted.config.replace(dtype_policy="fp32"))
    km.state_ = fitted.state_
    assert np.array_equal(np.asarray(km.decision_function(X)),
                          np.asarray(fitted.decision_function(X)))


# --------------------------------------------------------- serving dtype wire
@pytest.mark.dtype
def test_serve_registry_policy_dtype(fitted, data):
    """Registry entries carry the machine's compute dtype; the load
    generator ships payloads in it; warmup + verification stay coherent."""
    from repro.serve.loadgen import baseline_target, make_workload, run_load
    from repro.serve.registry import ModelRegistry

    X, _ = data
    reg = ModelRegistry(max_batch=32)
    reg.add("f32", fitted)
    km16 = KernelMachine(fitted.config.replace(dtype_policy="bf16"))
    km16.state_ = fitted.state_
    reg.add("b16", km16)
    assert reg.get("f32").dtype == np.dtype(np.float32)
    assert reg.get("b16").dtype.itemsize == 2           # ml_dtypes bfloat16
    counts = reg.warmup()
    assert counts["f32"] > 0 and counts["b16"] > 0
    streams = make_workload(reg, clients=2, requests_per_client=4,
                            max_rows=16, seed=1)
    for stream in streams:
        for req in stream:
            assert req.X.dtype == reg.get(req.model).dtype
    report = run_load(baseline_target(reg), streams, label="policy-smoke")
    assert report.completed == 8 and report.mismatches == 0
