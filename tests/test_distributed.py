"""Distributed Algorithm 1 correctness — runs in a subprocess with 8
simulated devices (XLA_FLAGS must be set before jax imports, and the main
test process must keep seeing 1 device per the project brief)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 8-fake-device subprocess, minutes of compiles

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (DistConfig, DistributedNystrom, KernelSpec,
                        TronConfig, random_basis, solve)
from repro.core.basis import kmeans
from repro.core.compat import make_mesh
from repro.data import make_classification

key = jax.random.PRNGKey(0)
X, y = make_classification(key, 2048, 16, clusters_per_class=4)
kern = KernelSpec("gaussian", sigma=2.0)
basis = random_basis(jax.random.PRNGKey(2), X, 128)
ref = solve(X, y, basis, lam=0.5, kernel=kern, cfg=TronConfig(max_iter=50))

out = {"n_devices": len(jax.devices())}
cases = [
    ((8,), ("data",), None, "shard_map", True),
    ((8,), ("data",), None, "auto", True),
    ((4, 2), ("data", "model"), "model", "shard_map", True),
    ((4, 2), ("data", "model"), "model", "auto", True),
    ((4, 2), ("data", "model"), "model", "shard_map", False),  # on-the-fly C
    ((2, 2, 2), ("pod", "data", "model"), "model", "shard_map", True),
]
for shape, names, ma, mode, mat in cases:
    mesh = make_mesh(shape, names)
    da = tuple(a for a in names if a != "model")
    dc = DistConfig(data_axes=da, model_axis=ma, mode=mode, materialize=mat)
    solver = DistributedNystrom(mesh, 0.5, "squared_hinge", kern, dc)
    Xs = jax.device_put(X, NamedSharding(mesh, P(da, None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(da)))
    res = solver.solve(Xs, ys, basis, cfg=TronConfig(max_iter=50))
    tag = f"{shape}-{mode}-{'mat' if mat else 'otf'}"
    out[tag] = {
        "f": float(res.f), "ref_f": float(ref.stats.f),
        "max_dbeta": float(jnp.max(jnp.abs(res.beta - ref.beta))),
    }

# unified estimator: the SAME fit call under four execution plans on the
# 8-device mesh — only MachineConfig.plan changes between runs
from repro.api import KernelMachine, MachineConfig
mesh8 = make_mesh((8,), ("data",))
Xs8 = jax.device_put(X, NamedSharding(mesh8, P(("data",), None)))
ys8 = jax.device_put(y, NamedSharding(mesh8, P(("data",))))
base_cfg = MachineConfig(kernel=kern, lam=0.5, tron=TronConfig(max_iter=50))
for plan in ("local", "shard_map", "auto", "otf"):
    km = KernelMachine(base_cfg.replace(plan=plan), mesh=mesh8)
    km.fit(Xs8, ys8, basis)
    out["api-" + plan] = {
        "f": km.result_.f, "ref_f": float(ref.stats.f),
        "max_dbeta": float(jnp.max(jnp.abs(km.state_["beta"] - ref.beta))),
    }

# distributed k-means == single-device k-means
mesh = make_mesh((4, 2), ("data", "model"))
c_local, _ = kmeans(jax.random.PRNGKey(5), X, 16, n_iter=3)
Xs = jax.device_put(X, NamedSharding(mesh, P(("data",), None)))
c_dist, _ = kmeans(jax.random.PRNGKey(5), Xs, 16, n_iter=3, mesh=mesh,
                   data_axes=("data",))
out["kmeans_max_diff"] = float(jnp.max(jnp.abs(c_local - c_dist)))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_eight_devices(results):
    assert results["n_devices"] == 8


@pytest.mark.parametrize("tag", [
    "(8,)-shard_map-mat", "(8,)-auto-mat",
    "(4, 2)-shard_map-mat", "(4, 2)-auto-mat",
    "(4, 2)-shard_map-otf", "(2, 2, 2)-shard_map-mat",
])
def test_distributed_matches_local(results, tag):
    r = results[tag]
    assert abs(r["f"] - r["ref_f"]) / abs(r["ref_f"]) < 1e-4, r
    # 5e-4 not 1e-4: psum/matmul reduction order differs across shard_map
    # implementations (jax.experimental vs jax.shard_map), and W's small
    # eigenvalues leave near-flat directions where beta moves at ~1e-4
    # for an objective change below float32 resolution.
    assert r["max_dbeta"] < 5e-4, r


def test_distributed_kmeans_matches_local(results):
    assert results["kmeans_max_diff"] < 1e-4


@pytest.mark.parametrize("plan", ["local", "shard_map", "auto", "otf"])
def test_kernel_machine_plans_match_on_8_devices(results, plan):
    """Acceptance: one fit call, plan swapped by config, same optimum."""
    r = results[f"api-{plan}"]
    assert abs(r["f"] - r["ref_f"]) / abs(r["ref_f"]) < 1e-4, r
    assert r["max_dbeta"] < 1e-3, r
