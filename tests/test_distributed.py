"""Distributed Algorithm 1 correctness — runs in a subprocess with 8
simulated devices (XLA_FLAGS must be set before jax imports, and the main
test process must keep seeing 1 device per the project brief)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow,  # 8-fake-device subprocess, min. of compiles
              pytest.mark.requires_devices(8)]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (DistConfig, DistributedNystrom, KernelSpec,
                        TronConfig, random_basis, solve)
from repro.core.basis import kmeans
from repro.core.compat import make_mesh
from repro.data import make_classification

key = jax.random.PRNGKey(0)
X, y = make_classification(key, 2048, 16, clusters_per_class=4)
kern = KernelSpec("gaussian", sigma=2.0)
basis = random_basis(jax.random.PRNGKey(2), X, 128)
ref = solve(X, y, basis, lam=0.5, kernel=kern, cfg=TronConfig(max_iter=50))

out = {"n_devices": len(jax.devices())}
cases = [
    ((8,), ("data",), None, "shard_map", True, False),
    ((8,), ("data",), None, "auto", True, False),
    ((4, 2), ("data", "model"), "model", "shard_map", True, False),
    ((4, 2), ("data", "model"), "model", "auto", True, False),
    ((4, 2), ("data", "model"), "model", "shard_map", False, False),  # otf C
    ((2, 2, 2), ("pod", "data", "model"), "model", "shard_map", True, False),
    ((8,), ("data",), None, "shard_map", False, True),   # fused (otf_shard)
]
for shape, names, ma, mode, mat, fused in cases:
    mesh = make_mesh(shape, names)
    da = tuple(a for a in names if a != "model")
    dc = DistConfig(data_axes=da, model_axis=ma, mode=mode, materialize=mat,
                    fused=fused)
    solver = DistributedNystrom(mesh, 0.5, "squared_hinge", kern, dc)
    Xs = jax.device_put(X, NamedSharding(mesh, P(da, None)))
    ys = jax.device_put(y, NamedSharding(mesh, P(da)))
    res = solver.solve(Xs, ys, basis, cfg=TronConfig(max_iter=50))
    tag = f"{shape}-{mode}-" + ("fused" if fused else "mat" if mat else "otf")
    out[tag] = {
        "f": float(res.f), "ref_f": float(ref.stats.f),
        "max_dbeta": float(jnp.max(jnp.abs(res.beta - ref.beta))),
    }

# one row-sharded 8-device mesh shared by everything below
mesh8 = make_mesh((8,), ("data",))
Xs8 = jax.device_put(X, NamedSharding(mesh8, P(("data",), None)))
ys8 = jax.device_put(y, NamedSharding(mesh8, P(("data",))))

# otf_shard memory contract on the real 8-device mesh: per-shard bound
from repro.core.introspect import max_intermediate_elems
for backend in ("jnp", "pallas"):
    dc = DistConfig(materialize=False, fused=True, backend=backend)
    solver = DistributedNystrom(mesh8, 0.5, "squared_hinge", kern, dc)
    fg, hd = solver.make_fused_closures(Xs8, ys8, basis)
    with mesh8:
        out[f"fused-max-intermediate-{backend}"] = max(
            max_intermediate_elems(fg, jnp.zeros(basis.shape[0])),
            max_intermediate_elems(hd, jnp.ones(X.shape[0]),
                                   jnp.zeros(basis.shape[0])))
out["nm_per_shard"] = (X.shape[0] // 8) * basis.shape[0]

# acceptance: otf_shard beta matches a tightly-converged local solve to
# 1e-4 relative (both runs share the tight stopping criterion)
tight = TronConfig(max_iter=300, grad_rtol=1e-6)
ref_t = solve(X, y, basis, lam=0.5, kernel=kern, cfg=tight)
dc = DistConfig(materialize=False, fused=True)
solver = DistributedNystrom(mesh8, 0.5, "squared_hinge", kern, dc)
res_t = solver.solve(Xs8, ys8, basis, cfg=tight)
out["otf_shard_rel_l2"] = float(
    jnp.linalg.norm(res_t.beta - ref_t.beta) / jnp.linalg.norm(ref_t.beta))

# stream plan on the same 8-device mesh, fed from a real mmap shard
# directory (shard boundaries deliberately misaligned with chunk_rows)
import tempfile
import numpy as np
from repro.data.chunks import MmapChunkSource, save_chunks
with tempfile.TemporaryDirectory() as td:
    save_chunks(td, np.asarray(X), np.asarray(y), rows_per_shard=600)
    src = MmapChunkSource(td, chunk_rows=512)
    sol_s = DistributedNystrom(mesh8, 0.5, "squared_hinge", kern,
                               DistConfig(materialize=False, fused=True))
    res_s = sol_s.solve_stream(src, np.asarray(basis), cfg=tight)
    out["stream_rel_l2"] = float(
        jnp.linalg.norm(res_s.beta - ref_t.beta) / jnp.linalg.norm(ref_t.beta))
    # per-chunk memory contract with the real 8-way sharding
    sc = sol_s.make_stream_closures(src, np.asarray(basis))
    m = basis.shape[0]
    cr = sc.chunk_rows
    Xc = jnp.zeros((cr, X.shape[1])); vc = jnp.zeros((cr,))
    with mesh8:
        out["stream_max_intermediate"] = max(
            max_intermediate_elems(sc.fg_chunk, Xc, vc, vc, basis,
                                   jnp.zeros((m,))),
            max_intermediate_elems(sc.hd_chunk, Xc, vc, basis,
                                   jnp.zeros((m,))))
    out["chunk_m_elems"] = cr * m

# unified estimator: the SAME fit call under every execution plan on the
# 8-device mesh — only MachineConfig.plan changes between runs
from repro.api import KernelMachine, MachineConfig
base_cfg = MachineConfig(kernel=kern, lam=0.5, tron=TronConfig(max_iter=50))
for plan in ("local", "shard_map", "auto", "otf", "otf_shard", "stream"):
    km = KernelMachine(base_cfg.replace(plan=plan), mesh=mesh8)
    km.fit(Xs8, ys8, basis)
    out["api-" + plan] = {
        "f": km.result_.f, "ref_f": float(ref.stats.f),
        "max_dbeta": float(jnp.max(jnp.abs(km.state_["beta"] - ref.beta))),
    }

# stage-wise growth under the fused plan: warm-started partial_fit on the
# same 8-device mesh reaches the same optimum as a fresh local fit
grow_cfg = MachineConfig(kernel=kern, lam=0.5, plan="otf_shard", tron=tight)
km_g = KernelMachine(grow_cfg, mesh=mesh8)
km_g.partial_fit(Xs8, ys8, basis[:64]).partial_fit(Xs8, ys8, basis[64:])
out["otf_shard_growth"] = {
    "stages": len(km_g.history_),
    "rel_l2": float(jnp.linalg.norm(km_g.state_["beta"] - ref_t.beta)
                    / jnp.linalg.norm(ref_t.beta)),
}

# distributed k-means == single-device k-means
mesh = make_mesh((4, 2), ("data", "model"))
c_local, _ = kmeans(jax.random.PRNGKey(5), X, 16, n_iter=3)
Xs = jax.device_put(X, NamedSharding(mesh, P(("data",), None)))
c_dist, _ = kmeans(jax.random.PRNGKey(5), Xs, 16, n_iter=3, mesh=mesh,
                   data_axes=("data",))
out["kmeans_max_diff"] = float(jnp.max(jnp.abs(c_local - c_dist)))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_eight_devices(results):
    assert results["n_devices"] == 8


@pytest.mark.parametrize("tag", [
    "(8,)-shard_map-mat", "(8,)-auto-mat",
    "(4, 2)-shard_map-mat", "(4, 2)-auto-mat",
    "(4, 2)-shard_map-otf", "(2, 2, 2)-shard_map-mat",
    "(8,)-shard_map-fused",
])
def test_distributed_matches_local(results, tag):
    r = results[tag]
    assert abs(r["f"] - r["ref_f"]) / abs(r["ref_f"]) < 1e-4, r
    # 5e-4 not 1e-4: psum/matmul reduction order differs across shard_map
    # implementations (jax.experimental vs jax.shard_map), and W's small
    # eigenvalues leave near-flat directions where beta moves at ~1e-4
    # for an objective change below float32 resolution.
    assert r["max_dbeta"] < 5e-4, r


def test_distributed_kmeans_matches_local(results):
    assert results["kmeans_max_diff"] < 1e-4


@pytest.mark.parametrize("plan", ["local", "shard_map", "auto", "otf",
                                  "otf_shard", "stream"])
def test_kernel_machine_plans_match_on_8_devices(results, plan):
    """Acceptance: one fit call, plan swapped by config, same optimum."""
    r = results[f"api-{plan}"]
    assert abs(r["f"] - r["ref_f"]) / abs(r["ref_f"]) < 1e-4, r
    assert r["max_dbeta"] < 1e-3, r


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_otf_shard_no_nm_block_on_any_device(results, backend):
    """Memory contract: the fused closures never allocate the per-shard
    (n/p, m) C block (jaxpr shape instrumentation, per-device avals)."""
    got = results[f"fused-max-intermediate-{backend}"]
    assert got < results["nm_per_shard"], (got, results["nm_per_shard"])


def test_otf_shard_beta_matches_local_1e4(results):
    """Acceptance: otf_shard trains tron on the 8-device mesh to a beta
    within 1e-4 relative of the tightly-converged local solve."""
    assert results["otf_shard_rel_l2"] < 1e-4, results["otf_shard_rel_l2"]


def test_otf_shard_partial_fit_growth_on_mesh(results):
    """Stage-wise growth keeps working under the fused plan: no CW cache
    to extend, recomputation makes growth trivially correct."""
    g = results["otf_shard_growth"]
    assert g["stages"] == 2
    assert g["rel_l2"] < 1e-3, g


def test_stream_beta_matches_local_1e4(results):
    """Acceptance: the out-of-core stream solve (real mmap shards, 8-way
    mesh, host TRON) lands within 1e-4 relative of the tight local solve."""
    assert results["stream_rel_l2"] < 1e-4, results["stream_rel_l2"]


def test_stream_chunk_memory_contract_on_mesh(results):
    """No per-chunk intermediate reaches chunk_rows x m elements on the
    real 8-device mesh (per-shard avals)."""
    assert results["stream_max_intermediate"] < results["chunk_m_elems"], \
        (results["stream_max_intermediate"], results["chunk_m_elems"])
