"""Attention variant unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn


def naive_attention(q, k, v, *, causal=True, window=0):
    """Reference softmax attention. q: (B,S,Kv,G,hd), k/v: (B,S,Kv,hd)."""
    B, S, Kv, G, hd = q.shape
    s = jnp.einsum("bqcgd,bkcd->bqcgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqcgk,bkcd->bqcgd", w, v.astype(jnp.float32))


def _qkv(B=2, S=64, Kv=2, G=3, hd=16, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, S, Kv, G, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, Kv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("blk", [8, 16, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(blk, causal):
    q, k, v = _qkv()
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    got = attn._flash(q, k, v, pos, 0, causal=causal, window=0, blk=blk)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [4, 16])
def test_flash_sliding_window(window):
    q, k, v = _qkv()
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    got = attn._flash(q, k, v, pos, 0, causal=True, window=window, blk=16)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_newton_schulz_pinv_converges():
    """Z -> A^-1 for well-conditioned PSD A (row-softmax matrices are)."""
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (8, 8))
    A = jax.nn.softmax(logits, axis=-1) + 0.5 * jnp.eye(8)
    Z = attn._newton_schulz_pinv(A[None], iters=12)[0]
    np.testing.assert_allclose(np.asarray(Z @ A), np.eye(8), atol=5e-2)


def test_nystrom_attention_exact_at_full_landmarks():
    """With m == S (bidirectional), the Nystrom factorization with a
    converged pseudo-inverse reproduces exact attention."""
    q, k, v = _qkv(S=32)
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    got = attn._nystrom_attention(q, k, v, pos, n_landmarks=32, causal=False)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.15, atol=0.15)


def test_nystrom_attention_approximates_causal():
    """Causal nystrom should correlate strongly with exact causal attention
    away from the earliest positions (segment-granular causality)."""
    q, k, v = _qkv(S=64, seed=3)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    got = attn._nystrom_attention(q, k, v, pos, n_landmarks=16, causal=True)
    want = naive_attention(q, k, v, causal=True)
    g = np.asarray(got)[:, 16:].ravel()
    w = np.asarray(want)[:, 16:].ravel()
    corr = np.corrcoef(g, w)[0, 1]
    # random (maximally diffuse) attention is the worst case for landmark
    # approximation; structured attention correlates far higher
    assert corr > 0.55, corr
    assert np.isfinite(g).all()


def test_nystrom_no_future_leakage():
    """Changing FUTURE keys/values must not change past outputs beyond the
    landmark-segment granularity boundary."""
    q, k, v = _qkv(S=64, seed=4)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    out1 = attn._nystrom_attention(q, k, v, pos, n_landmarks=8, causal=True)
    k2 = k.at[:, -8:].set(99.0)
    v2 = v.at[:, -8:].set(-99.0)
    out2 = attn._nystrom_attention(q, k2, v2, pos, n_landmarks=8, causal=True)
    # The segment-granular masks make the landmark kernel lower-triangular,
    # so the ONLY forward leak is through the Newton-Schulz initialization
    # scalar (global |A|_1 |A|_inf) — it must stay small (documented
    # approximate-causality, DESIGN.md). Exact attention would give 0 here.
    leak = np.max(np.abs(np.asarray(out1[:, :48]) - np.asarray(out2[:, :48])))
    signal = np.max(np.abs(np.asarray(out1[:, :48])))
    assert leak < 0.05 * signal, (leak, signal)
