"""Exercises the dry-run lowering path at small scale in a subprocess
(8 fake devices, reduced configs) — validates shardings/lowering machinery
without the 512-device production compile (run via repro.launch.dryrun)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow,  # 8-fake-device subprocess, min. of compiles
              pytest.mark.requires_devices(8)]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS
from repro.models.common import unzip
from repro.models.config import ShapeSpec
from repro.models.registry import cache_specs, input_specs, make_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.sharding.ctx import use_shard_hints
from repro.sharding.partitioning import batch_specs, cache_pspecs, param_specs
from repro.train.steps import make_serve_step, make_train_step

from repro.core import compat
from repro.core.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
out = {}
for name in ("tinyllama-1.1b", "mamba2-1.3b", "grok-1-314b",
             "deepseek-v2-236b", "whisper-small"):
    cfg = ARCHS[name].reduced(vocab=256)
    model = make_model(cfg, max_dec_seq=64)
    ann = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sds, axes = unzip(ann)
    p_specs = param_specs(axes, mesh)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    shape = ShapeSpec("t", 96 if cfg.is_encdec else 32, 8, "train")
    batch_sds = input_specs(cfg, shape)
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           batch_specs(batch_sds, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    ocfg = AdamWConfig()
    opt_sds = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_sds)
    opt_shard = {"m": p_shard, "v": p_shard, "step": NamedSharding(mesh, P())}
    step = make_train_step(model, ocfg, microbatches=2)
    with mesh, use_shard_hints(mesh):
        lowered = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                          out_shardings=(p_shard, opt_shard, None),
                          donate_argnums=(0, 1)).lower(
            params_sds, opt_sds, batch_sds)
        compiled = lowered.compile()
    cost = compat.cost_analysis(compiled)
    # decode path
    dshape = ShapeSpec("d", 64, 8, "decode")
    cache_sds = cache_specs(cfg, dshape)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           cache_pspecs(cache_sds, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
    serve = make_serve_step(model)
    with mesh, use_shard_hints(mesh):
        lc = jax.jit(serve,
                     in_shardings=(p_shard, NamedSharding(mesh, P(("data",), None)), c_shard),
                     out_shardings=(None, None, c_shard),
                     donate_argnums=(2,)).lower(params_sds, tok, cache_sds)
        cc = lc.compile()
    out[name] = {"train_flops": float(cost.get("flops", 0)),
                 "decode_ok": True}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "mamba2-1.3b",
                                  "grok-1-314b", "deepseek-v2-236b",
                                  "whisper-small"])
def test_lowering_compiles_on_mesh(results, name):
    assert results[name]["decode_ok"]
    assert results[name]["train_flops"] > 0
