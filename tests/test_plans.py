"""Execution-plan equivalence and the otf_shard memory contract.

The plan matrix iterates the *registry*, so a newly registered plan is
automatically held to the same standard: same small problem, same config,
beta agreeing with every other plan within tolerance. The memory tests
use jaxpr shape instrumentation (repro.core.introspect) to prove the
fused plan never materializes a C block — the claim that distinguishes
``otf_shard`` from ``otf``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelMachine, MachineConfig, StreamConfig, available_plans
from repro.core import KernelSpec, TronConfig, random_basis
from repro.core.compat import make_mesh
from repro.core.distributed import DistConfig, DistributedNystrom
from repro.core.introspect import (assert_max_intermediate_below,
                                   max_intermediate_elems)
from repro.data import ArrayChunkSource, make_classification

N, M, D = 256, 32, 8
CHUNK = 64          # stream plan chunking for this fixture (4 chunks)


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    X, y = make_classification(key, N, D, clusters_per_class=2)
    basis = random_basis(jax.random.PRNGKey(2), X, M)
    return X, y, basis


@pytest.fixture(scope="module")
def config():
    # tight grad_rtol: plans must agree at the *optimum*, not merely at a
    # loose early stop where near-flat directions of W leave beta slack
    return MachineConfig(kernel=KernelSpec("gaussian", sigma=2.0), lam=0.5,
                         tron=TronConfig(max_iter=300, grad_rtol=1e-6),
                         stream=StreamConfig(chunk_rows=CHUNK))


@pytest.fixture(scope="module")
def fits(problem, config):
    X, y, basis = problem
    out = {}
    for plan in available_plans():
        km = KernelMachine(config.replace(plan=plan)).fit(X, y, basis)
        out[plan] = np.asarray(km.state_["beta"])
    return out


def test_matrix_covers_registry(fits):
    assert set(fits) == set(available_plans())
    assert "otf_shard" in fits
    assert "stream" in fits             # the plan this PR adds is registered


@pytest.mark.parametrize("plan", available_plans())
def test_plan_matches_every_other(plan, fits):
    """Pairwise beta agreement across the whole registry."""
    b = fits[plan]
    scale = max(np.max(np.abs(v)) for v in fits.values())
    for other, bo in fits.items():
        assert np.max(np.abs(b - bo)) / scale < 5e-4, (plan, other)


def test_otf_shard_matches_local_tight(fits):
    """Acceptance: otf_shard's beta within 1e-4 relative of local's."""
    b, bl = fits["otf_shard"], fits["local"]
    assert np.linalg.norm(b - bl) / np.linalg.norm(bl) < 1e-4


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_otf_shard_never_materializes_C(problem, backend):
    """No intermediate of the fused f/g/Hd closures reaches n x m elements;
    the non-fused otf path (which rebuilds the per-shard block) is the
    positive control proving the instrumentation sees gram blocks."""
    X, y, basis = problem
    mesh = make_mesh((1,), ("data",))
    kern = KernelSpec("gaussian", sigma=2.0)
    beta = jnp.zeros((M,), X.dtype)
    D = jnp.ones((N,), X.dtype)

    fused = DistributedNystrom(
        mesh, 0.5, "squared_hinge", kern,
        DistConfig(materialize=False, fused=True, backend=backend))
    fg, hd = fused.make_fused_closures(X, y, basis)
    with mesh:
        assert_max_intermediate_below(fg, N * M, beta)
        assert_max_intermediate_below(hd, N * M, D, beta)

    control = DistributedNystrom(mesh, 0.5, "squared_hinge", kern,
                                 DistConfig(materialize=False))
    fg_c, _ = control.make_otf_closures(X, y, basis)
    with mesh:
        assert max_intermediate_elems(fg_c, beta) >= N * M


def test_otf_shard_partial_fit_growth(problem, config):
    """Stage-wise basis growth under otf_shard: recomputation makes growth
    trivially correct — the grown machine must land on the same optimum as
    a fresh local fit on the full basis, warm start included."""
    X, y, basis = problem
    ref = KernelMachine(config).fit(X, y, basis)
    km = KernelMachine(config.replace(plan="otf_shard"))
    km.partial_fit(X, y, basis[: M // 2]).partial_fit(X, y, basis[M // 2:])
    assert len(km.history_) == 2
    assert km.state_["beta"].shape == (M,)
    b, br = np.asarray(km.state_["beta"]), np.asarray(ref.state_["beta"])
    assert np.linalg.norm(b - br) / np.linalg.norm(br) < 1e-3
    # the warm-started second stage must keep the fitted objective value
    assert abs(km.result_.f - ref.result_.f) / abs(ref.result_.f) < 1e-4


def test_otf_shard_rejects_model_axis(problem):
    X, y, basis = problem
    cfg = MachineConfig(plan="otf_shard", model_axis="model")
    with pytest.raises(ValueError, match="rows only"):
        KernelMachine(cfg).fit(X, y, basis)


def test_stream_matches_local_tight(fits):
    """Acceptance: stream's beta within 1e-4 relative of local's."""
    b, bl = fits["stream"], fits["local"]
    assert np.linalg.norm(b - bl) / np.linalg.norm(bl) < 1e-4


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stream_never_materializes_chunk_gram(problem, backend):
    """Memory contract: no intermediate of the per-chunk f/g/Hd bodies
    reaches chunk_rows x m elements — the streamed gram is contracted
    through the fused kmvp path, never built."""
    X, y, basis = problem
    mesh = make_mesh((1,), ("data",))
    kern = KernelSpec("gaussian", sigma=2.0)
    solver = DistributedNystrom(
        mesh, 0.5, "squared_hinge", kern,
        DistConfig(materialize=False, fused=True, backend=backend))
    src = ArrayChunkSource(np.asarray(X), np.asarray(y), CHUNK)
    sc = solver.make_stream_closures(src, np.asarray(basis))
    cr = sc.chunk_rows
    Xc = jnp.zeros((cr, D))
    yc = jnp.zeros((cr,))
    wc = jnp.ones((cr,))
    beta = jnp.zeros((M,))
    Dl = jnp.ones((cr,))
    with mesh:
        assert_max_intermediate_below(sc.fg_chunk, cr * M, Xc, yc, wc,
                                      jnp.asarray(basis), beta)
        assert_max_intermediate_below(sc.hd_chunk, cr * M, Xc, Dl,
                                      jnp.asarray(basis), beta)


def test_stream_partial_fit_growth(problem, config):
    """Stage-wise growth under stream: like otf_shard, recomputation makes
    growth trivially correct — the grown machine lands on the fresh-fit
    optimum, warm start and all."""
    X, y, basis = problem
    ref = KernelMachine(config).fit(X, y, basis)
    km = KernelMachine(config.replace(plan="stream"))
    km.partial_fit(X, y, basis[: M // 2]).partial_fit(X, y, basis[M // 2:])
    assert len(km.history_) == 2
    assert km.state_["beta"].shape == (M,)
    b, br = np.asarray(km.state_["beta"]), np.asarray(ref.state_["beta"])
    assert np.linalg.norm(b - br) / np.linalg.norm(br) < 1e-3
    assert abs(km.result_.f - ref.result_.f) / abs(ref.result_.f) < 1e-4


def test_stream_ragged_n_and_chunking_invariance(problem, config):
    """n not divisible by the chunk size (mask-padded ragged last chunk)
    must give the same optimum as any other chunking of the same data."""
    X, y, basis = problem
    X, y = X[:200], y[:200]            # 200 = 3 x 64 + 8: ragged
    ref = KernelMachine(config).fit(X, y, basis)
    km = KernelMachine(config.replace(
        plan="stream", stream=StreamConfig(chunk_rows=56))).fit(X, y, basis)
    b, br = np.asarray(km.state_["beta"]), np.asarray(ref.state_["beta"])
    assert np.linalg.norm(b - br) / np.linalg.norm(br) < 1e-4


def test_stream_rejects_model_axis(problem):
    X, y, basis = problem
    cfg = MachineConfig(plan="stream", model_axis="model")
    with pytest.raises(ValueError, match="rows only"):
        KernelMachine(cfg).fit(X, y, basis)


def test_otf_shard_rff_solver(problem, config):
    """The validity matrix re-examination: rff composes with otf_shard via
    the exact linear-kernel reduction and matches rff under local."""
    X, y, _ = problem
    base = config.replace(solver="rff", rff_features=32)
    b_local = KernelMachine(base.replace(plan="local")).fit(X, y).state_["beta"]
    b_fused = KernelMachine(base.replace(plan="otf_shard")).fit(X, y).state_["beta"]
    assert np.max(np.abs(np.asarray(b_fused) - np.asarray(b_local))) < 5e-4


# -------------------------------------------- multiclass one-vs-rest (multi-RHS)
KCLS = 3


@pytest.fixture(scope="module")
def mc_problem():
    """K-class integer-label problem + its explicit ±1 one-vs-rest targets."""
    from repro.data import make_multiclass
    from repro.data.chunks import ovr_targets
    X, yi = make_multiclass(jax.random.PRNGKey(0), N, D, KCLS,
                            clusters_per_class=4)
    basis = random_basis(jax.random.PRNGKey(2), X, M)
    Y = ovr_targets(np.asarray(yi), np.arange(KCLS))
    return X, yi, Y, basis


@pytest.fixture(scope="module")
def mc_config(config):
    # lam high enough that every one-vs-rest column is well conditioned;
    # rtol 1e-5 is where the f32 one-vs-rest problems reliably terminate
    return config.replace(lam=8.0,
                          tron=TronConfig(max_iter=300, grad_rtol=1e-5))


@pytest.fixture(scope="module")
def mc_fits(mc_problem, mc_config):
    """One multi-RHS fit per registered plan on the SAME integer labels."""
    X, yi, _, basis = mc_problem
    out = {}
    for plan in available_plans():
        out[plan] = KernelMachine(mc_config.replace(plan=plan)).fit(X, yi,
                                                                    basis)
    return out


def test_multiclass_matrix_covers_registry(mc_fits, mc_problem):
    """Every plan fits integer labels as one (m, K) multi-RHS solve with
    classes in the state and label-space predictions."""
    X, yi, _, _ = mc_problem
    assert set(mc_fits) == set(available_plans())
    for plan, km in mc_fits.items():
        assert km.state_["beta"].shape == (M, KCLS), plan
        np.testing.assert_array_equal(np.asarray(km.state_["classes"]),
                                      np.arange(KCLS))
        o = km.decision_function(X[:16])
        assert o.shape == (16, KCLS), plan
        preds = np.asarray(km.predict(X))
        assert set(np.unique(preds)) <= set(range(KCLS)), plan
        assert km.score(X, yi) > 0.8, plan


def test_multiclass_plans_agree(mc_fits):
    """Pairwise beta agreement of the multi-RHS fits across the registry.

    Looser than the binary matrix (5e-4): the one-vs-rest hinge problems
    sit on wider f32 stagnation plateaus; the objective-level test below
    pins the tight equivalence."""
    betas = {p: np.asarray(km.state_["beta"]) for p, km in mc_fits.items()}
    scale = max(np.max(np.abs(b)) for b in betas.values())
    for p1, b1 in betas.items():
        for p2, b2 in betas.items():
            assert np.max(np.abs(b1 - b2)) / scale < 2e-3, (p1, p2)


@pytest.mark.parametrize("plan", ["stream", "otf_shard"])
def test_multiclass_matches_sequential_fits(plan, mc_problem, mc_config):
    """Acceptance: one multi-RHS fit == K sequential single-RHS fits, per
    column, within 1e-4 relative — compared at a matched iteration budget
    so trajectory-level equivalence is what is asserted (at this budget
    the stream driver is bit-identical; full-convergence equivalence is
    asserted on the objective below, where f32 plateau wander cannot
    blur it)."""
    X, yi, Y, basis = mc_problem
    cfg = mc_config.replace(plan=plan,
                            tron=TronConfig(max_iter=4, grad_rtol=1e-6))
    multi = np.asarray(KernelMachine(cfg).fit(X, yi, basis).state_["beta"])
    for k in range(KCLS):
        solo = np.asarray(
            KernelMachine(cfg).fit(X, jnp.asarray(Y[:, k]),
                                   basis).state_["beta"])
        rel = np.linalg.norm(multi[:, k] - solo) / np.linalg.norm(solo)
        assert rel < 1e-4, (plan, k, rel)


def test_multiclass_objective_matches_sequential(mc_problem, mc_config,
                                                 mc_fits):
    """Full-convergence equivalence: each column of the multi-RHS solve
    reaches the same objective value as its standalone single-RHS fit
    (the per-column f is the invariant the plateau cannot blur)."""
    X, _, Y, basis = mc_problem
    f_multi = np.asarray(mc_fits["stream"].result_.tron.f)
    assert f_multi.shape == (KCLS,)
    for k in range(KCLS):
        km = KernelMachine(mc_config.replace(plan="stream")).fit(
            X, jnp.asarray(Y[:, k]), basis)
        f_solo = float(km.result_.f)
        assert abs(f_multi[k] - f_solo) / abs(f_solo) < 1e-5, (k, f_multi[k],
                                                              f_solo)


def test_multiclass_fused_memory_contract_k_aware(mc_problem):
    """No intermediate of the K=8 multi-RHS fused f/g/Hd bodies reaches
    n x m elements (fused_contract_limit guards that the bound still
    separates legal (n, K) blocks from the forbidden gram block)."""
    from repro.core.introspect import fused_contract_limit
    X, _, _, basis = mc_problem
    K = 8
    mesh = make_mesh((1,), ("data",))
    kern = KernelSpec("gaussian", sigma=2.0)
    Y8 = jnp.ones((N, K))
    beta = jnp.zeros((M, K))
    D8 = jnp.ones((N, K))
    fused = DistributedNystrom(
        mesh, 0.5, "squared_hinge", kern,
        DistConfig(materialize=False, fused=True))
    fg, hd = fused.make_fused_closures(X, Y8, basis)
    limit = fused_contract_limit(N, M, K)
    with mesh:
        assert_max_intermediate_below(fg, limit, beta)
        assert_max_intermediate_below(hd, limit, D8, beta)
    with pytest.raises(ValueError, match="vacuous"):
        fused_contract_limit(N, M, k=M)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stream_multirhs_memory_contract(mc_problem, backend):
    """The stream chunk bodies keep the chunk_rows x m bound with K=8
    right-hand sides — including the cached-chunk path (the cache holds
    (chunk_rows, d) X blocks, which the walker sees as inputs, not
    intermediates; what matters is no gram chunk appears)."""
    from repro.core.introspect import fused_contract_limit
    X, yi, _, basis = mc_problem
    K = 8
    mesh = make_mesh((1,), ("data",))
    kern = KernelSpec("gaussian", sigma=2.0)
    solver = DistributedNystrom(
        mesh, 0.5, "squared_hinge", kern,
        DistConfig(materialize=False, fused=True, backend=backend))
    src = ArrayChunkSource(np.asarray(X), np.asarray(yi), CHUNK)
    sc = solver.make_stream_closures(src, np.asarray(basis),
                                     classes=np.arange(K))
    cr = sc.chunk_rows
    limit = fused_contract_limit(cr, M, K)
    Xc = jnp.zeros((cr, D))
    Yc = jnp.zeros((cr, K))
    wc = jnp.ones((cr,))
    beta = jnp.zeros((M, K))
    Dl = jnp.ones((cr, K))
    with mesh:
        assert_max_intermediate_below(sc.fg_chunk, limit, Xc, Yc, wc,
                                      jnp.asarray(basis), beta)
        assert_max_intermediate_below(sc.hd_chunk, limit, Xc, Dl,
                                      jnp.asarray(basis), beta)


# ------------------------------------------------- plan-aware inference
def _fitted_for(solver, problem, config):
    """One machine per solver, trained under its cheapest valid plan."""
    from repro.api import get_solver
    X, y, basis = problem
    cfg = config.replace(solver=solver, plan="local", rff_features=M,
                         ppack_epochs=1)
    entry = get_solver(solver)
    return KernelMachine(cfg).fit(X, y, basis if entry.needs_basis else None)


@pytest.mark.parametrize("solver", ["tron", "linearized", "rff", "ppacksvm"])
def test_decision_plan_matrix_parity(solver, problem, config):
    """Every registered (solver, plan) pair's decision_function matches the
    local dense reference at 1e-5 — including pairs whose TRAINING
    composition is invalid (linearized/ppacksvm are local-pinned solvers,
    but o(x) is one kmvp, valid under every decide arm)."""
    X, _, _ = problem
    km = _fitted_for(solver, problem, config)
    Xt = X[:100]                      # ragged vs chunk_rows AND mesh extent
    ref = np.asarray(km.decision_function(Xt, plan="local"))
    scale = max(np.max(np.abs(ref)), 1e-6)
    for plan in available_plans():
        o = np.asarray(km.decision_function(Xt, plan=plan))
        assert o.shape == ref.shape, (solver, plan)
        assert np.max(np.abs(o - ref)) / scale < 1e-5, (solver, plan)


def test_decision_unknown_plan_rejected(problem, config):
    km = _fitted_for("tron", problem, config)
    with pytest.raises(KeyError, match="unknown execution plan"):
        km.decision_function(problem[0][:8], plan="no_such_plan")


def test_multiclass_decision_plan_parity(mc_fits, mc_problem):
    """The (n, K) multi-RHS margin block survives every decide arm: same
    one-multi-RHS-evaluation margins, same argmax labels."""
    X, _, _, _ = mc_problem
    km = mc_fits["local"]
    ref = np.asarray(km.decision_function(X[:50], plan="local"))
    for plan in available_plans():
        o = np.asarray(km.decision_function(X[:50], plan=plan))
        assert o.shape == (50, KCLS), plan
        assert np.max(np.abs(o - ref)) / np.max(np.abs(ref)) < 1e-5, plan
        np.testing.assert_array_equal(
            np.asarray(km.predict(X[:50], plan=plan)),
            np.asarray(km.predict(X[:50], plan="local")))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_decision_never_materializes_test_gram(problem, config,
                                                     backend):
    """Inference keeps the training-side memory contract: no intermediate
    of the fused margin body reaches n x m elements; the dense local arm
    is the positive control proving the walker sees test grams."""
    from repro.api.infer import DecisionSpec, make_margin_body
    from repro.core.nystrom import gram as dense_gram
    X, _, basis = problem
    mesh = make_mesh((1,), ("data",))
    kern = KernelSpec("gaussian", sigma=2.0)
    beta = jnp.zeros((M,), X.dtype)
    spec = DecisionSpec(map_x=lambda x: x, basis=basis, beta=beta,
                        kernel=kern, backend=backend)
    body = make_margin_body(config, mesh, spec)
    with mesh:
        assert_max_intermediate_below(body, N * M, X, basis, beta)
    control = lambda Xq: dense_gram(Xq, basis, kern, "jnp") @ beta
    assert max_intermediate_elems(control, X) >= N * M


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stream_decision_memory_contract(mc_problem, config, backend):
    """Acceptance: the stream decide arm's per-chunk body stays under
    chunk_rows x m elements with a K=8 multi-RHS beta block
    (fused_contract_limit guards the bound still separates)."""
    from repro.api.infer import DecisionSpec, make_stream_decider
    from repro.core.introspect import fused_contract_limit
    X, _, _, basis = mc_problem
    K = 8
    mesh = make_mesh((1,), ("data",))
    spec = DecisionSpec(map_x=lambda x: x, basis=jnp.asarray(basis),
                        beta=jnp.zeros((M, K)),
                        kernel=KernelSpec("gaussian", sigma=2.0),
                        backend=backend)
    src = ArrayChunkSource(np.asarray(X), np.zeros((N,), np.float32), CHUNK)
    sd = make_stream_decider(config, mesh, spec, src)
    cr = sd.chunk_rows
    shapes = (jax.ShapeDtypeStruct((cr, D), jnp.float32),
              jax.ShapeDtypeStruct((M, D), jnp.float32),
              jax.ShapeDtypeStruct((M, K), jnp.float32))
    with mesh:
        assert_max_intermediate_below(sd.o_chunk,
                                      fused_contract_limit(cr, M, K), *shapes)


@pytest.mark.parametrize("solver", ["rff", "linearized", "ppacksvm"])
def test_multiclass_rejected_by_binary_solvers(mc_problem, solver):
    """Integer multiclass labels route to tron's multi-RHS path; the
    binary-only solvers refuse them with a pointer instead of silently
    fitting garbage."""
    X, yi, _, basis = mc_problem
    cfg = MachineConfig(solver=solver, plan="local")
    with pytest.raises(ValueError, match="binary-only"):
        KernelMachine(cfg).fit(X, yi,
                               basis if solver == "linearized" else None)


# ----------------------------------------- multi-controller plan validation
def test_multihost_rejects_materializing_plans_at_construction():
    """Every plan outside MULTIHOST_PLANS must fail a multi-process
    topology check with a message that names the plan, says why, and
    lists the plans that DO work — at construction, not deep in a trace."""
    from repro.sharding import multihost
    bad = sorted(set(available_plans()) - multihost.MULTIHOST_PLANS)
    assert bad, "no materializing plans left to reject?"
    for plan in bad:
        with pytest.raises(ValueError) as ei:
            multihost.check_plan(plan, num_processes=2)
        msg = str(ei.value)
        assert plan in msg                      # names the offender
        assert "stream" in msg and "otf_shard" in msg   # names the fix
        assert "multi-controller" in msg        # names the context


def test_multihost_plans_accepted_and_single_process_unconstrained():
    from repro.sharding import multihost
    for plan in sorted(multihost.MULTIHOST_PLANS):
        multihost.check_plan(plan, num_processes=4)     # no raise
    for plan in available_plans():
        multihost.check_plan(plan, num_processes=1)     # no raise


def test_multihost_machine_construction_fails_under_live_topology():
    """With an active 2-process topology, KernelMachine construction
    itself (registry validate) rejects non-partitionable plans; the
    multihost-safe plans still construct."""
    from repro.sharding import multihost
    assert multihost.current_span() is None, "test leaked a topology"
    try:
        multihost._SPAN = multihost.HostSpan(0, 2)
        with pytest.raises(ValueError, match="multi-controller"):
            KernelMachine(MachineConfig(plan="shard_map"))
        KernelMachine(MachineConfig(plan="stream"))      # constructs fine
        KernelMachine(MachineConfig(plan="otf_shard"))
    finally:
        multihost._reset_for_tests()


def test_multihost_span_and_mesh_validation():
    from types import SimpleNamespace
    from repro.sharding import multihost
    with pytest.raises(ValueError, match="out of range"):
        multihost.HostSpan(process_id=2, num_processes=2)
    with pytest.raises(ValueError, match="num_processes"):
        multihost.HostSpan(process_id=0, num_processes=0)
    # a mesh that does not cover the global device list is rejected with
    # a pointer at spanning_mesh (stub: check_mesh_spans reads .size only)
    with pytest.raises(ValueError, match="spanning_mesh"):
        multihost.check_mesh_spans(
            SimpleNamespace(size=jax.device_count() + 1), num_processes=2)
    multihost.check_mesh_spans(SimpleNamespace(size=1), num_processes=1)
