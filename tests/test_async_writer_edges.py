"""AsyncCheckpointWriter lifecycle edges (satellite 3).

The happy path and the drop-oldest policy live in
tests/test_checkpoint_resume.py; this file pins the boundary behaviors a
preemption or slow disk actually hits: flush timeouts expiring against an
in-flight write, close() racing an in-flight write, submit-after-close,
and the retry accounting around a transiently failing commit.
"""
import threading
import time

import pytest

from repro.checkpoint import AsyncCheckpointWriter
from repro.util.retry import RetryPolicy


class _GatedWrite:
    """A write_fn that blocks until released — a controllable slow disk."""

    def __init__(self):
        self.entered = threading.Event()
        self.release = threading.Event()
        self.calls = []

    def __call__(self, step, tree, metadata):
        self.calls.append(step)
        self.entered.set()
        assert self.release.wait(30.0), "test forgot to release the write"
        return 10


def test_flush_timeout_expires_against_inflight_write():
    gate = _GatedWrite()
    w = AsyncCheckpointWriter(gate)
    w.submit(1, {}, {})
    assert gate.entered.wait(10.0)
    # the write is in flight and blocked: a bounded flush must give up...
    t0 = time.monotonic()
    assert w.flush(timeout=0.1) is False
    assert time.monotonic() - t0 < 5.0
    # ...and an unbounded one must succeed once the disk unblocks
    gate.release.set()
    assert w.flush(timeout=30.0) is True
    assert w.stats()["snapshots_written"] == 1
    w.close()


def test_close_racing_inflight_write_completes_it():
    gate = _GatedWrite()
    w = AsyncCheckpointWriter(gate)
    w.submit(1, {}, {})
    assert gate.entered.wait(10.0)
    closer = threading.Thread(target=lambda: w.close(flush=True))
    closer.start()
    time.sleep(0.05)                        # close() is now blocked in flush
    assert closer.is_alive()
    gate.release.set()
    closer.join(30.0)
    assert not closer.is_alive()
    st = w.stats()
    assert st["snapshots_written"] == 1 and st["errors"] == 0


def test_close_without_flush_drops_pending():
    gate = _GatedWrite()
    w = AsyncCheckpointWriter(gate)
    w.submit(1, {}, {})
    assert gate.entered.wait(10.0)
    w.submit(2, {}, {})                     # parked in the pending slot
    gate.release.set()
    w.close(flush=False)
    st = w.stats()
    # step 1 (in flight at close) commits; step 2 (pending) is dropped
    assert st["snapshots_written"] == 1
    assert st["snapshots_dropped"] == 1
    assert st["last_step"] == 1


def test_submit_after_close_raises():
    w = AsyncCheckpointWriter(lambda s, t, m: 0)
    w.close()
    with pytest.raises(RuntimeError, match="closed"):
        w.submit(1, {}, {})


def test_transient_write_error_retried_not_counted_as_error():
    calls = []

    def flaky(step, tree, metadata):
        calls.append(step)
        if len(calls) == 1:
            raise OSError("blip")
        return 5

    w = AsyncCheckpointWriter(flaky, retry=RetryPolicy(max_attempts=3,
                                                       backoff_s=0.01))
    w.submit(7, {}, {})
    assert w.flush(timeout=30.0)
    w.close()
    st = w.stats()
    assert calls == [7, 7]
    assert st["errors"] == 0
    assert st["write_retries"] == 1
    assert st["snapshots_written"] == 1 and st["last_step"] == 7
