"""repro.util.retry: the shared backoff policy every recovery path uses.

The policy is proven here once; the fault-injection tests
(test_faults.py) then only need to prove the *wiring* — that chunk reads
and checkpoint commits actually route through it.
"""
import pytest

from repro.util.retry import RetryPolicy, call_with_retry


def _no_sleep(_):
    pass


def test_succeeds_after_transient_failures():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    retries = []
    out = call_with_retry(RetryPolicy(max_attempts=3), flaky,
                          label="t", sleep=_no_sleep,
                          on_retry=lambda a, e, d: retries.append((a, d)))
    assert out == "ok"
    assert len(calls) == 3
    assert [a for a, _ in retries] == [1, 2]
    assert all(d >= 0 for _, d in retries)


def test_attempt_cap_raises_last_error():
    def always():
        raise OSError("still broken")

    with pytest.raises(OSError, match="still broken"):
        call_with_retry(RetryPolicy(max_attempts=3), always, sleep=_no_sleep)


def test_non_retryable_raises_immediately():
    calls = []

    def bad():
        calls.append(1)
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        call_with_retry(RetryPolicy(max_attempts=5), bad, sleep=_no_sleep)
    assert len(calls) == 1


def test_custom_retryable_predicate():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) == 1:
            raise KeyError("routing miss")
        return 42

    policy = RetryPolicy(max_attempts=2,
                         retryable=lambda e: isinstance(e, KeyError))
    assert call_with_retry(policy, flaky, sleep=_no_sleep) == 42
    assert len(calls) == 2


def test_backoff_caps_and_jitter_is_deterministic():
    p = RetryPolicy(backoff_s=0.1, backoff_mult=2.0, max_backoff_s=0.25,
                    jitter=0.1, max_attempts=10)
    # capped exponential: 0.1, 0.2, 0.25, 0.25, ... before jitter
    for attempt, base in ((1, 0.1), (2, 0.2), (3, 0.25), (7, 0.25)):
        d = p.delay(attempt, label="x")
        assert base <= d <= base * 1.1 + 1e-12
    # same (label, attempt) -> same delay; different label -> (almost
    # surely) different jitter, never a different base
    assert p.delay(2, "a") == p.delay(2, "a")
    assert p.delay(2, "a") != p.delay(2, "b")


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_s=-1.0)


def test_keyboard_interrupt_never_retried():
    calls = []

    def interrupted():
        calls.append(1)
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        call_with_retry(RetryPolicy(max_attempts=5), interrupted,
                        sleep=_no_sleep)
    assert len(calls) == 1
