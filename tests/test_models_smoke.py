"""The model-family suite: per-architecture smoke (reduced variant of
each family, one forward/train/decode step, shapes + no NaNs), the
attention / MoE / SSD unit parity checks, and the teacher-forced-vs-
stepwise decode consistency sweep for every cache implementation.

(Absorbs the former test_attention.py, test_moe_ssm.py and
test_decode_consistency.py — one suite per subsystem, not one file per
historical PR.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import attention as attn
from repro.models import encdec as encdec_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as lm_mod
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.models.transformer import D_VISION
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.steps import make_train_step

B, S = 2, 32
S_DEC = 24          # decode-consistency sweep length (3 SSD chunks of 8)


def _batch(cfg, key):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            kf, (B, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(
            kf, (B, cfg.n_patches, D_VISION), cfg.jnp_dtype)
    return batch


# jamba's 52b config compiles a ~1-minute train step even reduced —
# right at the fast gate's per-test budget, so it runs with the slow suite
@pytest.mark.parametrize(
    "name", [pytest.param(n, marks=pytest.mark.slow)
             if n == "jamba-v0.1-52b" else n for n in sorted(ARCHS)])
def test_arch_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    model = make_model(cfg, max_dec_seq=64)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"

    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    p2, opt2, m2 = step(params, opt, batch)
    assert bool(jnp.isfinite(m2["loss"]))
    assert bool(jnp.isfinite(m2["gnorm"])) and float(m2["gnorm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_decode_step(name):
    cfg = ARCHS[name].reduced()
    model = make_model(cfg, max_dec_seq=64)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    cache = model.init_cache(params, batch, 64)
    step = jax.jit(model.decode_step)
    toks = batch["tokens"][:, :1]
    for _ in range(3):
        logits, cache = step(params, toks, cache)
        assert logits.shape == (B, 1, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: NaN in decode"
        toks = jnp.argmax(logits, axis=-1)


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "mamba2-1.3b",
                                  "grok-1-314b"])
def test_training_reduces_loss(name):
    """A few steps on a fixed batch must reduce the loss (memorization)."""
    cfg = ARCHS[name].reduced()
    model = make_model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


# ===================================================== attention unit parity
def naive_attention(q, k, v, *, causal=True, window=0):
    """Reference softmax attention. q: (B,S,Kv,G,hd), k/v: (B,S,Kv,hd)."""
    B, S, Kv, G, hd = q.shape
    s = jnp.einsum("bqcgd,bkcd->bqcgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqcgk,bkcd->bqcgd", w, v.astype(jnp.float32))


def _qkv(B=2, S=64, Kv=2, G=3, hd=16, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, S, Kv, G, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, Kv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("blk", [8, 16, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(blk, causal):
    q, k, v = _qkv()
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    got = attn._flash(q, k, v, pos, 0, causal=causal, window=0, blk=blk)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [4, 16])
def test_flash_sliding_window(window):
    q, k, v = _qkv()
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    got = attn._flash(q, k, v, pos, 0, causal=True, window=window, blk=16)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_newton_schulz_pinv_converges():
    """Z -> A^-1 for well-conditioned PSD A (row-softmax matrices are)."""
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (8, 8))
    A = jax.nn.softmax(logits, axis=-1) + 0.5 * jnp.eye(8)
    Z = attn._newton_schulz_pinv(A[None], iters=12)[0]
    np.testing.assert_allclose(np.asarray(Z @ A), np.eye(8), atol=5e-2)


def test_nystrom_attention_exact_at_full_landmarks():
    """With m == S (bidirectional), the Nystrom factorization with a
    converged pseudo-inverse reproduces exact attention."""
    q, k, v = _qkv(S=32)
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    got = attn._nystrom_attention(q, k, v, pos, n_landmarks=32, causal=False)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.15, atol=0.15)


def test_nystrom_attention_approximates_causal():
    """Causal nystrom should correlate strongly with exact causal attention
    away from the earliest positions (segment-granular causality)."""
    q, k, v = _qkv(S=64, seed=3)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    got = attn._nystrom_attention(q, k, v, pos, n_landmarks=16, causal=True)
    want = naive_attention(q, k, v, causal=True)
    g = np.asarray(got)[:, 16:].ravel()
    w = np.asarray(want)[:, 16:].ravel()
    corr = np.corrcoef(g, w)[0, 1]
    # random (maximally diffuse) attention is the worst case for landmark
    # approximation; structured attention correlates far higher
    assert corr > 0.55, corr
    assert np.isfinite(g).all()


def test_nystrom_no_future_leakage():
    """Changing FUTURE keys/values must not change past outputs beyond the
    landmark-segment granularity boundary."""
    q, k, v = _qkv(S=64, seed=4)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    out1 = attn._nystrom_attention(q, k, v, pos, n_landmarks=8, causal=True)
    k2 = k.at[:, -8:].set(99.0)
    v2 = v.at[:, -8:].set(-99.0)
    out2 = attn._nystrom_attention(q, k2, v2, pos, n_landmarks=8, causal=True)
    # The segment-granular masks make the landmark kernel lower-triangular,
    # so the ONLY forward leak is through the Newton-Schulz initialization
    # scalar (global |A|_1 |A|_inf) — it must stay small (documented
    # approximate-causality, DESIGN.md). Exact attention would give 0 here.
    leak = np.max(np.abs(np.asarray(out1[:, :48]) - np.asarray(out2[:, :48])))
    signal = np.max(np.abs(np.asarray(out1[:, :48])))
    assert leak < 0.05 * signal, (leak, signal)


# ===================================================== MoE / SSD unit parity
def _moe_setup(E=4, k=2, d=32, ff=64, cf=8.0):
    cfg = ARCHS["grok-1-314b"].reduced(
        n_experts=E, top_k=k, moe_d_ff=ff, d_model=d, capacity_factor=cf)
    params, _ = unzip(moe_mod.init_moe(jax.random.PRNGKey(0), cfg))
    return cfg, params


def test_moe_output_shape_and_finite():
    cfg, params = _moe_setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y, aux = moe_mod.apply_moe(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0


def test_moe_matches_dense_expert_sum():
    """With huge capacity (no dropping), grouped dispatch must equal the
    direct per-token weighted sum over its top-k experts."""
    cfg, params = _moe_setup(cf=100.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 32), jnp.float32)
    y, _ = moe_mod.apply_moe(params, cfg, x)

    xt = x.reshape(8, 32)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(xt)
    for t in range(8):
        acc = jnp.zeros((32,))
        for j in range(cfg.top_k):
            e = int(gi[t, j])
            h = jax.nn.silu(xt[t] @ params["w1"][e]) * (xt[t] @ params["w3"][e])
            acc = acc + gv[t, j] * (h @ params["w2"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """With capacity_factor ~ 0 tokens get dropped -> output ~ 0 (no shared)."""
    cfg, params = _moe_setup(cf=1e-6)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 32), jnp.float32)
    y, _ = moe_mod.apply_moe(params, cfg, x)
    # capacity floor is 4 per expert -> most tokens dropped, tiny norm
    full_cfg, _ = _moe_setup(cf=100.0)
    y_full, _ = moe_mod.apply_moe(params, full_cfg, x)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))


def naive_ssd(xh, dt, Bm, Cm, A):
    """Direct recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t; y = C h."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = np.zeros((Bsz, H, N, P), np.float64)
    ys = []
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t] * A[None, :], np.float64))
        upd = np.einsum("bn,bh,bhp->bhnp", np.asarray(Bm[:, t], np.float64),
                        np.asarray(dt[:, t], np.float64),
                        np.asarray(xh[:, t], np.float64))
        h = decay[:, :, None, None] * h + upd
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, t], np.float64), h))
    return np.stack(ys, axis=1)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_scan_matches_naive_recurrence(chunk):
    key = jax.random.PRNGKey(0)
    Bsz, S, H, P, N = 2, 16, 3, 4, 5
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    Bm = jax.random.normal(ks[2], (Bsz, S, N))
    Cm = jax.random.normal(ks[3], (Bsz, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    got = ssm_mod.ssd_scan(xh, dt, Bm, Cm, A, chunk)
    want = naive_ssd(xh, dt, Bm, Cm, np.asarray(A))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


def test_ssm_decode_matches_train():
    """ssm_train over a sequence == repeated ssm_decode state updates."""
    cfg = ARCHS["mamba2-1.3b"].reduced(ssm_chunk=8)
    params, _ = unzip(ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg))
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y_train = ssm_mod.ssm_train(params, cfg, h)
    cache = jax.tree.map(lambda x: x[0],
                         ssm_mod.init_ssm_cache(cfg, 2, layers=1))
    outs = []
    for t in range(16):
        y, cache = ssm_mod.ssm_decode(params, cfg, h[:, t: t + 1], cache, t)
        outs.append(y[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=2e-3, atol=2e-3)


# ============================================== decode/forward consistency
# Teacher-forced forward logits MUST match step-by-step decode logits —
# the strongest end-to-end correctness check for every cache implementation
# (GQA KV, sliding ring, MLA compressed/absorbed, SSM state, enc-dec
# cross). ~3 min of per-arch decode loops on CPU, hence the slow marker.
def _decode_all(model, params, tokens, cache):
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = model.decode_step(params, tokens[:, t: t + 1], cache)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache   # (B, S_DEC, V)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["tinyllama-1.1b", "llama3.2-1b", "qwen3-4b",
                                  "granite-34b", "grok-1-314b"])
def test_dense_moe_decode_matches_forward(name):
    # capacity_factor high enough that no token is dropped: capacity-based
    # MoE routing otherwise LEGITIMATELY differs between the 48-token
    # teacher-forced groups and the 2-token decode groups (documented
    # train/serve discrepancy of capacity routers).
    cfg = ARCHS[name].reduced(capacity_factor=64.0)
    model = make_model(cfg, max_dec_seq=S_DEC)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S_DEC), 0,
                                cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S_DEC)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_mla_absorbed_decode_matches_forward():
    cfg = ARCHS["deepseek-v2-236b"].reduced(capacity_factor=64.0)
    model = make_model(cfg, max_dec_seq=S_DEC)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S_DEC), 0,
                                cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S_DEC)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=3e-3, atol=3e-3)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["mamba2-1.3b", "jamba-v0.1-52b"])
def test_ssm_hybrid_decode_matches_forward(name):
    # S_DEC=24 -> 3 SSD chunks of 8
    cfg = ARCHS[name].reduced(ssm_chunk=8, capacity_factor=64.0)
    model = make_model(cfg, max_dec_seq=S_DEC)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S_DEC), 0,
                                cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S_DEC)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=5e-3, atol=5e-3)


@pytest.mark.slow
def test_encdec_decode_matches_forward():
    cfg = ARCHS["whisper-small"].reduced()
    model = make_model(cfg, max_dec_seq=S_DEC)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.encoder_seq, cfg.d_model),
                               cfg.jnp_dtype)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S_DEC), 0,
                                cfg.vocab)
    enc_out = encdec_mod.encode(params, cfg, frames)
    fwd_logits = encdec_mod.decoder_forward(params, cfg, tokens, enc_out)
    cache = encdec_mod.init_encdec_cache(params, cfg, frames, S_DEC)
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer decode == full forward with a sliding-window mask."""
    cfg = ARCHS["tinyllama-1.1b"].reduced(window=8,
                                          attention_variant="sliding")
    model = make_model(cfg, max_dec_seq=S_DEC)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S_DEC), 0,
                                cfg.vocab)
    fwd_logits, _, _ = lm_mod.forward_lm(params, cfg, {"tokens": tokens},
                                         remat=False)
    cache = lm_mod.init_cache(cfg, B, S_DEC)
    assert cache.layers["kv_0"].k.shape[2] == 8   # ring buffer, not S_DEC
    dec_logits, _ = _decode_all(model, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits), rtol=2e-3, atol=2e-3)
