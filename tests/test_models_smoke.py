"""Per-architecture smoke tests (brief deliverable f): reduced variant of
each family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.common import unzip
from repro.models.registry import make_model
from repro.models.transformer import D_VISION
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.steps import make_train_step

B, S = 2, 32


def _batch(cfg, key):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            kf, (B, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(
            kf, (B, cfg.n_patches, D_VISION), cfg.jnp_dtype)
    return batch


# jamba's 52b config compiles a ~1-minute train step even reduced —
# right at the fast gate's per-test budget, so it runs with the slow suite
@pytest.mark.parametrize(
    "name", [pytest.param(n, marks=pytest.mark.slow)
             if n == "jamba-v0.1-52b" else n for n in sorted(ARCHS)])
def test_arch_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    model = make_model(cfg, max_dec_seq=64)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"

    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    p2, opt2, m2 = step(params, opt, batch)
    assert bool(jnp.isfinite(m2["loss"]))
    assert bool(jnp.isfinite(m2["gnorm"])) and float(m2["gnorm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_decode_step(name):
    cfg = ARCHS[name].reduced()
    model = make_model(cfg, max_dec_seq=64)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    cache = model.init_cache(params, batch, 64)
    step = jax.jit(model.decode_step)
    toks = batch["tokens"][:, :1]
    for _ in range(3):
        logits, cache = step(params, toks, cache)
        assert logits.shape == (B, 1, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: NaN in decode"
        toks = jnp.argmax(logits, axis=-1)


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "mamba2-1.3b",
                                  "grok-1-314b"])
def test_training_reduces_loss(name):
    """A few steps on a fixed batch must reduce the loss (memorization)."""
    cfg = ARCHS[name].reduced()
    model = make_model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
